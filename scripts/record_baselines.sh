#!/usr/bin/env bash
# Record the committed perf-trajectory baselines (fig15 + fig17) on a
# machine with a Rust toolchain — the reference numbers that `crh
# bench-compare` and the CI compare step diff fresh runs against.
#
# Usage, from the repo root:
#
#   scripts/record_baselines.sh            # full-size runs (slow, real)
#   QUICK=1 scripts/record_baselines.sh    # smoke-size dry run (do NOT
#                                          # commit these as baselines)
#
# Then inspect `benchmarks/baselines/BENCH_*.json` and commit them.
# Snapshots embed a machine fingerprint (CPU model/count, kernel,
# CRH_* env); record on the machine CI actually runs on, or the
# compare step will warn about cross-fingerprint diffs instead of
# gating.
set -euo pipefail

cd "$(dirname "$0")/.."
out_dir="$(pwd)/benchmarks/baselines"

args=()
if [ "${QUICK:-0}" = "1" ]; then
    echo "record_baselines: QUICK=1 — smoke sizes, not commit-worthy" >&2
    args+=(-- --quick)
fi

cd rust
for bench in fig15_resize fig17_frontend; do
    echo "== recording ${bench} -> ${out_dir}/BENCH_*.json" >&2
    CRH_BENCH_JSON=1 CRH_BENCH_JSON_DIR="${out_dir}" \
        cargo bench --bench "${bench}" "${args[@]}"
done

echo "== done; review and commit:" >&2
ls -l "${out_dir}"/BENCH_*.json >&2
