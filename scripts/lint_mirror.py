#!/usr/bin/env python3
"""Non-authoritative Python mirror of `crh lint` (rust/src/analysis/).

The authoritative implementation is the Rust one, run by CI as a
blocking lane. This mirror exists because the audit workflow (writing
SAFETY:/ORDERING: comments across the crate) sometimes happens in
environments without a Rust toolchain; it reimplements the same lexer
and rules L001-L005 so the tree can be checked for self-cleanliness
anywhere python3 runs. If the two ever disagree, fix the mirror.

Usage: scripts/lint_mirror.py [path ...]   (default: rust/src rust/tests
       rust/benches examples, relative to the repo root, skipping
       lint_fixtures/)
"""

import os
import re
import sys

# --------------------------------------------------------------- lexer

IDENT_START = re.compile(r"[A-Za-z_]")
IDENT_CONT = re.compile(r"[A-Za-z0-9_]")


class Tok:
    __slots__ = ("kind", "text", "line", "col", "end_line")

    def __init__(self, kind, text, line, col, end_line=None):
        self.kind = kind
        self.text = text
        self.line = line
        self.col = col
        self.end_line = end_line if end_line is not None else line

    def is_punct(self, c):
        return self.kind == "punct" and self.text == c

    def is_ident(self, s):
        return self.kind == "ident" and self.text == s

    def is_comment(self):
        return self.kind in ("line_comment", "block_comment")


def lex(src):
    toks = []
    i, line, col = 0, 1, 1
    n = len(src)

    def bump(k=1):
        nonlocal i, line, col
        for _ in range(k):
            if i < n and src[i] == "\n":
                line += 1
                col = 1
            else:
                col += 1
            i += 1

    while i < n:
        c = src[i]
        sl, sc = line, col
        if c.isspace():
            bump()
        elif src.startswith("//", i):
            j = src.find("\n", i)
            j = n if j == -1 else j
            toks.append(Tok("line_comment", src[i:j], sl, sc))
            bump(j - i)
        elif src.startswith("/*", i):
            depth, j = 0, i
            while j < n:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                    if depth == 0:
                        break
                else:
                    j += 1
            start = i
            bump(j - i)
            toks.append(Tok("block_comment", src[start:j], sl, sc, line))
        elif c in "rb" and _starts_string_like(src, i):
            j, end_ln = _scan_string_like(src, i)
            text = src[i:j]
            bump(j - i)
            toks.append(Tok("str" if not text.endswith("'") or '"' in text else "char", text, sl, sc, line))
        elif c == "'":
            kind, j = _scan_quote(src, i)
            text = src[i:j]
            bump(j - i)
            toks.append(Tok(kind, text, sl, sc, line))
        elif c == '"':
            j = _scan_plain_string(src, i)
            text = src[i:j]
            bump(j - i)
            toks.append(Tok("str", text, sl, sc, line))
        elif IDENT_START.match(c):
            j = i
            if src.startswith("r#", i) and i + 2 < n and IDENT_START.match(src[i + 2]):
                j = i + 2
            while j < n and IDENT_CONT.match(src[j]):
                j += 1
            toks.append(Tok("ident", src[i:j], sl, sc))
            bump(j - i)
        elif c.isdigit():
            j = i
            while j < n:
                if IDENT_CONT.match(src[j]):
                    j += 1
                elif (src[j] == "." and j + 1 < n and src[j + 1].isdigit()
                      and "." not in src[i:j]):
                    j += 1
                else:
                    break
            toks.append(Tok("num", src[i:j], sl, sc))
            bump(j - i)
        else:
            toks.append(Tok("punct", c, sl, sc))
            bump()
    return toks


def _starts_string_like(src, i):
    n = len(src)
    if src.startswith('r"', i):
        return True
    if src.startswith("r#", i):
        j = i + 1
        while j < n and src[j] == "#":
            j += 1
        return j < n and src[j] == '"'
    if src.startswith('b"', i) or src.startswith("b'", i):
        return True
    if src.startswith("br", i):
        return i + 2 < n and src[i + 2] in '"#'
    return False


def _scan_string_like(src, i):
    n = len(src)
    j = i
    raw = False
    while j < n and src[j] in "rb":
        raw = raw or src[j] == "r"
        j += 1
    if j < n and src[j] == "'":
        _, j = _scan_quote(src, j)
        return j, None
    if raw:
        hashes = 0
        while j < n and src[j] == "#":
            hashes += 1
            j += 1
        j += 1  # opening quote
        close = '"' + "#" * hashes
        k = src.find(close, j)
        j = n if k == -1 else k + len(close)
        return j, None
    return _scan_plain_string(src, j), None


def _scan_plain_string(src, i):
    n = len(src)
    j = i + 1
    while j < n:
        if src[j] == "\\":
            j += 2
        elif src[j] == '"':
            return j + 1
        else:
            j += 1
    return n


def _scan_quote(src, i):
    n = len(src)
    nxt = src[i + 1] if i + 1 < n else ""
    if nxt == "\\":
        is_char = True
    elif nxt and IDENT_START.match(nxt):
        j = i + 2
        while j < n and IDENT_CONT.match(src[j]):
            j += 1
        is_char = j < n and src[j] == "'"
    elif nxt:
        is_char = i + 2 < n and src[i + 2] == "'"
    else:
        is_char = False
    if is_char:
        j = i + 1
        while j < n:
            if src[j] == "\\":
                j += 2
            elif src[j] == "'":
                return "char", j + 1
            else:
                j += 1
        return "char", n
    j = i + 1
    while j < n and IDENT_CONT.match(src[j]):
        j += 1
    return "lifetime", j


# ------------------------------------------------------------ file ctx


class SourceFile:
    def __init__(self, path, src):
        self.path = path.replace(os.sep, "/")
        self.toks = lex(src)
        self.attrs = self._collect_attrs()
        self.attr_tok = [False] * len(self.toks)
        for a in self.attrs:
            for k in range(a["hash"], a["end"]):
                self.attr_tok[k] = True
        self.test_tok = self._mark_test_regions()
        self.code_lines = set()
        attr_cand = set()
        self.comments_by_line = {}
        for idx, t in enumerate(self.toks):
            if t.is_comment():
                for l in range(t.line, t.end_line + 1):
                    self.comments_by_line.setdefault(l, []).append(idx)
            elif self.attr_tok[idx]:
                for l in range(t.line, t.end_line + 1):
                    attr_cand.add(l)
            else:
                for l in range(t.line, t.end_line + 1):
                    self.code_lines.add(l)
        self.attr_lines = attr_cand - self.code_lines

    def _collect_attrs(self):
        toks, out, i = self.toks, [], 0
        while i < len(toks):
            if not toks[i].is_punct("#"):
                i += 1
                continue
            j = i + 1
            if j < len(toks) and toks[j].is_punct("!"):
                j += 1
            if j >= len(toks) or not toks[j].is_punct("["):
                i += 1
                continue
            depth, name, inner, k = 0, "", [], j
            while k < len(toks):
                t = toks[k]
                if t.is_punct("["):
                    depth += 1
                elif t.is_punct("]"):
                    depth -= 1
                    if depth == 0:
                        k += 1
                        break
                elif t.kind == "ident":
                    if not name:
                        name = t.text
                    inner.append(t.text)
                k += 1
            out.append({"hash": i, "end": k, "name": name, "inner": inner})
            i = k
        return out

    def _mark_test_regions(self):
        toks = self.toks
        test = [False] * len(toks)
        for a in self.attrs:
            if a["inner"] not in (["test"], ["cfg", "test"]):
                continue
            depth, k, body = 0, a["end"], None
            while k < len(toks):
                t = toks[k]
                if t.is_punct("(") or t.is_punct("["):
                    depth += 1
                elif t.is_punct(")") or t.is_punct("]"):
                    depth -= 1
                elif t.is_punct("{") and depth == 0:
                    body = k
                    break
                elif t.is_punct(";") and depth == 0:
                    break
                k += 1
            if body is None:
                continue
            braces, k = 0, body
            while k < len(toks):
                t = toks[k]
                if t.is_punct("{"):
                    braces += 1
                elif t.is_punct("}"):
                    braces -= 1
                test[k] = True
                if braces == 0:
                    break
                k += 1
        return test

    def path_ends_with(self, suffix):
        return self.path.endswith("/" + suffix) or self.path == suffix

    def in_tests_dir(self):
        return "tests" in self.path.split("/")

    def line_comment_matches(self, line, pred):
        return any(
            pred(self.toks[i]) for i in self.comments_by_line.get(line, [])
        )

    def block_above_matches(self, line, pred):
        l = line - 1
        while l >= 1:
            if l in self.comments_by_line and l not in self.code_lines:
                if self.line_comment_matches(l, pred):
                    return True
            elif l not in self.attr_lines:
                break
            l -= 1
        return False

    def has_adjacent_comment(self, site_idx, pred):
        site_line = self.toks[site_idx].line
        if (self.line_comment_matches(site_line, pred)
                or self.block_above_matches(site_line, pred)):
            return True
        anchor, k = None, site_idx
        while k > 0:
            k -= 1
            t = self.toks[k]
            if t.is_comment():
                if pred(t):
                    return True
                continue
            if t.is_punct(";") or t.is_punct("{") or t.is_punct("}"):
                break
            anchor = k
        if anchor is not None:
            a_line = self.toks[anchor].line
            if a_line != site_line and (
                    self.line_comment_matches(a_line, pred)
                    or self.block_above_matches(a_line, pred)):
                return True
        return False

    def diag(self, rule, tok, msg):
        return (self.path, tok.line, tok.col, rule, msg)


# --------------------------------------------------------------- rules

SAFETY = lambda t: "SAFETY:" in t.text or "# Safety" in t.text
ORDERING = lambda t: "ORDERING:" in t.text
ANY = lambda t: True


def unquote(s):
    return s.lstrip("br#").strip('"').rstrip("#").strip('"')


def lint_files(files):
    out = []
    for f in files:
        for i, t in enumerate(f.toks):
            if t.is_ident("unsafe") and not f.has_adjacent_comment(i, SAFETY):
                out.append(f.diag("L001", t,
                                  "unsafe without adjacent // SAFETY:"))
        if not (f.path_ends_with("util/metrics.rs") or f.in_tests_dir()):
            for i, t in enumerate(f.toks):
                if (t.is_ident("Relaxed") and not f.test_tok[i]
                        and not f.has_adjacent_comment(i, ORDERING)):
                    out.append(f.diag(
                        "L002", t,
                        "Ordering::Relaxed without adjacent // ORDERING:"))
        for a in f.attrs:
            if a["name"] != "allow":
                continue
            hash_tok = f.toks[a["hash"]]
            if not (f.line_comment_matches(hash_tok.line, ANY)
                    or f.block_above_matches(hash_tok.line, ANY)):
                out.append(f.diag("L003", hash_tok,
                                  "#[allow] without justification comment"))

    declared = None
    for f in files:
        if not f.path_ends_with("util/metrics.rs"):
            continue
        idx = next((i for i, t in enumerate(f.toks)
                    if t.is_ident("REGISTRY")), None)
        if idx is None:
            continue
        declared, depth = set(), 0
        for t in f.toks[idx:]:
            if t.text in "([{" and t.kind == "punct":
                depth += 1
            elif t.text in ")]}" and t.kind == "punct":
                depth -= 1
            elif t.is_punct(";") and depth == 0:
                break
            elif t.kind == "str":
                name = unquote(t.text)
                if name in declared:
                    out.append(f.diag("L004", t,
                                      f"metric {name!r} declared twice"))
                declared.add(name)
    if declared is not None:
        for f in files:
            toks = f.toks
            for i in range(len(toks) - 3):
                if (toks[i].is_punct(".")
                        and (toks[i + 1].is_ident("counter")
                             or toks[i + 1].is_ident("hist"))
                        and toks[i + 2].is_punct("(")
                        and toks[i + 3].kind == "str"):
                    name = unquote(toks[i + 3].text)
                    if name not in declared:
                        out.append(f.diag(
                            "L004", toks[i + 3],
                            f"metric {name!r} not declared in REGISTRY"))

    frame, variants = None, []
    for f in files:
        if not f.path_ends_with("service/frame.rs"):
            continue
        toks = f.toks
        start = None
        for i in range(len(toks) - 1):
            if toks[i].is_ident("enum"):
                nm = next((j for j in range(i + 1, len(toks))
                           if not toks[j].is_comment()), None)
                if nm is not None and toks[nm].is_ident("Frame"):
                    start = next((j for j in range(nm + 1, len(toks))
                                  if toks[j].is_punct("{")), None)
                    break
        if start is None:
            continue
        frame, variants = f, []
        braces, parens, expecting, k = 1, 0, True, start + 1
        while k < len(toks) and braces > 0:
            t = toks[k]
            if t.is_comment() or f.attr_tok[k]:
                k += 1
                continue
            if t.is_punct("{"):
                braces += 1
            elif t.is_punct("}"):
                braces -= 1
            elif t.is_punct("(") or t.is_punct("["):
                parens += 1
            elif t.is_punct(")") or t.is_punct("]"):
                parens -= 1
            elif braces == 1 and parens == 0:
                if t.is_punct(","):
                    expecting = True
                elif expecting and t.kind == "ident":
                    variants.append((t.text, k))
                    expecting = False
            k += 1
    if frame is not None:
        for backend in ("service/server.rs", "service/reactor.rs",
                        "service/uring.rs"):
            bf = next((f for f in files if f.path_ends_with(backend)), None)
            if bf is None:
                continue
            dispatched = set()
            toks = bf.toks
            for i in range(len(toks) - 3):
                if (toks[i].is_ident("Frame") and toks[i + 1].is_punct(":")
                        and toks[i + 2].is_punct(":")
                        and toks[i + 3].kind == "ident"):
                    dispatched.add(toks[i + 3].text)
            for name, idx in variants:
                if name not in dispatched:
                    out.append(frame.diag(
                        "L005", frame.toks[idx],
                        f"frame variant `{name}` not dispatched in {backend}"))

    return sorted(out)


SKIP_DIRS = {"target", ".git", "lint_fixtures"}


def collect(paths):
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in SKIP_DIRS)
            for nm in sorted(names):
                if nm.endswith(".rs"):
                    files.append(os.path.join(root, nm))
    return sorted(set(files))


def main(argv):
    paths = argv[1:] or [
        p for p in ("rust/src", "rust/tests", "rust/benches", "examples")
        if os.path.isdir(p)
    ]
    srcs = []
    for path in collect(paths):
        with open(path, encoding="utf-8") as fh:
            srcs.append(SourceFile(path, fh.read()))
    diags = lint_files(srcs)
    for path, line, col, rule, msg in diags:
        print(f"{path}:{line}:{col}: {rule} {msg}")
    print(f"lint_mirror: {len(srcs)} file(s), {len(diags)} diagnostic(s)")
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
