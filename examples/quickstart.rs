//! Quickstart: create the paper's K-CAS Robin Hood set, hammer it from
//! a few threads, and inspect its probe-distance profile.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use crh::maps::kcas_rh::KCasRobinHood;
use crh::maps::ConcurrentSet;

fn main() {
    // 2^16 buckets; keys are 62-bit integers (>= 1).
    let table = Arc::new(KCasRobinHood::new(16));

    // Concurrent writers on disjoint ranges.
    let mut handles = Vec::new();
    for tid in 0..4u64 {
        let table = table.clone();
        handles.push(std::thread::spawn(move || {
            let base = 1 + tid * 10_000;
            for k in base..base + 5_000 {
                table.add(k);
            }
            // Delete every third key again.
            for k in (base..base + 5_000).step_by(3) {
                table.remove(k);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    println!("entries: {}", table.len_quiesced());
    assert!(table.contains(2)); // 2 survives (not on the step_by(3) grid)
    table.check_invariant().expect("Robin Hood invariant");

    // Probe-distance profile (the reason Robin Hood reads are fast).
    let snap = table.dfb_snapshot();
    let occ: Vec<i32> = snap.into_iter().filter(|&d| d >= 0).collect();
    let mean = occ.iter().map(|&d| d as f64).sum::<f64>() / occ.len() as f64;
    let max = occ.iter().max().unwrap();
    println!(
        "mean DFB {mean:.3}, max DFB {max} at LF {:.2}",
        occ.len() as f64 / 65536.0
    );
    println!("quickstart OK");
}
