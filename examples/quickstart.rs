//! Quickstart: the paper's K-CAS Robin Hood table as a *set*, then as
//! a *map* with the conditional-first API — counters via `fetch_add`,
//! a lease via `compare_exchange`, memoisation via `get_or_insert` —
//! and finally the probe-distance profile that makes Robin Hood reads
//! fast.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use crh::maps::kcas_rh::KCasRobinHood;
use crh::maps::kcas_rh_map::KCasRobinHoodMap;
use crh::maps::{ConcurrentMap, ConcurrentSet};

fn main() {
    // ---- the set (what the paper benchmarks) ----
    // 2^16 buckets; keys are 62-bit integers (>= 1).
    let table = Arc::new(KCasRobinHood::new(16));

    // Concurrent writers on disjoint ranges.
    let mut handles = Vec::new();
    for tid in 0..4u64 {
        let table = table.clone();
        handles.push(std::thread::spawn(move || {
            let base = 1 + tid * 10_000;
            for k in base..base + 5_000 {
                table.add(k);
            }
            // Delete every third key again.
            for k in (base..base + 5_000).step_by(3) {
                table.remove(k);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    println!("set entries: {}", table.len_quiesced());
    assert!(table.contains(2)); // 2 survives (not on the step_by(3) grid)
    table.check_invariant().expect("Robin Hood invariant");

    // ---- the map, conditional-first ----
    // The same algorithm over (key, value) pair buckets. Beyond
    // get/insert/remove, the map natively provides atomic
    // read-modify-write ops — each a single K-CAS, no locks:
    let map = Arc::new(KCasRobinHoodMap::new(12));

    // Counters: eight threads hammer one hot key with `fetch_add`;
    // a missing key counts as 0, and no increment can be lost.
    let mut handles = Vec::new();
    for _ in 0..8 {
        let map = map.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..10_000 {
                map.fetch_add(1, 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(map.get(1), Some(80_000), "fetch_add lost an increment");
    println!("counter after 8x10k concurrent increments: {:?}", map.get(1));

    // Leases: `compare_exchange` corners subsume insert-if-absent and
    // remove-if-equal, so check-then-act needs no external lock.
    let me = 42u64;
    map.compare_exchange(2, None, Some(me)).expect("acquire free lease");
    assert_eq!(
        map.compare_exchange(2, None, Some(7)),
        Err(Some(me)),
        "second acquire must fail and witness the owner"
    );
    map.compare_exchange(2, Some(me), None).expect("owner releases");
    assert_eq!(map.compare_exchange(2, None, None), Ok(()), "lease free");

    // Memoisation: `get_or_insert` publishes the first computation and
    // never overwrites a winner.
    assert_eq!(map.get_or_insert(3, 333), None); // we inserted
    assert_eq!(map.get_or_insert(3, 999), Some(333)); // loser observes
    assert_eq!(map.get(3), Some(333));
    println!("lease + memoisation corners OK");
    map.check_invariant_quiesced().expect("map invariant");

    // ---- probe-distance profile (why Robin Hood reads are fast) ----
    let snap = table.dfb_snapshot();
    let occ: Vec<i32> = snap.into_iter().filter(|&d| d >= 0).collect();
    let mean = occ.iter().map(|&d| d as f64).sum::<f64>() / occ.len() as f64;
    let max = occ.iter().max().unwrap();
    println!(
        "mean DFB {mean:.3}, max DFB {max} at LF {:.2}",
        occ.len() as f64 / 65536.0
    );
    println!("quickstart OK");
}
