//! A key→value service over TCP — the service layer end-to-end: a
//! sharded K-CAS Robin Hood *map* behind the pipelined batch-frame
//! protocol (`crh::service::server`), driven by concurrent clients at
//! batch sizes {1, 8, 64}.
//!
//! Protocol (see `service::server` docs): `G k` / `P k v` / `D k`
//! single ops plus the conditional verbs `C k e n`
//! (compare-exchange, `-` = absent, replying `OK` or `!<witness>`),
//! `U k v` (get-or-insert) and `A k d` (fetch-add); `B n` multi-op
//! batch frames, `T n` all-or-nothing transaction frames, `Q` quit;
//! value-shaped replies are the value or `-`, and
//! malformed/out-of-range requests get `ERR <msg>` without killing
//! the connection (the old one-op-per-line server panicked its
//! connection thread on `k > MAX_KEY`).
//!
//! The guard-rail probes speak raw lines on purpose (they test the
//! codec's error paths); everything else goes through the typed
//! client — `MapOp` in, `MapReply` out ([`Client::batch_typed`],
//! [`Client::txn`]) — so the example doubles as typed-API
//! documentation.
//!
//! The example starts the server on an ephemeral port, checks the
//! protocol guard rails, then runs the same total op count per batch
//! size and prints throughput plus frame-latency percentiles. Batch
//! frames amortise both round trips and K-CAS descriptor setup, so
//! batch=64 must beat batch=1.
//!
//! ```sh
//! cargo run --release --example kv_service             # threaded backend
//! cargo run --release --example kv_service -- --backend reactor
//! cargo run --release --example kv_service -- --backend uring
//! ```
//!
//! `--backend {threads,reactor,uring}` serves the identical protocol
//! through the chosen front-end (`--reactor` is kept as an alias for
//! `--backend reactor`; `uring` transparently falls back to the epoll
//! reactor on kernels without io_uring); every assertion below must
//! hold on any backend.

use std::sync::Arc;
use std::time::Instant;

use crh::maps::{ConcurrentMap, MapKind, MapOp, MapReply, MAX_KEY};
use crh::service::server::Client;
use crh::service::Backend;
use crh::util::rng::Rng;

const KEY_SPACE: u64 = 10_000;
const CLIENTS: u64 = 4;
/// Total ops per client per batch size (divisible by every batch size).
const OPS_PER_CLIENT: usize = 12_800;

fn draw_op(r: &mut Rng) -> MapOp {
    let k = 1 + r.below(KEY_SPACE);
    match r.below(10) {
        0 => MapOp::Insert(k, k * 2 + 1),
        1 => MapOp::Remove(k),
        _ => MapOp::Get(k),
    }
}

/// One client connection: `OPS_PER_CLIENT / batch` frames of `batch`
/// ops each; returns per-frame latencies (ns).
fn client(addr: std::net::SocketAddr, tid: u64, batch: usize) -> Vec<u128> {
    let mut c = Client::connect(addr).expect("connect");
    let mut r = Rng::for_thread(0xCAFE ^ batch as u64, tid);
    let frames = OPS_PER_CLIENT / batch;
    let mut lat = Vec::with_capacity(frames);
    let mut ops = Vec::with_capacity(batch);
    for _ in 0..frames {
        ops.clear();
        ops.extend((0..batch).map(|_| draw_op(&mut r)));
        let t0 = Instant::now();
        let replies = c.batch(&ops).expect("batch round trip");
        lat.push(t0.elapsed().as_nanos());
        assert_eq!(replies.len(), batch);
    }
    lat
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let backend = if args.iter().any(|a| a == "--reactor") {
        Backend::Reactor // pre-matrix alias, kept for scripts
    } else {
        args.iter()
            .position(|a| a == "--backend")
            .and_then(|i| args.get(i + 1))
            .map(|s| {
                Backend::parse(s)
                    .unwrap_or_else(|| panic!("unknown backend {s}"))
            })
            .unwrap_or(Backend::Threads)
    };
    let kind = MapKind::parse("sharded-kcas-rh-map:4").unwrap();
    let map: Arc<dyn ConcurrentMap> = Arc::from(kind.build(16));
    let handle = backend
        .spawn(map.clone(), 0)
        .unwrap_or_else(|e| panic!("spawn {backend} server: {e}"));
    let addr = handle.addr();
    let mode = match backend {
        Backend::Threads => "thread-per-connection",
        Backend::Reactor => "epoll event loop",
        Backend::Uring => {
            if crh::service::uring::uring_frontend_available() {
                "io_uring completion rings"
            } else {
                "io_uring → epoll fallback (kernel lacks io_uring)"
            }
        }
    };
    println!("kv_service: {} on {addr} ({mode})", kind.display());

    // Protocol guard rails: an out-of-range key must be rejected at the
    // protocol boundary — and the connection must survive it.
    let mut probe = Client::connect(addr).expect("connect");
    let reply = probe.request_line(&format!("P {} 1", MAX_KEY + 1)).unwrap();
    assert_eq!(reply, "ERR key out of range", "guard rail: {reply}");
    assert_eq!(probe.request_line("G 0").unwrap(), "ERR key out of range");
    assert_eq!(probe.request_line("nonsense").unwrap(), "ERR bad request");
    assert_eq!(probe.request_line("P 7 700").unwrap(), "-");
    assert_eq!(probe.request_line("G 7").unwrap(), "700");
    assert_eq!(probe.request_line("D 7").unwrap(), "700");
    println!("guard rails OK (bad requests get ERR, connection survives)");

    // The conditional verbs: check-then-act without read-check-write
    // round trips or server-side locks — one wire op, one K-CAS.
    // Typed end to end: `MapOp` in, `MapReply` out, no reply-string
    // parsing. Lease: acquire / contended acquire (witnesses the
    // owner) / wrong-owner release / owner release.
    let lease = probe
        .batch_typed(&[
            MapOp::CmpEx(20, None, Some(1)),
            MapOp::CmpEx(20, None, Some(2)),
            MapOp::CmpEx(20, Some(2), None),
            MapOp::CmpEx(20, Some(1), None),
        ])
        .expect("lease batch");
    assert_eq!(
        lease,
        [
            MapReply::CmpEx(Ok(())),
            MapReply::CmpEx(Err(Some(1))),
            MapReply::CmpEx(Err(Some(1))),
            MapReply::CmpEx(Ok(())),
        ]
    );
    // Counter (fetch-add treats a missing key as 0) and memoisation
    // (get-or-insert never overwrites the winner).
    let cond = probe
        .batch_typed(&[
            MapOp::FetchAdd(21, 5),
            MapOp::FetchAdd(21, 5),
            MapOp::Get(21),
            MapOp::GetOrInsert(22, 7),
            MapOp::GetOrInsert(22, 8),
            MapOp::Remove(21),
            MapOp::Remove(22),
        ])
        .expect("conditional batch");
    assert_eq!(
        cond,
        [
            MapReply::Added(None),
            MapReply::Added(Some(5)),
            MapReply::Value(Some(10)),
            MapReply::Existing(None),
            MapReply::Existing(Some(7)),
            MapReply::Removed(Some(10)),
            MapReply::Removed(Some(7)),
        ]
    );
    println!("conditional verbs OK (C/U/A: lease, counter, memoise)");

    // Transactions: a `T <n>` frame commits its whole op set
    // atomically — one K-CAS spanning every touched key, even when
    // the keys land on different shards of the 4-way facade. A
    // debit+credit transfer either fully happens or not at all; no
    // interleaving ever observes money in flight.
    const M: u64 = 1 << 62; // fetch-add is mod 2^62: += M-x is -= x
    let seeded = probe
        .batch_typed(&[MapOp::Insert(30, 100), MapOp::Insert(31, 100)])
        .expect("seed accounts");
    assert_eq!(seeded, [MapReply::Prev(None), MapReply::Prev(None)]);
    let transfer = probe
        .txn(&[MapOp::FetchAdd(30, M - 25), MapOp::FetchAdd(31, 25)])
        .expect("transfer commits");
    assert_eq!(
        transfer,
        [MapReply::Added(Some(100)), MapReply::Added(Some(100))]
    );
    let audit = probe
        .txn(&[MapOp::Get(30), MapOp::Get(31)])
        .expect("atomic read pair");
    assert_eq!(
        audit,
        [MapReply::Value(Some(75)), MapReply::Value(Some(125))]
    );
    let cleanup = probe
        .batch_typed(&[MapOp::Remove(30), MapOp::Remove(31)])
        .expect("cleanup");
    assert_eq!(
        cleanup,
        [MapReply::Removed(Some(75)), MapReply::Removed(Some(125))]
    );
    println!("transactions OK (T: atomic cross-shard transfer + audit)");

    let mut results: Vec<(usize, f64)> = Vec::new();
    for batch in [1usize, 8, 64] {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..CLIENTS)
            .map(|tid| std::thread::spawn(move || client(addr, tid, batch)))
            .collect();
        let mut lat: Vec<u128> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        let dt = t0.elapsed().as_secs_f64();
        lat.sort_unstable();
        let total_ops = CLIENTS as usize * OPS_PER_CLIENT;
        let tput = total_ops as f64 / dt;
        let pct = |p: f64| {
            lat[(p * (lat.len() - 1) as f64) as usize] as f64 / 1000.0
        };
        println!(
            "batch={batch:<3} {total_ops} ops from {CLIENTS} clients in \
             {dt:.2}s ({tput:.0} ops/s); frame latency us: p50 {:.1}  \
             p90 {:.1}  p99 {:.1}",
            pct(0.50),
            pct(0.90),
            pct(0.99)
        );
        results.push((batch, tput));
    }

    let (b1, tp1) = results[0];
    let (bn, tpn) = *results.last().unwrap();
    assert!(
        tpn > tp1,
        "batch={bn} ({tpn:.0} ops/s) should beat batch={b1} ({tp1:.0} ops/s)"
    );
    println!(
        "batching speedup: batch={bn} is {:.1}x batch={b1}",
        tpn / tp1
    );
    println!("final map size: {}", map.len_quiesced());
    map.check_invariant_quiesced().expect("invariant");
    handle.shutdown(); // joins every server thread — no stragglers
    println!("kv_service OK");
}
