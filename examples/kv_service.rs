//! A tiny membership service over TCP — the "coordinator" shape of the
//! system: a Rust leader owning a K-CAS Robin Hood set, serving
//! line-oriented requests from concurrent clients with Python nowhere
//! in sight.
//!
//! Protocol (one request per line):
//!   `A <key>` add, `R <key>` remove, `C <key>` contains, `Q` quit.
//! Replies: `1` / `0` / `ERR <msg>`.
//!
//! The example starts the server on an ephemeral port, runs 8 client
//! connections driving mixed traffic, prints latency percentiles, and
//! exits.
//!
//! ```sh
//! cargo run --release --example kv_service
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use crh::maps::kcas_rh::KCasRobinHood;
use crh::maps::ConcurrentSet;
use crh::util::rng::Rng;

fn serve(listener: TcpListener, table: Arc<KCasRobinHood>) {
    for stream in listener.incoming() {
        let Ok(stream) = stream else { break };
        stream.set_nodelay(true).ok();
        let table = table.clone();
        std::thread::spawn(move || {
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            let mut line = String::new();
            loop {
                line.clear();
                if reader.read_line(&mut line).unwrap_or(0) == 0 {
                    return;
                }
                let mut it = line.split_whitespace();
                let reply = match (it.next(), it.next()) {
                    (Some("Q"), _) => return,
                    (Some(cmd), Some(k)) => match (cmd, k.parse::<u64>()) {
                        ("A", Ok(k)) if k >= 1 => (table.add(k) as u8).to_string(),
                        ("R", Ok(k)) if k >= 1 => {
                            (table.remove(k) as u8).to_string()
                        }
                        ("C", Ok(k)) if k >= 1 => {
                            (table.contains(k) as u8).to_string()
                        }
                        _ => "ERR bad key".to_string(),
                    },
                    _ => "ERR bad request".to_string(),
                };
                let _ = writeln!(out, "{reply}");
            }
        });
    }
}

fn client(addr: std::net::SocketAddr, tid: u64, n: usize) -> Vec<u128> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;
    let mut r = Rng::for_thread(0xCAFE, tid);
    let mut lat = Vec::with_capacity(n);
    let mut resp = String::new();
    for _ in 0..n {
        let k = 1 + r.below(10_000);
        let cmd = match r.below(10) {
            0 => format!("A {k}"),
            1 => format!("R {k}"),
            _ => format!("C {k}"),
        };
        let t0 = Instant::now();
        writeln!(out, "{cmd}").unwrap();
        resp.clear();
        reader.read_line(&mut resp).unwrap();
        lat.push(t0.elapsed().as_nanos());
        assert!(
            resp.starts_with('0') || resp.starts_with('1'),
            "bad reply {resp:?}"
        );
    }
    writeln!(out, "Q").unwrap();
    lat
}

fn main() {
    let table = Arc::new(KCasRobinHood::new(16));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let table = table.clone();
        std::thread::spawn(move || serve(listener, table));
    }

    let clients = 8;
    let per = 5_000;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for tid in 0..clients {
        handles.push(std::thread::spawn(move || client(addr, tid, per)));
    }
    let mut lat: Vec<u128> =
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let dt = t0.elapsed().as_secs_f64();
    lat.sort_unstable();
    let pct = |p: f64| lat[(p * (lat.len() - 1) as f64) as usize] as f64 / 1000.0;
    println!(
        "kv_service: {} reqs from {clients} clients in {dt:.2}s \
         ({:.0} req/s)",
        lat.len(),
        lat.len() as f64 / dt
    );
    println!(
        "latency us: p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        pct(1.0)
    );
    println!("final table size: {}", table.len_quiesced());
    table.check_invariant().expect("invariant");
    println!("kv_service OK");
}
