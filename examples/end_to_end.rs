//! End-to-end driver — proves all three layers compose on a real
//! workload:
//!
//! 1. load the AOT artifacts (L1 Pallas hash kernel fused into the L2
//!    JAX pipeline) through the PJRT runtime,
//! 2. pre-hash the benchmark key stream through the artifact and verify
//!    bit-exact agreement with the Rust hot-path hash,
//! 3. run the paper's headline experiment — throughput scaling of all
//!    six concurrent tables (K-CAS Robin Hood on top) at 60% load
//!    factor / light updates,
//! 4. feed the resulting Robin Hood table snapshot back through the L2
//!    probe-statistics graph and report the probe-length distribution.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use crh::bench::{driver, workload, WorkloadCfg};
use crh::maps::{ConcurrentSet, TableKind};
use crh::runtime::Engine;
use crh::util::error::{Error, Result};
use crh::util::hash::splitmix64;

fn main() -> Result<()> {
    // ---- Layer 1+2: artifacts through the runtime engine ----
    let engine = Engine::load_default().map_err(|e| {
        Error::msg(format!("{e}\nhint: run `make artifacts` first"))
    })?;
    println!(
        "[1/4] runtime engine up on `{}` (hash batch {}, table 2^{})",
        engine.platform(),
        engine.manifest.hash_batch,
        engine.manifest.size_log2
    );

    // ---- pre-hash the workload key stream via the AOT pipeline ----
    let n_keys = 200_000usize;
    let keys: Vec<i64> = (1..=n_keys as i64).collect();
    let hashes = engine.hash_stream(&keys)?;
    let mut mismatches = 0;
    for (i, &k) in keys.iter().enumerate() {
        if hashes[i] as u64 != splitmix64(k as u64) {
            mismatches += 1;
        }
    }
    assert_eq!(mismatches, 0, "Pallas/JAX/Rust hash disagreement");
    println!(
        "[2/4] pre-hashed {n_keys} keys via the Pallas kernel; \
         0 mismatches vs the Rust hot path"
    );

    // ---- the paper's headline benchmark (light mix) ----
    let cfg =
        WorkloadCfg::cell(20, 0.6, crh::bench::Mix::LIGHT.update_pct, 500, 0xE2E);
    let max = crh::util::affinity::available_cpus();
    let mut threads: Vec<usize> = vec![1, 2, 4];
    if max > 4 {
        threads.push(max);
    }
    threads.dedup();
    println!(
        "[3/4] throughput scaling, 2^{} buckets, LF 60%, 10% updates \
         (ops/us):",
        cfg.size_log2
    );
    print!("{:<18}", "threads");
    for &t in &threads {
        print!(" {t:>8}");
    }
    println!();
    let mut kcas_best = 0.0f64;
    for kind in TableKind::ALL_CONCURRENT {
        print!("{:<18}", kind.display());
        for &t in &threads {
            let r = driver::run(kind, &cfg, t, true);
            let v = r.ops_per_us();
            if kind == TableKind::KCasRobinHood {
                kcas_best = kcas_best.max(v);
            }
            print!(" {v:>8.2}");
        }
        println!();
    }
    assert!(kcas_best > 0.0);

    // ---- L2 analytics over the real table state ----
    let table = TableKind::KCasRobinHood.build(cfg.size_log2);
    workload::prefill(table.as_ref(), &cfg);
    let stats = engine.probe_stats(&table.dfb_snapshot())?;
    println!(
        "[4/4] probe stats via AOT graph: {} entries, mean DFB {:.3}, \
         var {:.3}, max {}",
        stats.count, stats.mean, stats.var, stats.max
    );
    let mass: i64 = stats.hist.iter().take(4).sum();
    println!(
        "      {:.1}% of entries within 3 buckets of home \
         (Robin Hood's low expected probe length)",
        100.0 * mass as f64 / stats.count as f64
    );
    println!("end_to_end OK");
    Ok(())
}
