//! Concurrent de-duplication — the classic hash-set workload: N threads
//! stream tokens from a synthetic corpus (Zipf-ish repetition, like
//! words in text) and insert them into one shared set; the set's size
//! is the distinct-token count.
//!
//! Compares the paper's K-CAS Robin Hood against Michael's chained
//! table on the same stream.
//!
//! ```sh
//! cargo run --release --example dedup
//! ```

use std::sync::Arc;
use std::time::Instant;

use crh::maps::{ConcurrentSet, TableKind};
use crh::util::hash::splitmix64;
use crh::util::rng::Rng;

/// Zipf-ish token stream: token ids drawn with density ~ 1/rank.
fn token(r: &mut Rng, vocab: u64) -> u64 {
    let u = r.f64().max(1e-12);
    let rank = (vocab as f64).powf(u) as u64;
    1 + splitmix64(rank) % (1 << 40) // spread ids over a wide key space
}

fn run(kind: TableKind, threads: u64, tokens_per_thread: u64) -> (usize, f64) {
    let table: Arc<dyn ConcurrentSet> = Arc::from(kind.build(20));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for tid in 0..threads {
        let table = table.clone();
        handles.push(std::thread::spawn(move || {
            let mut r = Rng::for_thread(0xD00D, tid);
            let mut new = 0u64;
            for _ in 0..tokens_per_thread {
                if table.add(token(&mut r, 200_000)) {
                    new += 1;
                }
            }
            new
        }));
    }
    let new_total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed().as_secs_f64();
    let distinct = table.len_quiesced();
    assert_eq!(distinct as u64, new_total, "dedup miscount");
    (distinct, dt)
}

fn main() {
    let threads = 4;
    let per = 500_000;
    println!("# dedup: {threads} threads x {per} tokens");
    for kind in [TableKind::KCasRobinHood, TableKind::Michael] {
        let (distinct, dt) = run(kind, threads, per);
        println!(
            "{:<18} {distinct:>8} distinct tokens in {dt:.3}s \
             ({:.2} Mtokens/s)",
            kind.display(),
            threads as f64 * per as f64 / dt / 1e6
        );
    }
    println!("dedup OK");
}
