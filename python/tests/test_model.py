"""L2 correctness: hash_pipeline and probe_stats vs numpy references."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


class TestHashPipeline:
    def test_buckets_in_range(self):
        keys = jnp.arange(model.HASH_BATCH, dtype=jnp.int64)
        hashes, buckets = model.hash_pipeline(keys, size_log2=10)
        b = np.asarray(buckets)
        assert b.min() >= 0 and b.max() < (1 << 10)
        np.testing.assert_array_equal(
            np.asarray(hashes), ref.splitmix64_np(np.asarray(keys)))

    def test_bucket_is_hash_mask(self):
        keys = jnp.asarray(
            np.random.default_rng(3).integers(0, 1 << 62, 8192, dtype=np.int64))
        hashes, buckets = model.hash_pipeline(keys, size_log2=23)
        h = np.asarray(hashes).view(np.uint64)
        np.testing.assert_array_equal(
            np.asarray(buckets).view(np.uint64), h & np.uint64((1 << 23) - 1))

    def test_different_size_log2_changes_mask(self):
        keys = jnp.arange(1024, dtype=jnp.int64)
        _, b8 = model.hash_pipeline(keys, size_log2=8)
        _, b16 = model.hash_pipeline(keys, size_log2=16)
        m = np.asarray(b16) & ((1 << 8) - 1)
        np.testing.assert_array_equal(np.asarray(b8), m)


class TestProbeStats:
    def _check(self, dfb):
        dfb = np.asarray(dfb, dtype=np.int32)
        hist, count, mean, var, maxd = model.probe_stats(jnp.asarray(dfb))
        ehist, ecount, emean, evar, emax = ref.probe_stats_np(dfb, model.MAX_DFB)
        np.testing.assert_array_equal(np.asarray(hist), ehist)
        assert int(count) == ecount
        if ecount:
            assert abs(float(mean) - emean) < 1e-9
            assert abs(float(var) - evar) < 1e-6
            assert int(maxd) == emax

    def test_empty_table(self):
        self._check(np.full(256, -1))

    def test_all_home(self):
        self._check(np.zeros(256))

    def test_mixed(self):
        rng = np.random.default_rng(11)
        dfb = rng.integers(-1, 12, 4096).astype(np.int32)
        self._check(dfb)

    def test_outliers_clamp_to_last_bin(self):
        dfb = np.array([0, 1, 200, model.MAX_DFB, model.MAX_DFB + 1], np.int32)
        hist, count, _, _, maxd = model.probe_stats(jnp.asarray(dfb))
        assert int(np.asarray(hist)[model.MAX_DFB]) == 3  # 200, 64, 65
        assert int(count) == 5
        assert int(maxd) == 200

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1),
           size=st.sampled_from([64, 1000, 4096]),
           hi=st.integers(0, 100))
    def test_hypothesis_random_snapshots(self, seed, size, hi):
        rng = np.random.default_rng(seed)
        self._check(rng.integers(-1, hi + 1, size).astype(np.int32))


class TestRobinHoodTheory:
    def test_expected_probe_length_low_at_high_lf(self):
        """Simulate serial Robin Hood in numpy and check Celis' claim:
        mean successful probe distance stays small even at LF 0.8."""
        size = 1 << 14
        n = int(size * 0.8)
        keys = np.arange(1, n + 1, dtype=np.int64)
        h = ref.splitmix64_np(keys).view(np.uint64)
        home = (h & np.uint64(size - 1)).astype(np.int64)
        table = np.full(size, -1, dtype=np.int64)  # stores home bucket
        for hb in home:
            cur, d = int(hb), 0
            while True:
                i = (cur + 0) % size
                if table[i] == -1:
                    table[i] = int(hb) if d == 0 else (i - d) % size
                    break
                occ_d = (i - table[i]) % size
                if occ_d < d:
                    old = table[i]
                    table[i] = (i - d) % size
                    d = occ_d
                    hb = old  # continue displacing the evicted entry
                cur = (cur + 1) % size
                d += 1
        occ = table >= 0
        dfb = np.where(occ, (np.arange(size) - table) % size, -1).astype(np.int32)
        _, count, mean, _, _ = ref.probe_stats_np(dfb)
        assert count == n
        # Celis: ~2.6 expected probes for successful search; DFB mean ~1.6.
        assert float(mean) < 4.0, f"mean DFB {mean} too high"
