"""L1 correctness: Pallas hashmix kernel vs pure-jnp / numpy oracles.

Hypothesis sweeps shapes and adversarial bit patterns; every case must be
bit-exact (the Rust hot path re-implements this mixer and the table's
correctness depends on agreement).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hashmix
from compile.kernels.hashmix import splitmix64, GAMMA, MIX1, MIX2
from compile.kernels import ref

I64 = np.iinfo(np.int64)


def _mix_np(keys):
    return ref.splitmix64_np(np.asarray(keys, dtype=np.int64))


class TestKernelVsRef:
    def test_small_batch_exact(self):
        keys = jnp.arange(hashmix.DEFAULT_BLOCK, dtype=jnp.int64)
        out = hashmix.hashmix(keys)
        expect = ref.splitmix64_ref(keys)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))

    def test_multi_block_grid(self):
        keys = jnp.arange(4 * hashmix.DEFAULT_BLOCK, dtype=jnp.int64) * 7919
        out = hashmix.hashmix(keys)
        np.testing.assert_array_equal(
            np.asarray(out), _mix_np(np.asarray(keys)))

    def test_jnp_ref_matches_numpy_ref(self):
        keys = np.array([0, 1, -1, I64.min, I64.max, 1 << 40], dtype=np.int64)
        np.testing.assert_array_equal(
            np.asarray(ref.splitmix64_ref(jnp.asarray(keys))), _mix_np(keys))

    def test_custom_block_size(self):
        keys = jnp.arange(2048, dtype=jnp.int64)
        out = hashmix.hashmix(keys, block=256)
        np.testing.assert_array_equal(np.asarray(out), _mix_np(np.asarray(keys)))

    def test_indivisible_batch_raises(self):
        keys = jnp.arange(1000, dtype=jnp.int64)
        with pytest.raises(ValueError, match="not divisible"):
            hashmix.hashmix(keys, block=256)

    @settings(max_examples=30, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=8),
        block=st.sampled_from([8, 64, 256, 1024]),
        seed=st.integers(min_value=0, max_value=2**32 - 1),
    )
    def test_hypothesis_shapes_and_values(self, blocks, block, seed):
        rng = np.random.default_rng(seed)
        keys = rng.integers(I64.min, I64.max, blocks * block, dtype=np.int64)
        out = hashmix.hashmix(jnp.asarray(keys), block=block)
        np.testing.assert_array_equal(np.asarray(out), _mix_np(keys))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(min_value=I64.min, max_value=I64.max),
                    min_size=8, max_size=8))
    def test_hypothesis_adversarial_values(self, vals):
        keys = np.array(vals, dtype=np.int64)
        out = hashmix.hashmix(jnp.asarray(keys), block=8)
        np.testing.assert_array_equal(np.asarray(out), _mix_np(keys))


class TestMixerProperties:
    def test_known_vector(self):
        # splitmix64(0) first output — cross-checked with the published
        # reference implementation (Vigna): 0xE220A8397B1DCDAF.
        out = _mix_np(np.array([0], dtype=np.int64))
        assert np.uint64(out.view(np.uint64)[0]) == np.uint64(0xE220A8397B1DCDAF)

    def test_bijective_on_sample(self):
        # Mixer is a bijection: no collisions on any sample.
        keys = np.arange(1 << 16, dtype=np.int64)
        out = _mix_np(keys)
        assert len(np.unique(out)) == len(keys)

    def test_avalanche_quality(self):
        # Flipping one input bit flips ~32 output bits on average.
        rng = np.random.default_rng(7)
        keys = rng.integers(I64.min, I64.max, 512, dtype=np.int64)
        flipped = keys ^ np.int64(1 << 17)
        d = _mix_np(keys).view(np.uint64) ^ _mix_np(flipped).view(np.uint64)
        popcnt = np.unpackbits(d.view(np.uint8)).sum() / len(keys)
        assert 24 < popcnt < 40, f"poor avalanche: {popcnt}"

    def test_bucket_uniformity(self):
        # Home buckets should be near-uniform: chi-square sanity bound.
        keys = np.arange(1 << 16, dtype=np.int64)
        h = _mix_np(keys).view(np.uint64)
        buckets = (h & np.uint64(255)).astype(np.int64)
        counts = np.bincount(buckets, minlength=256)
        expect = len(keys) / 256
        chi2 = float(((counts - expect) ** 2 / expect).sum())
        # 255 dof, mean 255, sd ~22.6 — 400 is a generous 6-sigma bound.
        assert chi2 < 400, f"chi2={chi2}"

    def test_constants_match_published_splitmix64(self):
        assert GAMMA == 0x9E3779B97F4A7C15
        assert MIX1 == 0xBF58476D1CE4E5B9
        assert MIX2 == 0x94D049BB133111EB

    def test_splitmix_uint_path(self):
        z = splitmix64(jnp.asarray([np.uint64(0)], dtype=jnp.uint64))
        assert int(z[0]) == 0xE220A8397B1DCDAF
