"""AOT lowering tests: the artifacts must be valid HLO text with the
expected entry signatures, and the golden vectors must match the oracle."""

import numpy as np

from compile import aot, model
from compile.kernels import ref


class TestLowering:
    def test_hash_pipeline_lowers_to_hlo_text(self):
        text = aot.lower_hash_pipeline(size_log2=23)
        assert text.startswith("HloModule")
        assert f"s64[{model.HASH_BATCH}]" in text
        # Mixer multiply constants must survive lowering (fused, not DCE'd).
        assert "multiply" in text

    def test_probe_stats_lowers_to_hlo_text(self):
        text = aot.lower_probe_stats()
        assert text.startswith("HloModule")
        assert f"s32[{model.STATS_BATCH}]" in text

    def test_root_is_tuple(self):
        # return_tuple=True: rust unwraps with to_tupleN.
        text = aot.lower_hash_pipeline(size_log2=23)
        root = [l for l in text.splitlines() if "ROOT" in l]
        assert root and "tuple" in root[-1].split("=")[1]

    def test_size_log2_is_baked_in(self):
        t10 = aot.lower_hash_pipeline(size_log2=10)
        t23 = aot.lower_hash_pipeline(size_log2=23)
        assert t10 != t23


class TestGoldenVectors:
    def test_golden_matches_numpy_ref(self):
        text = aot.golden_vectors(64)
        lines = [l.split() for l in text.strip().splitlines()]
        keys = np.array([int(k) for k, _ in lines], dtype=np.int64)
        hashes = np.array([int(h) for _, h in lines], dtype=np.int64)
        np.testing.assert_array_equal(ref.splitmix64_np(keys), hashes)

    def test_golden_contains_edge_keys(self):
        text = aot.golden_vectors(16)
        keys = [int(l.split()[0]) for l in text.strip().splitlines()]
        for edge in (0, 1, -1, (1 << 62) - 1):
            assert edge in keys
