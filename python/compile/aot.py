"""AOT lowering: jitted L2 functions -> HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` / serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the HLO text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Emits:
  hash_pipeline.hlo.txt   int64[HASH_BATCH] keys -> (hashes, buckets)
  probe_stats.hlo.txt     int32[STATS_BATCH] dfb -> (hist, count, mean, var, max)
  golden_hash.txt         "key hash" lines for the Rust cross-check test
  MANIFEST.txt            shapes + parameters the Rust runtime asserts on
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_hash_pipeline(size_log2: int) -> str:
    spec = jax.ShapeDtypeStruct((model.HASH_BATCH,), jnp.int64)
    lowered = jax.jit(
        model.hash_pipeline, static_argnames=("size_log2",)
    ).lower(spec, size_log2=size_log2)
    return to_hlo_text(lowered)


def lower_probe_stats() -> str:
    spec = jax.ShapeDtypeStruct((model.STATS_BATCH,), jnp.int32)
    lowered = jax.jit(model.probe_stats).lower(spec)
    return to_hlo_text(lowered)


def golden_vectors(n: int = 256) -> str:
    """Deterministic key/hash pairs for the Rust bit-exactness test."""
    rng = np.random.default_rng(0xC0FFEE)
    keys = rng.integers(np.iinfo(np.int64).min, np.iinfo(np.int64).max, n, dtype=np.int64)
    keys[:8] = [0, 1, 2, -1, 7, 1 << 40, (1 << 62) - 1, 42]
    hashes = ref.splitmix64_np(keys)
    return "".join(f"{int(k)} {int(h)}\n" for k, h in zip(keys, hashes))


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--size-log2", type=int, default=23,
                   help="table size exponent baked into the bucket mask")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    emitted = {}
    emitted["hash_pipeline.hlo.txt"] = lower_hash_pipeline(args.size_log2)
    emitted["probe_stats.hlo.txt"] = lower_probe_stats()
    emitted["golden_hash.txt"] = golden_vectors()
    emitted["MANIFEST.txt"] = (
        f"hash_batch {model.HASH_BATCH}\n"
        f"stats_batch {model.STATS_BATCH}\n"
        f"max_dfb {model.MAX_DFB}\n"
        f"size_log2 {args.size_log2}\n"
    )
    for name, text in emitted.items():
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")


if __name__ == "__main__":
    main()
