"""Build-time-only package: L1 Pallas kernels, L2 JAX model, AOT lowering.

Nothing in here runs on the request path — `make artifacts` lowers the
jitted functions to HLO text once, and the Rust coordinator loads the
artifacts via PJRT.

x64 must be enabled before any jnp op traces: the hash pipeline is
genuine 64-bit integer arithmetic.
"""

import jax

jax.config.update("jax_enable_x64", True)
