"""Pure-jnp / numpy oracles for the L1 kernel and L2 analytics.

Everything here is straight-line jnp or numpy with no Pallas — the
correctness ground truth that pytest compares the kernel and the AOT
artifacts against.
"""

import jax.numpy as jnp
import numpy as np
from jax import lax

from .hashmix import GAMMA, MIX1, MIX2


def splitmix64_ref(keys: jnp.ndarray) -> jnp.ndarray:
    """Reference SplitMix64 on int64[N] via plain jnp ops (no pallas)."""
    z = lax.bitcast_convert_type(keys, jnp.uint64)
    z = z + jnp.uint64(GAMMA)
    z = (z ^ (z >> jnp.uint64(30))) * jnp.uint64(MIX1)
    z = (z ^ (z >> jnp.uint64(27))) * jnp.uint64(MIX2)
    z = z ^ (z >> jnp.uint64(31))
    return lax.bitcast_convert_type(z, jnp.int64)


def splitmix64_np(keys: np.ndarray) -> np.ndarray:
    """Numpy-only reference (independent of JAX entirely)."""
    z = keys.astype(np.int64).view(np.uint64)
    with np.errstate(over="ignore"):
        z = z + np.uint64(GAMMA)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(MIX1)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(MIX2)
        z = z ^ (z >> np.uint64(31))
    return z.view(np.int64)


def probe_stats_np(dfb: np.ndarray, max_dfb: int = 64):
    """Numpy reference for the L2 probe-distance analytics.

    dfb: int32[M], distance-from-home-bucket per bucket, -1 for empty.
    Returns (hist[max_dfb+1], count, mean, var, max) where hist[max_dfb]
    accumulates clamped outliers.
    """
    occ = dfb[dfb >= 0].astype(np.int64)
    clamped = np.minimum(occ, max_dfb)
    hist = np.bincount(clamped, minlength=max_dfb + 1).astype(np.int64)
    count = int(occ.size)
    if count == 0:
        return hist, 0, 0.0, 0.0, 0
    mean = float(occ.mean())
    var = float(occ.var())
    return hist, count, mean, var, int(occ.max())
