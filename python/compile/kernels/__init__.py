"""L1 Pallas kernels + pure-jnp oracles."""

from . import hashmix, ref  # noqa: F401
from .hashmix import GAMMA, MIX1, MIX2, splitmix64  # noqa: F401
