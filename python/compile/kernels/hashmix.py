"""L1 — Pallas kernel: batched SplitMix64 hash mixer.

This is the compute hot-spot of the paper's system: every hash-table
operation begins by mixing the key into a uniformly distributed 64-bit
hash (the paper's ``hash(key)`` in Figs. 7-9).  The benchmark harness
pre-hashes entire key streams in batches through this kernel (AOT-lowered
to HLO and executed from Rust via PJRT); the Rust hot path implements the
bit-identical mixer in ``rust/src/util/hash.rs``.

The mixer is the SplitMix64 finalizer (Steele et al., "Fast splittable
pseudorandom number generators"): an add of the golden-gamma constant
followed by three xor-shift-multiply rounds.  It is bijective on u64,
passes avalanche tests, and is what Rust's stdlib-era Robin Hood table
used via FxHash-class mixers.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): this is a
pure element-wise integer kernel — VPU work, no MXU.  We tile the key
batch into VMEM-sized blocks with BlockSpec (BLOCK x u64 = 8 KiB per
operand at the default BLOCK=1024); each element is read and written
exactly once, so the kernel sits on the HBM-bandwidth roofline by
construction.  ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# SplitMix64 constants.
GAMMA = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB

DEFAULT_BLOCK = 1024


def _u64(c: int) -> jnp.ndarray:
    return jnp.uint64(c)


def splitmix64(z: jnp.ndarray) -> jnp.ndarray:
    """One SplitMix64 step on a uint64 array (gamma add + finalizer)."""
    z = z + _u64(GAMMA)
    z = (z ^ (z >> _u64(30))) * _u64(MIX1)
    z = (z ^ (z >> _u64(27))) * _u64(MIX2)
    return z ^ (z >> _u64(31))


def _hashmix_kernel(keys_ref, out_ref):
    """Pallas body: mix one VMEM block of keys.

    Keys arrive as int64 (JAX's interchange-friendly signed type, and what
    the Rust literal API speaks); we bitcast to uint64 for the modular
    arithmetic and bitcast back.
    """
    k = lax.bitcast_convert_type(keys_ref[...], jnp.uint64)
    h = splitmix64(k)
    out_ref[...] = lax.bitcast_convert_type(h, jnp.int64)


@functools.partial(jax.jit, static_argnames=("block",))
def hashmix(keys: jnp.ndarray, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Batched SplitMix64 over an int64[N] key array (N % block == 0)."""
    n = keys.shape[0]
    if n % block != 0:
        raise ValueError(f"batch {n} not divisible by block {block}")
    grid = (n // block,)
    return pl.pallas_call(
        _hashmix_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int64),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(keys)
