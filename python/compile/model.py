"""L2 — JAX compute graph for the hash pipeline and probe analytics.

Two jitted entry points, both AOT-lowered to HLO text by ``aot.py`` and
executed from the Rust coordinator via PJRT:

* ``hash_pipeline``  — key batch -> (mixed hash, home bucket).  Calls the
  L1 Pallas kernel (``kernels.hashmix``); the bucket masking fuses into
  the same HLO module so Rust gets both outputs from one execution.
* ``probe_stats``    — a table snapshot's DFB (distance-from-home-bucket)
  array -> histogram / count / mean / variance / max.  Used by the
  harness to report the Robin Hood probe-length distribution the paper's
  §2.2 analysis relies on (expected ~2.6 probes successful search).

Shapes are static per artifact (PJRT executables are shape-specialised);
the Rust runtime chunks larger streams through the fixed batch.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.hashmix import hashmix

# Default artifact shapes (must match aot.py and the Rust runtime).
HASH_BATCH = 65536
STATS_BATCH = 65536
MAX_DFB = 64


@functools.partial(jax.jit, static_argnames=("size_log2",))
def hash_pipeline(keys: jnp.ndarray, size_log2: int = 23):
    """Mix a batch of int64 keys and derive their home buckets.

    Returns ``(hashes int64[N], buckets int64[N])`` with
    ``bucket = hash & (2**size_log2 - 1)`` — identical to
    ``rust/src/util/hash.rs::home_bucket``.
    """
    hashes = hashmix(keys)
    mask = jnp.int64((1 << size_log2) - 1)
    buckets = hashes & mask
    return hashes, buckets


@jax.jit
def probe_stats(dfb: jnp.ndarray):
    """Probe-distance analytics over a table snapshot.

    dfb: int32[M]; -1 marks an empty bucket.  Returns
    ``(hist int64[MAX_DFB+1], count int64, mean f64, var f64, max int32)``.
    Out-of-range distances accumulate in the last histogram bin.
    """
    occ = dfb >= 0
    count = jnp.sum(occ.astype(jnp.int64))
    # Route empties to a scratch bin past the histogram, then drop it.
    clamped = jnp.minimum(dfb, MAX_DFB)
    binned = jnp.where(occ, clamped, MAX_DFB + 1)
    hist = jnp.bincount(binned, length=MAX_DFB + 2)[: MAX_DFB + 1]
    d = jnp.where(occ, dfb, 0).astype(jnp.float64)
    denom = jnp.maximum(count, 1).astype(jnp.float64)
    mean = jnp.sum(d) / denom
    var = jnp.sum(jnp.where(occ, (d - mean) ** 2, 0.0)) / denom
    maxd = jnp.max(jnp.where(occ, dfb, -1))
    return hist.astype(jnp.int64), count, mean, var, maxd
