//! Resizable K-CAS Robin Hood — the paper's §4.3 future work.
//!
//! "An area we don't deal with is resize, specifically, when to resize
//! the table and how to do it." This module supplies the simplest
//! correct answer as an extension: an epoch-style wrapper where normal
//! operations share a read lock (full concurrency — the inner table's
//! own K-CAS protocol provides thread safety) and a grow migration
//! takes the write lock, quiescing the table while it rebuilds at twice
//! the size. Growth triggers automatically when the approximate load
//! factor crosses `grow_at` (default 0.85, past the paper's 80%
//! evaluation ceiling, so benchmark workloads never pay for it).
//!
//! This is deliberately a *blocking* resize: the paper notes no
//! formally published generic lock-free resize exists; a non-blocking
//! migration (Maier-style busy-bit tables or [33]'s split-ordered
//! lists) is out of scope and orthogonal to the Robin Hood contribution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

use super::kcas_rh::KCasRobinHood;
use super::ConcurrentSet;
use crate::util::hash::splitmix64;

pub struct ResizableRobinHood {
    inner: RwLock<KCasRobinHood>,
    /// Approximate element count (relaxed; only steers the grow trigger).
    approx_len: AtomicUsize,
    grow_at: f64,
}

impl ResizableRobinHood {
    pub fn new(size_log2: u32) -> Self {
        Self::with_threshold(size_log2, 0.85)
    }

    pub fn with_threshold(size_log2: u32, grow_at: f64) -> Self {
        assert!((0.1..1.0).contains(&grow_at));
        Self {
            inner: RwLock::new(KCasRobinHood::new(size_log2)),
            approx_len: AtomicUsize::new(0),
            grow_at,
        }
    }

    /// Grow to twice the current size, migrating all keys. Blocks until
    /// in-flight operations drain (write lock).
    pub fn grow(&self) {
        let mut guard = self.inner.write().unwrap();
        let old = &*guard;
        let new_log2 = old.capacity().trailing_zeros() + 1;
        let next = KCasRobinHood::new(new_log2);
        let mut moved = 0usize;
        for (i, d) in old.dfb_snapshot().into_iter().enumerate() {
            if d >= 0 {
                // Quiesced: snapshot indexes are stable under the write
                // lock; re-read the key via the public API.
                let key = old.key_at(i).expect("occupied bucket vanished");
                next.add(key);
                moved += 1;
            }
        }
        self.approx_len.store(moved, Ordering::Relaxed);
        *guard = next;
    }

    fn maybe_grow(&self) {
        let guard = self.inner.read().unwrap();
        let cap = guard.capacity();
        drop(guard);
        if self.approx_len.load(Ordering::Relaxed) as f64
            >= self.grow_at * cap as f64
        {
            self.grow();
        }
    }
}

impl ConcurrentSet for ResizableRobinHood {
    // The plain entry points route through the hashed twins (like the
    // inner table itself) so the grow-trigger accounting exists once.

    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    /// Hash forwarding is grow-safe: `h` is the full 64-bit hash and
    /// each generation of the inner table masks it down itself.
    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        self.inner.read().unwrap().contains_hashed(h, key)
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        let added = self.inner.read().unwrap().add_hashed(h, key);
        if added
            && self.approx_len.fetch_add(1, Ordering::Relaxed) + 1
                >= (self.grow_at * self.inner.read().unwrap().capacity() as f64)
                    as usize
        {
            self.maybe_grow();
        }
        added
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        let removed = self.inner.read().unwrap().remove_hashed(h, key);
        if removed {
            self.approx_len.fetch_sub(1, Ordering::Relaxed);
        }
        removed
    }

    fn name(&self) -> &'static str {
        "resizable-rh"
    }

    fn capacity(&self) -> usize {
        self.inner.read().unwrap().capacity()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        self.inner.read().unwrap().dfb_snapshot()
    }

    fn len_quiesced(&self) -> usize {
        self.inner.read().unwrap().len_quiesced()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn grows_past_initial_capacity() {
        let t = ResizableRobinHood::with_threshold(6, 0.75); // 64 buckets
        for k in 1..=400u64 {
            assert!(t.add(k), "add {k}");
        }
        assert!(t.capacity() >= 512, "capacity {}", t.capacity());
        for k in 1..=400u64 {
            assert!(t.contains(k), "lost {k} across migrations");
        }
        assert_eq!(t.len_quiesced(), 400);
    }

    #[test]
    fn explicit_grow_preserves_membership() {
        let t = ResizableRobinHood::new(8);
        for k in 1..=100u64 {
            t.add(k);
        }
        let before = t.capacity();
        t.grow();
        assert_eq!(t.capacity(), before * 2);
        for k in 1..=100u64 {
            assert!(t.contains(k));
        }
    }

    #[test]
    fn concurrent_adds_through_growth() {
        let t = Arc::new(ResizableRobinHood::with_threshold(7, 0.7));
        let mut hs = Vec::new();
        for tid in 0..6u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 10_000;
                for k in base..base + 500 {
                    assert!(t.add(k));
                    assert!(t.contains(k), "read-your-write across grow");
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.len_quiesced(), 3000);
        assert!(t.capacity() >= 4096);
        for tid in 0..6u64 {
            let base = 1 + tid * 10_000;
            for k in base..base + 500 {
                assert!(t.contains(k));
            }
        }
    }

    #[test]
    fn removes_update_trigger_accounting() {
        let t = ResizableRobinHood::with_threshold(6, 0.9);
        for round in 0..20 {
            for k in 1..=40u64 {
                t.add(k + round * 100);
            }
            for k in 1..=40u64 {
                t.remove(k + round * 100);
            }
        }
        // Churn with balanced add/remove shouldn't force runaway growth.
        assert!(t.capacity() <= 1024, "capacity {}", t.capacity());
        assert_eq!(t.len_quiesced(), 0);
    }
}
