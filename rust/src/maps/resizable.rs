//! Resizable K-CAS Robin Hood — the paper's §4.3 future work, solved
//! two ways.
//!
//! "An area we don't deal with is resize, specifically, when to resize
//! the table and how to do it." This module answers with **two
//! engines** over the same trigger policy (grow when the approximate
//! load factor crosses `grow_at`, default 0.85):
//!
//! * [`IncResizableRobinHood`] / [`ResizableRobinHoodMap`] — the
//!   primary engine: **non-blocking cooperative two-generation
//!   migration**. A grow installs a double-size successor table with a
//!   single pointer store; from then on every operation first helps
//!   migrate one fixed stripe of old-generation buckets (Maier-style
//!   cooperative helping, "Concurrent Hash Tables: Fast and
//!   General(?)!"), and the old/new generation pair composes with open
//!   addressing exactly as in Gao, Groote & Hesselink's lock-free
//!   dynamic hash tables. Buckets are frozen for migration with
//!   K-CAS-visible marks in the bucket word itself
//!   (`kcas_rh::FROZEN_TOMB` / `FROZEN_EMPTY`, reserved encodings above
//!   `MAX_KEY`): a live key is transferred to the next generation and
//!   tombstoned in **one K-CAS**, so no key is ever observable in zero
//!   or two generations. Writers that target a migrating region freeze
//!   the key's whole home run (moving it and its neighbours) and then
//!   operate on the new generation; reads probe old → new. No
//!   operation ever waits for the whole migration — the old stop-shard
//!   pause is gone.
//!
//! * [`QuiescingResize`] — the previous blocking engine, kept as the
//!   comparable baseline (and as the conservative choice): an epoch
//!   RwLock where normal operations share a read lock and a grow takes
//!   the write lock, quiescing the table while it rebuilds at twice
//!   the size. The `fig15_resize` experiment measures exactly this
//!   difference: per-op tail latency *during* an in-flight migration,
//!   incremental vs quiescing.
//!
//! ## Memory of retired generations
//!
//! Completed source generations cannot be freed while concurrent
//! readers may still hold references into them, and this crate is
//! dependency-free (no epoch/hazard reclamation). Retired generations
//! are therefore owned by the wrapper and released when it drops; the
//! total retained memory is a geometric series bounded by ~1x the
//! current table (each retired generation is half the next one's size).
//!
//! ## Progress
//!
//! Migration inherits the K-CAS's progress: stripe transfers and
//! home-run freezes are lock-free phase-1 installs with helping, and
//! per-bucket freezing is idempotent, so any thread can complete any
//! stripe. The only mutex in the incremental engine guards migration
//! *installation* (a rare, O(1) pointer publication — normal
//! operations never touch it).

use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};

use super::kcas_rh::{KCasRobinHood, Probe};
use super::kcas_rh_map::{KCasRobinHoodMap, ProbeVal};
use super::txn;
use super::{
    ConcurrentMap, ConcurrentSet, MapError, MapOp, MapReply, TxnError,
};
use crate::util::hash::splitmix64;
use crate::util::metrics::metrics;

/// Buckets migrated per helping step: every operation that runs while a
/// migration is active first drains one stripe of this size from the
/// old generation. 64 buckets matches the minimum timestamp-shard width
/// and keeps the per-op helping tax small and bounded.
pub const STRIPE: usize = 64;

/// A table that can act as one generation of a two-generation resize.
pub(crate) trait Generation: Send + Sync + 'static {
    fn new_gen(size_log2: u32) -> Self;
    fn capacity(&self) -> usize;
    /// Freeze `[start, start+len)` of `self`, draining live entries
    /// into `target`; idempotent and race-safe.
    fn migrate_range(&self, target: &Self, start: usize, len: usize) -> usize;
}

impl Generation for KCasRobinHood {
    fn new_gen(size_log2: u32) -> Self {
        KCasRobinHood::new(size_log2)
    }
    fn capacity(&self) -> usize {
        ConcurrentSet::capacity(self)
    }
    fn migrate_range(&self, target: &Self, start: usize, len: usize) -> usize {
        KCasRobinHood::migrate_range(self, target, start, len)
    }
}

impl Generation for KCasRobinHoodMap {
    fn new_gen(size_log2: u32) -> Self {
        KCasRobinHoodMap::new(size_log2)
    }
    fn capacity(&self) -> usize {
        ConcurrentMap::capacity(self)
    }
    fn migrate_range(&self, target: &Self, start: usize, len: usize) -> usize {
        KCasRobinHoodMap::migrate_range(self, target, start, len)
    }
}

/// One generation: the table plus the migration bookkeeping for the
/// migration *into* it (a generation is migrated into at most once).
struct Gen<T> {
    table: T,
    /// The generation this one drains (null for the genesis table).
    src: *const Gen<T>,
    /// Next stripe of `src` to claim (indexes stripes, not buckets).
    cursor: AtomicUsize,
    /// Stripes fully drained; the helper that completes the last stripe
    /// promotes this generation to current.
    done: AtomicUsize,
    /// Install time; promotion reports `born.elapsed()` as the
    /// migration's wall time (telemetry only).
    born: std::time::Instant,
}

// SAFETY: `src` is only ever read (never through a mutable alias) and
// points into a Box owned by the wrapper's generation list, which
// outlives every reference handed out.
unsafe impl<T: Send + Sync> Send for Gen<T> {}
// SAFETY: as for Send — all fields are themselves Sync (atomics plus a
// Sync table) and `src` is immutable after construction.
unsafe impl<T: Send + Sync> Sync for Gen<T> {}

/// The shared two-generation core: `current`/`migration` pointer pair,
/// cooperative stripe helping, the grow trigger, and the append-only
/// generation list that owns every table.
pub(crate) struct TwoGen<T> {
    current: AtomicPtr<Gen<T>>,
    /// Target generation of the in-flight migration; null when none.
    migration: AtomicPtr<Gen<T>>,
    /// Owns all generations ever created (see module docs on memory);
    /// locked only to install a migration — never on the op path.
    gens: Mutex<Vec<Box<Gen<T>>>>,
    /// Approximate element count (relaxed; only steers the trigger).
    approx_len: AtomicUsize,
    grow_at: f64,
}

// SAFETY: the raw generation pointers always point into the Boxes held
// by `gens`, which live until the wrapper drops.
unsafe impl<T: Send + Sync> Send for TwoGen<T> {}
// SAFETY: as for Send — shared access goes through atomics, the `gens`
// mutex, and &T methods of a Sync table.
unsafe impl<T: Send + Sync> Sync for TwoGen<T> {}

impl<T: Generation> TwoGen<T> {
    fn new(size_log2: u32, grow_at: f64) -> Self {
        assert!((0.1..1.0).contains(&grow_at));
        let genesis = Box::new(Gen {
            table: T::new_gen(size_log2),
            src: ptr::null(),
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            born: std::time::Instant::now(),
        });
        let cur = &*genesis as *const Gen<T> as *mut Gen<T>;
        TwoGen {
            current: AtomicPtr::new(cur),
            migration: AtomicPtr::new(ptr::null_mut()),
            gens: Mutex::new(vec![genesis]),
            approx_len: AtomicUsize::new(0),
            grow_at,
        }
    }

    /// The current generation's table. The reference is valid for the
    /// wrapper's lifetime (generations are never freed before drop).
    fn current(&self) -> &T {
        // SAFETY: `current` always points into a Box held by `gens`,
        // which is freed only when the wrapper drops.
        unsafe { &(*self.current.load(Ordering::Acquire)).table }
    }

    fn capacity(&self) -> usize {
        self.current().capacity()
    }

    fn migration_active(&self) -> bool {
        !self.migration.load(Ordering::Acquire).is_null()
    }

    /// Number of generations created so far (1 = never grown).
    fn generations(&self) -> usize {
        self.gens.lock().unwrap().len()
    }

    /// Run one operation against the engine. `fast` executes against
    /// the current generation when no migration is active; `slow`
    /// executes against `(source, target)` during one — after this core
    /// has helped drain one stripe. Either closure returns
    /// `Err(MapError::Frozen)` to signal "re-read the generation
    /// pointers and retry" (a migration started, completed, or a
    /// chained one began); no other error variant reaches this loop.
    fn run_op<R>(
        &self,
        mut fast: impl FnMut(&T) -> Result<R, MapError>,
        mut slow: impl FnMut(&T, &T) -> Result<R, MapError>,
    ) -> R {
        loop {
            let mig = self.migration.load(Ordering::Acquire);
            if mig.is_null() {
                match fast(self.current()) {
                    Ok(r) => return r,
                    Err(MapError::Frozen) => {
                        metrics().freeze_encounters.incr();
                        continue;
                    }
                    Err(e) => unreachable!("resize engine error: {e}"),
                }
            }
            // SAFETY: a non-null migration pointer targets a Box held
            // by `gens`, alive for the wrapper's lifetime.
            let mig = unsafe { &*mig };
            self.help(mig);
            // SAFETY: a migration target's `src` is the non-null
            // generation it drains, owned by `gens` as well.
            let src = unsafe { &(*mig.src).table };
            match slow(src, &mig.table) {
                Ok(r) => return r,
                Err(MapError::Frozen) => {
                    metrics().freeze_encounters.incr();
                    continue;
                }
                Err(e) => unreachable!("resize engine error: {e}"),
            }
        }
    }

    /// Claim and drain one stripe of `mig`'s source (cooperative
    /// helping). The helper that drains the last stripe promotes the
    /// target generation to current and clears the migration pointer —
    /// in that order, so every interleaving sees a serviceable state.
    fn help(&self, mig: &Gen<T>) {
        // SAFETY: `help` is only called with an installed migration
        // target, whose `src` points at the Box-owned source generation.
        let src = unsafe { &(*mig.src).table };
        let nstripes = src.capacity().div_ceil(STRIPE);
        // ORDERING: the cursor is a pure work-claim ticket; the stripe
        // data it hands out is synchronised by the K-CAS protocol
        // inside migrate_range, not by this counter.
        let s = mig.cursor.fetch_add(1, Ordering::Relaxed);
        if s >= nstripes {
            return; // all stripes claimed; stragglers finish them
        }
        let moved = src.migrate_range(&mig.table, s * STRIPE, STRIPE);
        metrics().resize_stripes_drained.incr();
        metrics().resize_keys_migrated.add(moved as u64);
        if mig.done.fetch_add(1, Ordering::AcqRel) + 1 == nstripes {
            let mig_ptr = mig as *const Gen<T> as *mut Gen<T>;
            self.current.store(mig_ptr, Ordering::Release);
            // ORDERING: Relaxed failure ordering — a lost race means a
            // chained grow already replaced the pointer; the observed
            // value is discarded either way.
            let _ = self.migration.compare_exchange(
                mig_ptr,
                ptr::null_mut(),
                Ordering::AcqRel,
                Ordering::Relaxed,
            );
            metrics().resize_generations.incr();
            metrics()
                .resize_wall_ns
                .add(mig.born.elapsed().as_nanos() as u64);
        }
    }

    /// Drive any in-flight migration to completion (helping until the
    /// migration pointer clears). Used by the quiesced accessors
    /// (`len_quiesced`, snapshots, invariant checks) and tests.
    fn finish_migration(&self) {
        loop {
            let mig = self.migration.load(Ordering::Acquire);
            if mig.is_null() {
                return;
            }
            // SAFETY: non-null migration pointer → Box held by `gens`.
            self.help(unsafe { &*mig });
            std::hint::spin_loop();
        }
    }

    /// Successful-insert accounting + grow trigger.
    fn note_add(&self) {
        // ORDERING: approximate accounting that only steers the grow
        // trigger; no other memory is published through the counter
        // and an off-by-a-few count merely shifts when a grow starts.
        let len = self.approx_len.fetch_add(1, Ordering::Relaxed).saturating_add(1);
        if self.migration.load(Ordering::Acquire).is_null()
            && len as f64 >= self.grow_at * self.capacity() as f64
        {
            self.start_grow();
        }
    }

    /// Saturating decrement: the counter is approximate (an op's table
    /// commit and its accounting are not atomic), so a remove racing an
    /// add's not-yet-counted insert must not wrap below zero — a
    /// wrapped counter would read as "huge" and trigger spurious grows.
    fn note_remove(&self) {
        // ORDERING: same approximate trigger accounting as note_add —
        // Relaxed for both the update and the failure re-read.
        let _ = self.approx_len.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(1)),
        );
    }

    /// Install a migration into a double-size generation. The mutex
    /// serialises installers only; the load factor is re-checked under
    /// it so N threads crossing the threshold together install one
    /// migration, not N.
    fn start_grow(&self) {
        let mut gens = self.gens.lock().unwrap();
        if !self.migration.load(Ordering::Acquire).is_null() {
            return;
        }
        let cur_ptr = self.current.load(Ordering::Acquire);
        // SAFETY: `current` points into a Box held by `gens` (locked
        // right now), freed only when the wrapper drops.
        let cap = unsafe { &(*cur_ptr).table }.capacity();
        // ORDERING: trigger recheck off the approximate count; the
        // mutex already serialises installers.
        if (self.approx_len.load(Ordering::Relaxed) as f64)
            < self.grow_at * cap as f64
        {
            return;
        }
        let target = Box::new(Gen {
            table: T::new_gen(cap.trailing_zeros() + 1),
            src: cur_ptr,
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            born: std::time::Instant::now(),
        });
        let target_ptr = &*target as *const Gen<T> as *mut Gen<T>;
        gens.push(target);
        self.migration.store(target_ptr, Ordering::Release);
    }
}

impl TwoGen<KCasRobinHoodMap> {
    /// Resolve the generation a transaction should plan `h`'s key
    /// against. With no migration active that is the current table.
    /// During one, help drain a stripe and then freeze the key's whole
    /// home run out of the source — exactly the single-op slow path
    /// (`cmpex_mig` etc.) — after which the target generation alone is
    /// authoritative for the key, so the commit descriptor's entries
    /// target it. Re-invoked by the transaction driver on every
    /// attempt, so generation turnover between attempts re-resolves.
    fn txn_table(&self, h: u64) -> &KCasRobinHoodMap {
        let mig = self.migration.load(Ordering::Acquire);
        if mig.is_null() {
            return self.current();
        }
        // SAFETY: a non-null migration pointer targets a Box held by
        // `gens`, alive for the wrapper's lifetime.
        let mig = unsafe { &*mig };
        self.help(mig);
        // SAFETY: a migration target's `src` is the non-null
        // generation it drains, owned by `gens` as well.
        let src = unsafe { &(*mig.src).table };
        src.migrate_home_run(&mig.table, h);
        &mig.table
    }
}

/// Post-commit grow-trigger accounting for one transactional (op,
/// reply) pair — the same membership deltas the single-op paths record.
fn txn_note(core: &TwoGen<KCasRobinHoodMap>, op: &MapOp, reply: &MapReply) {
    match (op, reply) {
        (MapOp::Insert(..), MapReply::Prev(None))
        | (MapOp::GetOrInsert(..), MapReply::Existing(None))
        | (MapOp::FetchAdd(..), MapReply::Added(None))
        | (MapOp::CmpEx(_, None, Some(_)), MapReply::CmpEx(Ok(()))) => {
            core.note_add()
        }
        (MapOp::Remove(..), MapReply::Removed(Some(_)))
        | (MapOp::CmpEx(_, Some(_), None), MapReply::CmpEx(Ok(()))) => {
            core.note_remove()
        }
        _ => {}
    }
}

/// Non-blocking growable K-CAS Robin Hood **set**: the two-generation
/// cooperative-migration engine (see module docs). CLI spec:
/// `inc-resize-rh` (`inc-resize-rh:N` for the sharded composition).
pub struct IncResizableRobinHood {
    core: TwoGen<KCasRobinHood>,
}

impl IncResizableRobinHood {
    pub fn new(size_log2: u32) -> Self {
        Self::with_threshold(size_log2, 0.85)
    }

    pub fn with_threshold(size_log2: u32, grow_at: f64) -> Self {
        IncResizableRobinHood { core: TwoGen::new(size_log2, grow_at) }
    }

    /// Is a migration currently in flight? (Diagnostics/tests: the
    /// non-blocking property is "operations complete while this is
    /// true".)
    pub fn migration_active(&self) -> bool {
        self.core.migration_active()
    }

    /// Generations created so far (1 = never grown).
    pub fn generations(&self) -> usize {
        self.core.generations()
    }

    /// Drive any in-flight migration to completion.
    pub fn finish_migration(&self) {
        self.core.finish_migration();
    }

    /// Robin Hood invariant of the current generation (quiesced only;
    /// finishes any in-flight migration first).
    pub fn check_invariant(&self) -> Result<(), String> {
        self.core.finish_migration();
        self.core.current().check_invariant()
    }
}

impl ConcurrentSet for IncResizableRobinHood {
    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    /// Reads fall through old -> new: a live hit in the source is
    /// definitive (transfers are atomic, so a key is never in two
    /// generations); a miss that crossed frozen buckets re-probes the
    /// target. A clean miss needs no second probe at all — the key's
    /// home run was untouched by migration, so no writer can have
    /// moved it yet.
    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        self.core.run_op(
            |cur| match cur.probe_mig(h, key) {
                Probe::Found => Ok(true),
                Probe::Absent => Ok(false),
                Probe::FrozenMiss => Err(MapError::Frozen),
            },
            |src, tgt| match src.probe_mig(h, key) {
                Probe::Found => Ok(true),
                // Clean miss in the source: the key's home run was
                // never frozen, so no writer can have moved or added
                // it to the target — definitive, no second probe.
                Probe::Absent => Ok(false),
                Probe::FrozenMiss => match tgt.probe_mig(h, key) {
                    Probe::Found => Ok(true),
                    Probe::Absent => Ok(false),
                    // A chained migration began freezing the
                    // target: re-read the generation pointers.
                    Probe::FrozenMiss => Err(MapError::Frozen),
                },
            },
        )
    }

    /// Writers during migration freeze the key's whole home run in the
    /// source (transferring it and its run neighbours), then operate on
    /// the target — the key can never re-enter the frozen run, so the
    /// target alone is authoritative afterwards.
    fn add_hashed(&self, h: u64, key: u64) -> bool {
        let added = self.core.run_op(
            |cur| cur.add_mig(h, key).map_err(MapError::from),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.add_mig(h, key).map_err(MapError::from)
            },
        );
        if added {
            self.core.note_add();
        }
        added
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        let removed = self.core.run_op(
            |cur| cur.remove_mig(h, key).map_err(MapError::from),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.remove_mig(h, key).map_err(MapError::from)
            },
        );
        if removed {
            self.core.note_remove();
        }
        removed
    }

    fn name(&self) -> &'static str {
        "inc-resize-rh"
    }

    fn capacity(&self) -> usize {
        self.core.capacity()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        self.core.finish_migration();
        self.core.current().dfb_snapshot()
    }

    fn len_quiesced(&self) -> usize {
        self.core.finish_migration();
        self.core.current().len_quiesced()
    }
}

/// Non-blocking growable K-CAS Robin Hood **map**: the same
/// two-generation engine over [`KCasRobinHoodMap`] — the map/service
/// layer's first growable table. CLI spec: `inc-resize-rh-map[:N]`.
///
/// Naming note: despite the similar names, this is **not** the map
/// twin of [`ResizableRobinHood`] — that alias names the *quiescing*
/// set engine ([`QuiescingResize`]); this map uses the *incremental*
/// engine, like [`IncResizableRobinHood`]. There is no quiescing map.
pub struct ResizableRobinHoodMap {
    core: TwoGen<KCasRobinHoodMap>,
}

impl ResizableRobinHoodMap {
    pub fn new(size_log2: u32) -> Self {
        Self::with_threshold(size_log2, 0.85)
    }

    pub fn with_threshold(size_log2: u32, grow_at: f64) -> Self {
        ResizableRobinHoodMap { core: TwoGen::new(size_log2, grow_at) }
    }

    /// Is a migration currently in flight?
    pub fn migration_active(&self) -> bool {
        self.core.migration_active()
    }

    /// Generations created so far (1 = never grown).
    pub fn generations(&self) -> usize {
        self.core.generations()
    }

    /// Drive any in-flight migration to completion.
    pub fn finish_migration(&self) {
        self.core.finish_migration();
    }
}

impl ConcurrentMap for ResizableRobinHoodMap {
    fn get(&self, key: u64) -> Option<u64> {
        self.get_hashed(splitmix64(key), key)
    }

    fn insert(&self, key: u64, value: u64) -> Option<u64> {
        self.insert_hashed(splitmix64(key), key, value)
    }

    fn remove(&self, key: u64) -> Option<u64> {
        self.remove_hashed(splitmix64(key), key)
    }

    fn compare_exchange(
        &self,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        self.compare_exchange_hashed(splitmix64(key), key, expected, new)
    }

    fn get_or_insert(&self, key: u64, value: u64) -> Option<u64> {
        self.get_or_insert_hashed(splitmix64(key), key, value)
    }

    fn fetch_add(&self, key: u64, delta: u64) -> Option<u64> {
        self.fetch_add_hashed(splitmix64(key), key, delta)
    }

    fn get_hashed(&self, h: u64, key: u64) -> Option<u64> {
        self.core.run_op(
            |cur| match cur.get_mig(h, key) {
                ProbeVal::Found(v) => Ok(Some(v)),
                ProbeVal::Absent => Ok(None),
                ProbeVal::FrozenMiss => Err(MapError::Frozen),
            },
            |src, tgt| match src.get_mig(h, key) {
                ProbeVal::Found(v) => Ok(Some(v)),
                // Clean miss in the source is definitive (see the set
                // twin): the key's home run was never frozen.
                ProbeVal::Absent => Ok(None),
                ProbeVal::FrozenMiss => match tgt.get_mig(h, key) {
                    ProbeVal::Found(v) => Ok(Some(v)),
                    ProbeVal::Absent => Ok(None),
                    ProbeVal::FrozenMiss => Err(MapError::Frozen),
                },
            },
        )
    }

    fn insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        let prev = self.core.run_op(
            |cur| cur.insert_mig(h, key, value),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.insert_mig(h, key, value)
            },
        );
        if prev.is_none() {
            self.core.note_add();
        }
        prev
    }

    fn remove_hashed(&self, h: u64, key: u64) -> Option<u64> {
        let prev = self.core.run_op(
            |cur| cur.remove_mig(h, key),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.remove_mig(h, key)
            },
        );
        if prev.is_some() {
            self.core.note_remove();
        }
        prev
    }

    // Conditional ops forward like the unconditional writes: freeze the
    // key's home run in the source generation, then run the native
    // single-K-CAS op against the target — the conditional semantics
    // need no extra machinery because after the freeze the target alone
    // is authoritative for the key, and the inner op is atomic there.

    fn compare_exchange_hashed(
        &self,
        h: u64,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        let r = self.core.run_op(
            |cur| cur.cmpex_mig(h, key, expected, new),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.cmpex_mig(h, key, expected, new)
            },
        );
        if r.is_ok() {
            // Only the membership-changing corners move the trigger.
            match (expected, new) {
                (None, Some(_)) => self.core.note_add(),
                (Some(_), None) => self.core.note_remove(),
                _ => {}
            }
        }
        r
    }

    fn get_or_insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        let prev = self.core.run_op(
            |cur| cur.get_or_insert_mig(h, key, value),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.get_or_insert_mig(h, key, value)
            },
        );
        if prev.is_none() {
            self.core.note_add();
        }
        prev
    }

    fn fetch_add_hashed(&self, h: u64, key: u64, delta: u64) -> Option<u64> {
        let prev = self.core.run_op(
            |cur| cur.fetch_add_mig(h, key, delta),
            |src, tgt| {
                src.migrate_home_run(tgt, h);
                tgt.fetch_add_mig(h, key, delta)
            },
        );
        if prev.is_none() {
            self.core.note_add();
        }
        prev
    }

    /// Transactions re-resolve the live generation for every key on
    /// every attempt (see [`TwoGen::txn_table`]): mid-migration, each
    /// txn key's home run is frozen out of the source first, so all of
    /// the commit descriptor's entries land in live tables — possibly
    /// spanning both generations for *different* keys, which the
    /// address-keyed descriptor handles like any other cross-table
    /// span.
    fn apply_txn(&self, ops: &[MapOp]) -> Result<Vec<MapReply>, TxnError> {
        let replies = txn::commit_kcas(ops, &mut |h| self.core.txn_table(h))?;
        for (op, reply) in ops.iter().zip(&replies) {
            txn_note(&self.core, op, reply);
        }
        Ok(replies)
    }

    fn name(&self) -> &'static str {
        "inc-resize-rh-map"
    }

    fn capacity(&self) -> usize {
        self.core.capacity()
    }

    fn len_quiesced(&self) -> usize {
        self.core.finish_migration();
        self.core.current().len_quiesced()
    }

    fn check_invariant_quiesced(&self) -> Result<(), String> {
        self.core.finish_migration();
        self.core.current().check_invariant()
    }
}

impl txn::TxnBackend for ResizableRobinHoodMap {
    fn apply_txn_routed(
        shards: &[Self],
        route: &dyn Fn(u64) -> usize,
        ops: &[MapOp],
    ) -> Result<Vec<MapReply>, TxnError> {
        let replies = txn::commit_kcas(ops, &mut |h| {
            shards[route(h)].core.txn_table(h)
        })?;
        for (op, reply) in ops.iter().zip(&replies) {
            let shard = &shards[route(splitmix64(op.key()))];
            txn_note(&shard.core, op, reply);
        }
        Ok(replies)
    }
}

/// The previous blocking engine, kept as the comparable baseline: an
/// epoch RwLock where normal operations share a read lock (full
/// concurrency — the inner table's K-CAS protocol provides thread
/// safety) and a grow takes the write lock, quiescing the table while
/// it rebuilds at twice the size. CLI spec: `resizable-rh`.
pub struct QuiescingResize {
    inner: RwLock<KCasRobinHood>,
    /// Approximate element count (relaxed; only steers the grow trigger).
    approx_len: AtomicUsize,
    /// Capacity cache so the add hot path never takes a second read
    /// lock just to evaluate the trigger; refreshed under the write
    /// lock at grow time.
    cap_cache: AtomicUsize,
    grow_at: f64,
}

/// Former name of [`QuiescingResize`], kept for spec/source
/// compatibility (`resizable-rh`, `sharded-resizable-rh:N`).
pub type ResizableRobinHood = QuiescingResize;

impl QuiescingResize {
    pub fn new(size_log2: u32) -> Self {
        Self::with_threshold(size_log2, 0.85)
    }

    pub fn with_threshold(size_log2: u32, grow_at: f64) -> Self {
        assert!((0.1..1.0).contains(&grow_at));
        Self {
            inner: RwLock::new(KCasRobinHood::new(size_log2)),
            approx_len: AtomicUsize::new(0),
            cap_cache: AtomicUsize::new(1 << size_log2),
            grow_at,
        }
    }

    /// Grow to twice the current size, migrating all keys. Blocks until
    /// in-flight operations drain (write lock). Unconditional — callers
    /// wanting the trigger semantics go through the internal rechecked
    /// path.
    pub fn grow(&self) {
        let mut guard = self.inner.write().unwrap();
        self.grow_locked(&mut guard);
    }

    fn grow_locked(&self, guard: &mut KCasRobinHood) {
        let t0 = std::time::Instant::now();
        let old = &*guard;
        let new_log2 = old.capacity().trailing_zeros() + 1;
        let next = KCasRobinHood::new(new_log2);
        let mut moved = 0usize;
        for (i, d) in old.dfb_snapshot().into_iter().enumerate() {
            if d >= 0 {
                // Quiesced: snapshot indexes are stable under the write
                // lock; re-read the key via the public API.
                let key = old.key_at(i).expect("occupied bucket vanished");
                next.add(key);
                moved += 1;
            }
        }
        // ORDERING: approximate trigger input, rebuilt under the write
        // lock whose release publishes it.
        self.approx_len.store(moved, Ordering::Relaxed);
        // ORDERING: as above — the capacity cache is re-read under the
        // write lock before any grow decision is acted on.
        self.cap_cache.store(next.capacity(), Ordering::Relaxed);
        *guard = next;
        metrics().resize_keys_migrated.add(moved as u64);
        metrics().resize_generations.incr();
        metrics().resize_wall_ns.add(t0.elapsed().as_nanos() as u64);
    }

    fn maybe_grow(&self) {
        let mut guard = self.inner.write().unwrap();
        // Recheck under the write lock: N threads crossing the
        // threshold together must grow once, not double N times.
        // ORDERING: approximate count; the write lock serialises the
        // actual decision.
        if (self.approx_len.load(Ordering::Relaxed) as f64)
            < self.grow_at * guard.capacity() as f64
        {
            return;
        }
        self.grow_locked(&mut guard);
    }
}

impl ConcurrentSet for QuiescingResize {
    // The plain entry points route through the hashed twins (like the
    // inner table itself) so the grow-trigger accounting exists once.

    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    /// Hash forwarding is grow-safe: `h` is the full 64-bit hash and
    /// each generation of the inner table masks it down itself.
    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        self.inner.read().unwrap().contains_hashed(h, key)
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        let added = self.inner.read().unwrap().add_hashed(h, key);
        if added {
            // Trigger off the cached capacity: no second read-lock
            // acquisition on the hot path.
            // ORDERING: approximate trigger accounting; nothing is
            // published through the counter.
            let len =
                self.approx_len.fetch_add(1, Ordering::Relaxed).saturating_add(1);
            // ORDERING: the cache may lag a concurrent grow by one
            // evaluation — worst case one spurious maybe_grow, which
            // re-reads authoritatively under the write lock.
            let cap = self.cap_cache.load(Ordering::Relaxed);
            if len as f64 >= self.grow_at * cap as f64 {
                self.maybe_grow();
            }
        }
        added
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        let removed = self.inner.read().unwrap().remove_hashed(h, key);
        if removed {
            // Saturating: a remove can race an add whose accounting
            // hasn't landed yet; wrapping below zero would read as
            // "huge" and force a spurious grow.
            // ORDERING: approximate trigger accounting — Relaxed for
            // both the update and the failure re-read.
            let _ = self.approx_len.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |v| Some(v.saturating_sub(1)),
            );
        }
        removed
    }

    fn name(&self) -> &'static str {
        "resizable-rh"
    }

    fn capacity(&self) -> usize {
        self.inner.read().unwrap().capacity()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        self.inner.read().unwrap().dfb_snapshot()
    }

    fn len_quiesced(&self) -> usize {
        self.inner.read().unwrap().len_quiesced()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn grows_past_initial_capacity() {
        let t = QuiescingResize::with_threshold(6, 0.75); // 64 buckets
        for k in 1..=400u64 {
            assert!(t.add(k), "add {k}");
        }
        assert!(t.capacity() >= 512, "capacity {}", t.capacity());
        for k in 1..=400u64 {
            assert!(t.contains(k), "lost {k} across migrations");
        }
        assert_eq!(t.len_quiesced(), 400);
    }

    #[test]
    fn explicit_grow_preserves_membership() {
        let t = QuiescingResize::new(8);
        for k in 1..=100u64 {
            t.add(k);
        }
        let before = t.capacity();
        t.grow();
        assert_eq!(t.capacity(), before * 2);
        for k in 1..=100u64 {
            assert!(t.contains(k));
        }
    }

    #[test]
    fn concurrent_adds_through_growth() {
        let t = Arc::new(QuiescingResize::with_threshold(7, 0.7));
        let mut hs = Vec::new();
        for tid in 0..6u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 10_000;
                for k in base..base + 500 {
                    assert!(t.add(k));
                    assert!(t.contains(k), "read-your-write across grow");
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.len_quiesced(), 3000);
        assert!(t.capacity() >= 4096);
        for tid in 0..6u64 {
            let base = 1 + tid * 10_000;
            for k in base..base + 500 {
                assert!(t.contains(k));
            }
        }
    }

    #[test]
    fn removes_update_trigger_accounting() {
        let t = QuiescingResize::with_threshold(6, 0.9);
        for round in 0..20 {
            for k in 1..=40u64 {
                t.add(k + round * 100);
            }
            for k in 1..=40u64 {
                t.remove(k + round * 100);
            }
        }
        // Churn with balanced add/remove shouldn't force runaway growth.
        assert!(t.capacity() <= 1024, "capacity {}", t.capacity());
        assert_eq!(t.len_quiesced(), 0);
    }

    #[test]
    fn threshold_recheck_grows_once_not_n_times() {
        // 8 threads all observe the trigger simultaneously; the locked
        // recheck must collapse them into a single doubling (the old
        // code doubled once per thread).
        let t = Arc::new(QuiescingResize::with_threshold(8, 0.9)); // 256
        let trigger = (256.0 * 0.9) as u64;
        for k in 1..trigger {
            t.add(k);
        }
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                t.add(10_000 + tid);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.capacity(), 512, "double-grow race regressed");
        assert_eq!(t.len_quiesced(), trigger as usize - 1 + 8);
    }

    // ---- incremental engine ----

    #[test]
    fn inc_grows_past_initial_capacity() {
        let t = IncResizableRobinHood::with_threshold(6, 0.75); // 64
        for k in 1..=400u64 {
            assert!(t.add(k), "add {k}");
        }
        t.finish_migration();
        assert!(t.capacity() >= 512, "capacity {}", t.capacity());
        assert!(t.generations() >= 4);
        for k in 1..=400u64 {
            assert!(t.contains(k), "lost {k} across migrations");
        }
        assert_eq!(t.len_quiesced(), 400);
        t.check_invariant().unwrap();
    }

    #[test]
    fn inc_map_grows_and_keeps_pairs() {
        let m = ResizableRobinHoodMap::with_threshold(6, 0.75);
        for k in 1..=300u64 {
            assert_eq!(m.insert(k, k * 3), None);
        }
        m.finish_migration();
        assert!(m.capacity() >= 512, "capacity {}", m.capacity());
        for k in 1..=300u64 {
            assert_eq!(m.get(k), Some(k * 3), "pair lost for {k}");
        }
        assert_eq!(m.insert(7, 99), Some(21));
        assert_eq!(m.remove(7), Some(99));
        assert_eq!(m.len_quiesced(), 299);
        m.check_invariant_quiesced().unwrap();
    }

    #[test]
    fn inc_map_conditional_ops_across_growth() {
        let m = ResizableRobinHoodMap::with_threshold(6, 0.75); // 64
        for k in 1..=300u64 {
            assert_eq!(m.get_or_insert(k, k * 2), None, "key {k}");
        }
        m.finish_migration();
        assert!(m.capacity() >= 512, "capacity {}", m.capacity());
        for k in 1..=300u64 {
            assert_eq!(m.fetch_add(k, 1), Some(k * 2), "key {k}");
            assert_eq!(
                m.compare_exchange(k, Some(k * 2 + 1), Some(k)),
                Ok(()),
                "key {k}"
            );
            assert_eq!(m.get(k), Some(k));
        }
        assert_eq!(m.compare_exchange(301, Some(1), Some(2)), Err(None));
        assert_eq!(m.compare_exchange(301, None, None), Ok(()));
        assert_eq!(m.len_quiesced(), 300);
        m.check_invariant_quiesced().unwrap();
    }

    #[test]
    fn inc_map_conditional_ops_mid_migration() {
        // Trip the trigger, then run every conditional corner while the
        // migration is still in flight: each must answer from the
        // old/new split consistently.
        let m = ResizableRobinHoodMap::with_threshold(7, 0.5); // 128
        let mut k = 1u64;
        while !m.migration_active() {
            m.insert(k, k * 3);
            k += 1;
        }
        let seeded = k - 1;
        for q in 1..=seeded {
            assert_eq!(m.get_or_insert(q, 0), Some(q * 3), "mid-mig {q}");
        }
        assert_eq!(m.fetch_add(2, 4), Some(6));
        assert_eq!(m.get(2), Some(10));
        assert_eq!(m.compare_exchange(2, Some(10), Some(11)), Ok(()));
        assert_eq!(m.compare_exchange(2, Some(10), Some(12)), Err(Some(11)));
        assert_eq!(m.compare_exchange(2, Some(11), None), Ok(()));
        assert_eq!(m.compare_exchange(2, None, None), Ok(()));
        assert_eq!(m.fetch_add(seeded + 100, 7), None);
        m.finish_migration();
        assert_eq!(m.get(seeded + 100), Some(7));
        assert_eq!(m.len_quiesced(), seeded as usize);
        m.check_invariant_quiesced().unwrap();
    }

    #[test]
    fn inc_ops_mid_migration_see_consistent_state() {
        // Freeze the trigger exactly at the boundary, then interleave
        // reads/writes while stripes are still unclaimed: every op must
        // answer correctly from the old/new split.
        let t = IncResizableRobinHood::with_threshold(7, 0.5); // 128
        let mut k = 1u64;
        while !t.migration_active() {
            t.add(k);
            k += 1;
        }
        let added = k - 1;
        // Migration is in flight; mixed ops against the split state.
        for q in 1..=added {
            assert!(t.contains(q), "mid-migration lost {q}");
        }
        assert!(!t.contains(added + 100));
        assert!(t.remove(3));
        assert!(!t.contains(3));
        assert!(t.add(3));
        assert!(t.contains(3));
        t.finish_migration();
        assert_eq!(t.len_quiesced(), added as usize);
    }

    #[test]
    fn inc_removes_update_trigger_accounting() {
        let t = IncResizableRobinHood::with_threshold(6, 0.9);
        for round in 0..20 {
            for k in 1..=40u64 {
                t.add(k + round * 100);
            }
            for k in 1..=40u64 {
                t.remove(k + round * 100);
            }
        }
        t.finish_migration();
        assert!(t.capacity() <= 1024, "capacity {}", t.capacity());
        assert_eq!(t.len_quiesced(), 0);
    }

    #[test]
    fn inc_concurrent_adds_through_growth() {
        let t = Arc::new(IncResizableRobinHood::with_threshold(7, 0.7));
        let mut hs = Vec::new();
        for tid in 0..6u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 10_000;
                for k in base..base + 500 {
                    assert!(t.add(k));
                    assert!(t.contains(k), "read-your-write across grow");
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.len_quiesced(), 3000);
        assert!(t.capacity() >= 4096);
        t.check_invariant().unwrap();
        for tid in 0..6u64 {
            let base = 1 + tid * 10_000;
            for k in base..base + 500 {
                assert!(t.contains(k));
            }
        }
    }
}
