//! Transactional Robin Hood — the paper's HTM (lock-elision) variant,
//! emulated in software (DESIGN.md substitution #1).
//!
//! The paper runs plain Robin Hood inside Intel RTM transactions with
//! speculative lock elision [32]. This container (like most current
//! x86 parts) has no usable TSX, so we emulate the *semantics* the
//! transactions provided:
//!
//! * **Readers** run optimistically against per-shard *sequence
//!   versions* (even = stable, odd = writer in flight) — precisely the
//!   read-set validation an HTM transaction performs in hardware;
//!   a conflicting writer aborts the reader, which retries.
//! * **Writers** discover their write span, acquire the covering shard
//!   locks in sorted order (deadlock-free), re-validate, apply the
//!   whole displacement/shift chain, and publish by bumping versions —
//!   an explicit software transaction with the same multi-bucket
//!   atomicity granularity.
//!
//! Compared with [`super::kcas_rh`], there is no timestamp array on the
//! read path and no K-CAS descriptor indirection — which is exactly why
//! the paper's Fig. 10 shows the transactional variant winning single
//! core, and the lock serialization is why it stops scaling across
//! sockets (Figs. 11-12).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::util::pad::CachePadded;

use super::{check_key, ConcurrentSet};
use crate::util::hash::{dfb, home_bucket};

const NIL: u64 = 0;

thread_local! {
    static SCRATCH: RefCell<(Vec<(usize, u64)>, Vec<(usize, u64)>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

pub struct TxRobinHood {
    table: Box<[AtomicU64]>,
    vers: Box<[CachePadded<AtomicU64>]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    mask: u64,
    shard_log2: u32,
}

impl TxRobinHood {
    pub fn new(size_log2: u32) -> Self {
        // Bounded shard table (cache-resident), like the HTM variant's
        // elided lock table — see kcas_rh::default_shard_log2.
        let shard_log2 = super::kcas_rh::default_shard_log2(size_log2);
        let size = 1usize << size_log2;
        let shards = (size >> shard_log2).max(1);
        Self {
            table: (0..size).map(|_| AtomicU64::new(NIL)).collect(),
            vers: (0..shards)
                .map(|_| CachePadded::new(AtomicU64::new(0)))
                .collect(),
            locks: (0..shards)
                .map(|_| CachePadded::new(Mutex::new(())))
                .collect(),
            mask: (size - 1) as u64,
            shard_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn shard(&self, i: usize) -> usize {
        (i >> self.shard_log2) & (self.vers.len() - 1)
    }

    #[inline]
    fn wrap(&self, i: usize) -> usize {
        i & self.mask as usize
    }

    /// Bucket load without bounds check (indices are pre-masked).
    #[inline(always)]
    fn bucket(&self, i: usize) -> u64 {
        debug_assert!(i < self.table.len());
        // SAFETY: every caller masks `i` by the power-of-two table
        // mask, so it is always in bounds (debug-asserted above).
        unsafe { self.table.get_unchecked(i) }.load(Ordering::Acquire)
    }

    #[inline]
    fn dist(&self, key: u64, i: usize) -> u64 {
        dfb(home_bucket(key, self.mask), i, self.mask)
    }

    /// Lock shards covering `[start, start+len)` (wrapped), sorted.
    fn lock_span(&self, start: usize, len: usize) -> Vec<MutexGuard<'_, ()>> {
        let mut shards: Vec<usize> = (0..=len >> self.shard_log2)
            .map(|s| self.shard(self.wrap(start + (s << self.shard_log2))))
            .collect();
        shards.sort_unstable();
        shards.dedup();
        shards
            .iter()
            .map(|&s| self.locks[s].lock().unwrap())
            .collect()
    }

    /// Begin the "commit" of a software transaction over bucket range
    /// `[start, start+len)`: bump all covered versions to odd.
    fn tx_begin(&self, start: usize, len: usize) {
        let mut s = 0;
        while s <= len >> self.shard_log2 {
            let sh = self.shard(self.wrap(start + (s << self.shard_log2)));
            self.vers[sh].fetch_add(1, Ordering::AcqRel);
            s += 1;
        }
    }

    /// Publish: bump versions back to even.
    fn tx_end(&self, start: usize, len: usize) {
        let mut s = 0;
        while s <= len >> self.shard_log2 {
            let sh = self.shard(self.wrap(start + (s << self.shard_log2)));
            self.vers[sh].fetch_add(1, Ordering::AcqRel);
            s += 1;
        }
    }
}

impl TxRobinHood {
    /// Slow-path `contains` for probes that cross version shards.
    #[cold]
    fn contains_multi_shard(&self, key: u64, home: usize) -> bool {
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let seen = &mut guard.0;
            'retry: loop {
                seen.clear();
                let mut i = home;
                let mut cur_dist = 0u64;
                loop {
                    let sh = self.shard(i);
                    if seen.last().map(|&(x, _)| x) != Some(sh) {
                        let v = self.vers[sh].load(Ordering::Acquire);
                        if v & 1 == 1 {
                            continue 'retry; // writer in flight: abort
                        }
                        seen.push((sh, v));
                    }
                    let cur = self.bucket(i);
                    if cur == key {
                        return true;
                    }
                    if cur == NIL || self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = self.wrap(i + 1);
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break;
                    }
                }
                // Read-set validation (what RTM does in hardware).
                for &(sh, v) in seen.iter() {
                    if self.vers[sh].load(Ordering::Acquire) != v {
                        continue 'retry;
                    }
                }
                return false;
            }
        })
    }
}

impl ConcurrentSet for TxRobinHood {
    /// Optimistic read with a register-resident read-set in the common
    /// single-shard case (exactly what a short RTM transaction's
    /// hardware read-set gives you for free).
    fn contains(&self, key: u64) -> bool {
        check_key(key);
        let home = home_bucket(key, self.mask);
        'retry: loop {
            let sh0 = self.shard(home);
            let v0 = self.vers[sh0].load(Ordering::Acquire);
            if v0 & 1 == 1 {
                std::hint::spin_loop();
                continue 'retry; // writer in flight
            }
            let mut i = home;
            let mut cur_dist = 0u64;
            loop {
                if self.shard(i) != sh0 {
                    return self.contains_multi_shard(key, home);
                }
                let cur = self.bucket(i);
                if cur == key {
                    return true;
                }
                if cur == NIL || self.dist(cur, i) < cur_dist {
                    break;
                }
                i = self.wrap(i + 1);
                cur_dist += 1;
                if cur_dist as usize > self.size() {
                    break;
                }
            }
            if self.vers[sh0].load(Ordering::Acquire) == v0 {
                return false;
            }
            continue 'retry;
        }
    }

    fn add(&self, key: u64) -> bool {
        check_key(key);
        let home = home_bucket(key, self.mask);
        let mut est = 2 * (1usize << self.shard_log2);
        'attempt: loop {
            assert!(est <= 2 * self.size(), "tx-rh table too full");
            let guards = self.lock_span(home, est);
            // Serial Robin Hood insertion, planned within the locked span.
            let mut active = key;
            let mut active_dist = 0u64;
            let mut i = home;
            let mut span = 0usize;
            let mut plan: Vec<(usize, u64)> = Vec::new();
            let end = loop {
                if span >= est {
                    drop(guards);
                    est *= 2;
                    continue 'attempt; // chain leaves the locked span
                }
                let cur = self.bucket(i);
                if cur == NIL {
                    plan.push((i, active));
                    break span;
                }
                if cur == key {
                    return false;
                }
                let cur_d = self.dist(cur, i);
                if cur_d < active_dist {
                    plan.push((i, active));
                    active = cur;
                    active_dist = cur_d;
                }
                i = self.wrap(i + 1);
                active_dist += 1;
                span += 1;
            };
            // Commit the transaction.
            let first = plan.first().map(|&(p, _)| p).unwrap();
            let wlen = end + 1;
            let _ = first;
            self.tx_begin(home, wlen);
            for &(p, v) in &plan {
                self.table[p].store(v, Ordering::Release);
            }
            self.tx_end(home, wlen);
            return true;
        }
    }

    fn remove(&self, key: u64) -> bool {
        check_key(key);
        let home = home_bucket(key, self.mask);
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let seen = &mut guard.0;
            'retry: loop {
                // Optimistic find (same protocol as contains).
                seen.clear();
                let mut i = home;
                let mut cur_dist = 0u64;
                let mut hit = false;
                loop {
                    let sh = self.shard(i);
                    if seen.last().map(|&(x, _)| x) != Some(sh) {
                        let v = self.vers[sh].load(Ordering::Acquire);
                        if v & 1 == 1 {
                            continue 'retry;
                        }
                        seen.push((sh, v));
                    }
                    let cur = self.bucket(i);
                    if cur == key {
                        hit = true;
                        break;
                    }
                    if cur == NIL || self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = self.wrap(i + 1);
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break;
                    }
                }
                if !hit {
                    for &(sh, v) in seen.iter() {
                        if self.vers[sh].load(Ordering::Acquire) != v {
                            continue 'retry;
                        }
                    }
                    return false;
                }
                // Found at i: lock the shift span and re-validate.
                let mut est = 2 * (1usize << self.shard_log2);
                loop {
                    assert!(est <= 2 * self.size(), "tx-rh: shift too long");
                    let guards = self.lock_span(i, est);
                    if self.table[i].load(Ordering::Acquire) != key {
                        drop(guards);
                        continue 'retry; // moved under us
                    }
                    // Determine the backward-shift chain end.
                    let mut m = i;
                    let mut len = 0usize;
                    let mut grown = false;
                    loop {
                        let next = self.wrap(m + 1);
                        if len + 1 >= est {
                            grown = true;
                            break;
                        }
                        let nk = self.bucket(next);
                        if nk == NIL || self.dist(nk, next) == 0 {
                            break;
                        }
                        m = next;
                        len += 1;
                    }
                    if grown {
                        drop(guards);
                        est *= 2;
                        continue;
                    }
                    // Transaction: shift [i+1..=m] back one, Nil m.
                    self.tx_begin(i, len + 1);
                    let mut hole = i;
                    while hole != m {
                        let next = self.wrap(hole + 1);
                        let v = self.bucket(next);
                        self.table[hole].store(v, Ordering::Release);
                        hole = next;
                    }
                    self.table[m].store(NIL, Ordering::Release);
                    self.tx_end(i, len + 1);
                    return true;
                }
            }
        })
    }

    fn name(&self) -> &'static str {
        "tx-rh"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let k = self.table[i].load(Ordering::Acquire);
                if k == NIL {
                    -1
                } else {
                    self.dist(k, i) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        self.table
            .iter()
            .filter(|b| b.load(Ordering::Acquire) != NIL)
            .count()
    }
}

impl TxRobinHood {
    /// Robin Hood invariant check (quiesced).
    pub fn check_invariant(&self) -> Result<(), String> {
        let n = self.size();
        for i in 0..n {
            let k = self.table[i].load(Ordering::Acquire);
            if k == NIL {
                continue;
            }
            let d = self.dist(k, i);
            if d == 0 {
                continue;
            }
            let pi = self.wrap(i + n - 1);
            let prev = self.table[pi].load(Ordering::Acquire);
            if prev == NIL {
                return Err(format!("bucket {i}: dfb {d} after empty"));
            }
            let pd = self.dist(prev, pi);
            if d > pd + 1 {
                return Err(format!("bucket {i}: dfb {d} > prev {pd}+1"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = TxRobinHood::new(8);
        assert!(t.add(3));
        assert!(!t.add(3));
        assert!(t.contains(3));
        assert!(t.remove(3));
        assert!(!t.remove(3));
        assert!(!t.contains(3));
    }

    #[test]
    fn high_load_factor_fill() {
        let t = TxRobinHood::new(10);
        let n = (1024.0 * 0.85) as u64;
        for k in 1..=n {
            assert!(t.add(k));
        }
        t.check_invariant().unwrap();
        for k in 1..=n {
            assert!(t.contains(k));
        }
        assert_eq!(t.len_quiesced(), n as usize);
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "tx-rh matches HashSet",
            25,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = TxRobinHood::new(7);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                t.check_invariant()?;
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_disjoint_deterministic() {
        let t = Arc::new(TxRobinHood::new(12));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 1000;
                for k in base..base + 300 {
                    assert!(t.add(k));
                }
                for k in (base..base + 300).step_by(2) {
                    assert!(t.remove(k));
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
        assert_eq!(t.len_quiesced(), 8 * 150);
    }

    #[test]
    fn readers_never_miss_stable_keys() {
        let t = Arc::new(TxRobinHood::new(7));
        const CHURN: u64 = 60;
        for k in 1..=CHURN + 30 {
            t.add(k);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        for tid in 0..2u64 {
            let (t, stop) = (t.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(41, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(CHURN);
                    t.remove(k);
                    t.add(k);
                }
            }));
        }
        for tid in 0..4u64 {
            let (t, stop) = (t.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(43, tid);
                for _ in 0..30_000 {
                    let k = CHURN + 1 + r.below(30);
                    assert!(t.contains(k), "stable key {k} missed");
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
    }
}
