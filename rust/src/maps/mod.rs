//! The hash tables: the paper's contribution and all its competitors.
//!
//! Every table implements [`ConcurrentSet`] over 62-bit integer keys
//! (the paper benchmarks integer *sets*: `Add/Contains/Remove(key)`).
//! Key 0 is reserved as Nil in the open-addressing tables; the public
//! API therefore requires `1 <= key <= MAX_KEY`.

pub mod hopscotch;
pub mod kcas_rh;
pub mod kcas_rh_map;
pub mod lockfree_lp;
pub mod locked_lp;
pub mod michael;
pub mod resizable;
pub mod serial_rh;
pub mod tx_rh;

/// Largest legal key (62-bit, minus the reserved Nil/Tombstone values).
pub const MAX_KEY: u64 = (1 << 62) - 3;

/// A concurrent set of integer keys — the paper's benchmark interface.
pub trait ConcurrentSet: Send + Sync {
    /// True iff `key` is in the set (paper Fig. 7).
    fn contains(&self, key: u64) -> bool;
    /// Insert; false if already present (paper Fig. 8).
    fn add(&self, key: u64) -> bool;
    /// Delete; false if not present (paper Fig. 9).
    fn remove(&self, key: u64) -> bool;

    /// Short stable name used in benchmark tables.
    fn name(&self) -> &'static str;

    /// Number of buckets (chained tables report the bucket-array length).
    fn capacity(&self) -> usize;

    /// Distance-from-home-bucket per bucket, -1 for empty. Only valid
    /// when quiesced (no concurrent writers); used for invariant checks
    /// and the probe-statistics analytics. Chained tables return empty.
    fn dfb_snapshot(&self) -> Vec<i32> {
        Vec::new()
    }

    /// Exact element count when quiesced.
    fn len_quiesced(&self) -> usize;
}

/// Which table to construct — used by the CLI, harness, and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableKind {
    KCasRobinHood,
    TxRobinHood,
    Hopscotch,
    LockFreeLp,
    LockedLp,
    Michael,
    SerialRobinHood,
}

impl TableKind {
    pub const ALL_CONCURRENT: [TableKind; 6] = [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::Hopscotch,
        TableKind::LockFreeLp,
        TableKind::LockedLp,
        TableKind::Michael,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TableKind::KCasRobinHood => "kcas-rh",
            TableKind::TxRobinHood => "tx-rh",
            TableKind::Hopscotch => "hopscotch",
            TableKind::LockFreeLp => "lockfree-lp",
            TableKind::LockedLp => "locked-lp",
            TableKind::Michael => "michael",
            TableKind::SerialRobinHood => "serial-rh",
        }
    }

    /// Paper display name (Figs. 10-12 / Table 1 rows).
    pub fn display(&self) -> &'static str {
        match self {
            TableKind::KCasRobinHood => "K-CAS Robin Hood",
            TableKind::TxRobinHood => "Transactional RH",
            TableKind::Hopscotch => "Hopscotch Hashing",
            TableKind::LockFreeLp => "Lock-Free LP",
            TableKind::LockedLp => "Locked LP",
            TableKind::Michael => "Maged Michael",
            TableKind::SerialRobinHood => "Serial Robin Hood",
        }
    }

    pub fn parse(s: &str) -> Option<TableKind> {
        match s {
            "kcas-rh" => Some(TableKind::KCasRobinHood),
            "tx-rh" => Some(TableKind::TxRobinHood),
            "hopscotch" => Some(TableKind::Hopscotch),
            "lockfree-lp" => Some(TableKind::LockFreeLp),
            "locked-lp" => Some(TableKind::LockedLp),
            "michael" => Some(TableKind::Michael),
            "serial-rh" => Some(TableKind::SerialRobinHood),
            _ => None,
        }
    }

    /// Construct a table with `1 << size_log2` buckets.
    pub fn build(&self, size_log2: u32) -> Box<dyn ConcurrentSet> {
        match self {
            TableKind::KCasRobinHood => {
                Box::new(kcas_rh::KCasRobinHood::new(size_log2))
            }
            TableKind::TxRobinHood => Box::new(tx_rh::TxRobinHood::new(size_log2)),
            TableKind::Hopscotch => Box::new(hopscotch::Hopscotch::new(size_log2)),
            TableKind::LockFreeLp => {
                Box::new(lockfree_lp::LockFreeLp::new(size_log2))
            }
            TableKind::LockedLp => Box::new(locked_lp::LockedLp::new(size_log2)),
            TableKind::Michael => Box::new(michael::MichaelSet::new(size_log2)),
            TableKind::SerialRobinHood => {
                Box::new(serial_rh::SerialRobinHoodLocked::new(size_log2))
            }
        }
    }
}

/// Validate a key for the open-addressing tables.
#[inline]
pub(crate) fn check_key(key: u64) {
    assert!(
        key >= 1 && key <= MAX_KEY,
        "key {key} out of range [1, {MAX_KEY}]"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in TableKind::ALL_CONCURRENT {
            assert_eq!(TableKind::parse(k.name()), Some(k));
        }
        assert_eq!(
            TableKind::parse("serial-rh"),
            Some(TableKind::SerialRobinHood)
        );
        assert_eq!(TableKind::parse("nope"), None);
    }

    #[test]
    fn build_all_kinds_smoke() {
        for k in TableKind::ALL_CONCURRENT {
            let t = k.build(8);
            assert!(t.add(7));
            assert!(t.contains(7));
            assert!(!t.add(7));
            assert!(t.remove(7));
            assert!(!t.contains(7), "{}", k.name());
            assert!(!t.remove(7));
        }
    }
}
