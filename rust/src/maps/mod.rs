//! The hash tables: the paper's contribution, all its competitors, and
//! the scaling compositions (growable engines, sharded facade). Growth
//! comes in two flavours (see [`resizable`]): the non-blocking
//! two-generation engines (`inc-resize-rh[:N]`, `inc-resize-rh-map[:N]`)
//! and the quiescing epoch-RwLock baseline (`resizable-rh`,
//! `sharded-resizable-rh:N`).
//!
//! Every table implements [`ConcurrentSet`] over 62-bit integer keys
//! (the paper benchmarks integer *sets*: `Add/Contains/Remove(key)`).
//! Key 0 is reserved as Nil in the open-addressing tables; the public
//! API therefore requires `1 <= key <= MAX_KEY`.
//!
//! The key→value side (§2.2: Robin Hood hashing is what Rust's stdlib
//! shipped as a *map*) lives behind [`ConcurrentMap`], implemented by
//! [`kcas_rh_map::KCasRobinHoodMap`], the [`locked_lp::LockedLpMap`]
//! blocking baseline, and [`sharded::Sharded`] compositions of either.
//! Map specs are named by [`MapKind`] exactly like set specs by
//! [`TableKind`]: flat names (`kcas-rh-map`, `locked-lp-map`) plus
//! sharded names with a `:N` power-of-two shard-count suffix
//! (`sharded-kcas-rh-map:16`). Values are 62-bit
//! (`<= kcas::MAX_VALUE`); batch traffic uses [`MapOp`]/[`MapReply`]
//! (see `service::batch` for the batched pipeline built on top).
//!
//! The map surface is **conditional-first**: beyond the unconditional
//! `get`/`insert`/`remove` trio, every map natively provides
//! [`ConcurrentMap::compare_exchange`] (whose `expected`/`new` corners
//! subsume insert-if-absent and remove-if-equal),
//! [`ConcurrentMap::get_or_insert`], and [`ConcurrentMap::fetch_add`] —
//! on the K-CAS tables each is a *single* K-CAS (value-word guard +
//! write), so check-then-act workloads (counters, leases, optimistic
//! updates) need no external locking. The `fig16_rmw` experiment
//! measures them under contention skew.

pub mod hopscotch;
pub mod kcas_rh;
pub mod kcas_rh_map;
pub mod lockfree_lp;
pub mod locked_lp;
pub mod michael;
pub mod resizable;
pub mod serial_rh;
pub mod sharded;
pub mod tx_rh;
pub mod txn;

/// Largest legal key (62-bit, minus the reserved Nil/Tombstone values).
pub const MAX_KEY: u64 = (1 << 62) - 3;

/// Typed map-layer error — the single error vocabulary shared by the
/// internal op plumbing and the transaction API, so `apply_txn` does
/// not invent a second convention next to the `Frozen` sentinel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapError {
    /// The targeted home run is frozen for migration; re-resolve the
    /// generation pointers and retry (internal — public ops never
    /// surface this, they help the migration and re-run).
    Frozen,
    /// No free bucket on the probe path (the table is full).
    TableFull,
    /// The transaction's per-key physical plans overlap irreconcilably
    /// (e.g. two inserts claiming one bucket); the commit was aborted
    /// with no effect. Deterministic for a given table state, so the
    /// caller should not blindly retry.
    TxnConflict,
    /// The receiver does not implement multi-key transactions.
    Unsupported,
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MapError::Frozen => "bucket run frozen for migration",
            MapError::TableFull => "table full",
            MapError::TxnConflict => "transaction conflict",
            MapError::Unsupported => "transactions unsupported",
        })
    }
}

impl std::error::Error for MapError {}

impl From<kcas_rh::Frozen> for MapError {
    fn from(_: kcas_rh::Frozen) -> Self {
        MapError::Frozen
    }
}

/// Error type of [`ConcurrentMap::apply_txn`].
pub type TxnError = MapError;

/// A concurrent set of integer keys — the paper's benchmark interface.
pub trait ConcurrentSet: Send + Sync {
    /// True iff `key` is in the set (paper Fig. 7).
    fn contains(&self, key: u64) -> bool;
    /// Insert; false if already present (paper Fig. 8).
    fn add(&self, key: u64) -> bool;
    /// Delete; false if not present (paper Fig. 9).
    fn remove(&self, key: u64) -> bool;

    /// Hash-aware twin of [`ConcurrentSet::contains`]: `h` must equal
    /// `splitmix64(key)`. The sharded facade routes on the *high* bits
    /// of `h` and hands the same hash down so the inner table's home
    /// bucket (`h & mask`) costs no second SplitMix64. Tables that
    /// don't exploit the hint fall back to the plain entry point.
    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        let _ = h;
        self.contains(key)
    }

    /// Hash-aware twin of [`ConcurrentSet::add`] (`h == splitmix64(key)`).
    fn add_hashed(&self, h: u64, key: u64) -> bool {
        let _ = h;
        self.add(key)
    }

    /// Hash-aware twin of [`ConcurrentSet::remove`] (`h == splitmix64(key)`).
    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        let _ = h;
        self.remove(key)
    }

    /// Short stable name used in benchmark tables.
    fn name(&self) -> &'static str;

    /// Number of buckets (chained tables report the bucket-array length).
    fn capacity(&self) -> usize;

    /// Distance-from-home-bucket per bucket, -1 for empty. Only valid
    /// when quiesced (no concurrent writers); used for invariant checks
    /// and the probe-statistics analytics. Chained tables return empty;
    /// sharded tables concatenate per-shard snapshots in shard order.
    fn dfb_snapshot(&self) -> Vec<i32> {
        Vec::new()
    }

    /// Exact element count when quiesced.
    fn len_quiesced(&self) -> usize;
}

/// One key→value operation, the unit of the batched service pipeline
/// (`service::batch`). Keys obey the table key range `[1, MAX_KEY]`;
/// values are 62-bit (`<= kcas::MAX_VALUE`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapOp {
    /// Look up a key.
    Get(u64),
    /// Insert or overwrite `(key, value)`.
    Insert(u64, u64),
    /// Remove a key.
    Remove(u64),
    /// `CmpEx(key, expected, new)`: conditional write — see
    /// [`ConcurrentMap::compare_exchange`] for the four corners.
    CmpEx(u64, Option<u64>, Option<u64>),
    /// `GetOrInsert(key, value)`: insert iff absent, report the
    /// resident value otherwise.
    GetOrInsert(u64, u64),
    /// `FetchAdd(key, delta)`: atomic counter increment (missing keys
    /// count as 0).
    FetchAdd(u64, u64),
}

impl MapOp {
    /// The key this operation targets (what batch routing shards on).
    #[inline]
    pub fn key(&self) -> u64 {
        match *self {
            MapOp::Get(k)
            | MapOp::Insert(k, _)
            | MapOp::Remove(k)
            | MapOp::CmpEx(k, _, _)
            | MapOp::GetOrInsert(k, _)
            | MapOp::FetchAdd(k, _) => k,
        }
    }
}

/// Reply to one [`MapOp`], mirroring its variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapReply {
    /// `Get`: the value, if the key was present.
    Value(Option<u64>),
    /// `Insert`: the previous value, if the key existed (overwrite).
    Prev(Option<u64>),
    /// `Remove`: the value that was removed, if the key existed.
    Removed(Option<u64>),
    /// `CmpEx`: `Ok(())` if the exchange committed, `Err(witness)` with
    /// the value observed at the linearization point otherwise.
    CmpEx(Result<(), Option<u64>>),
    /// `GetOrInsert`: the pre-existing value (`None` = we inserted).
    Existing(Option<u64>),
    /// `FetchAdd`: the previous value (`None` = the key was absent and
    /// now holds `delta`).
    Added(Option<u64>),
}

impl MapReply {
    /// The optional value inside, regardless of variant (what the wire
    /// protocol prints for value-shaped replies: the value or `-`).
    /// A successful `CmpEx` carries no value and reports `None`; a
    /// failed one reports its witness (the wire layer prints `CmpEx`
    /// replies as `OK` / `!<witness>` instead — see `service::server`).
    #[inline]
    pub fn value(&self) -> Option<u64> {
        match *self {
            MapReply::Value(v)
            | MapReply::Prev(v)
            | MapReply::Removed(v)
            | MapReply::Existing(v)
            | MapReply::Added(v) => v,
            MapReply::CmpEx(r) => r.err().flatten(),
        }
    }
}

/// A batch op paired with its precomputed SplitMix64 hash
/// (`.0 == splitmix64(.1.key())`) — what `Sharded`'s batch grouping
/// hands down so inner tables never re-hash (see
/// [`ConcurrentMap::apply_batch_hashed`]).
pub type HashedMapOp = (u64, MapOp);

/// A concurrent key→value map — the service-layer interface, mirroring
/// [`ConcurrentSet`] (ROADMAP "Sharded map (key→value)" milestone).
///
/// Keys obey the same `[1, MAX_KEY]` range as the set tables; values
/// are 62-bit (`<= kcas::MAX_VALUE`) — store indices/handles for larger
/// payloads.
pub trait ConcurrentMap: Send + Sync {
    /// Look up `key`; the value paired with it at the linearization
    /// point, if present.
    fn get(&self, key: u64) -> Option<u64>;
    /// Insert or overwrite; returns the previous value if `key` existed.
    fn insert(&self, key: u64, value: u64) -> Option<u64>;
    /// Remove; returns the value that was present.
    fn remove(&self, key: u64) -> Option<u64>;

    /// Atomic conditional write — the unified check-then-act primitive
    /// the unconditional trio can't express without external locking.
    /// The `expected`/`new` corners subsume the classic conditional ops:
    ///
    /// | `expected` | `new`     | meaning                               |
    /// |------------|-----------|---------------------------------------|
    /// | `None`     | `Some(v)` | insert `v` iff `key` absent           |
    /// | `Some(e)`  | `Some(v)` | overwrite iff currently `e`           |
    /// | `Some(e)`  | `None`    | remove iff currently `e`              |
    /// | `None`     | `None`    | succeed iff `key` absent (assertion)  |
    ///
    /// Returns `Ok(())` when the exchange committed (the table held
    /// `expected` at the linearization point and now holds `new`), or
    /// `Err(witness)` with the value actually observed there (`None` =
    /// absent). Implementations must make the check and the write one
    /// atomic step — on `KCasRobinHoodMap` the whole op is a single
    /// K-CAS (value-word guard + write), on `LockedLpMap` it runs under
    /// the home-segment lock.
    fn compare_exchange(
        &self,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>>;

    /// Insert `value` iff `key` is absent; returns the pre-existing
    /// value otherwise (`None` = this call inserted). Unlike
    /// [`ConcurrentMap::insert`] it never overwrites.
    fn get_or_insert(&self, key: u64, value: u64) -> Option<u64>;

    /// Atomic `value += delta` (wrapping in the 62-bit value domain).
    /// A missing key counts as 0: the op inserts `delta`. Returns the
    /// previous value (`None` = the key was absent).
    fn fetch_add(&self, key: u64, delta: u64) -> Option<u64>;

    /// Hash-aware twin of [`ConcurrentMap::get`] (`h == splitmix64(key)`;
    /// see [`ConcurrentSet::contains_hashed`]).
    fn get_hashed(&self, h: u64, key: u64) -> Option<u64> {
        let _ = h;
        self.get(key)
    }

    /// Hash-aware twin of [`ConcurrentMap::insert`].
    fn insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        let _ = h;
        self.insert(key, value)
    }

    /// Hash-aware twin of [`ConcurrentMap::remove`].
    fn remove_hashed(&self, h: u64, key: u64) -> Option<u64> {
        let _ = h;
        self.remove(key)
    }

    /// Hash-aware twin of [`ConcurrentMap::compare_exchange`].
    fn compare_exchange_hashed(
        &self,
        h: u64,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        let _ = h;
        self.compare_exchange(key, expected, new)
    }

    /// Hash-aware twin of [`ConcurrentMap::get_or_insert`].
    fn get_or_insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        let _ = h;
        self.get_or_insert(key, value)
    }

    /// Hash-aware twin of [`ConcurrentMap::fetch_add`].
    fn fetch_add_hashed(&self, h: u64, key: u64, delta: u64) -> Option<u64> {
        let _ = h;
        self.fetch_add(key, delta)
    }

    /// Apply one op (convenience used by the default batch path).
    fn apply_one(&self, op: MapOp) -> MapReply {
        match op {
            MapOp::Get(k) => MapReply::Value(self.get(k)),
            MapOp::Insert(k, v) => MapReply::Prev(self.insert(k, v)),
            MapOp::Remove(k) => MapReply::Removed(self.remove(k)),
            MapOp::CmpEx(k, e, n) => {
                MapReply::CmpEx(self.compare_exchange(k, e, n))
            }
            MapOp::GetOrInsert(k, v) => {
                MapReply::Existing(self.get_or_insert(k, v))
            }
            MapOp::FetchAdd(k, d) => MapReply::Added(self.fetch_add(k, d)),
        }
    }

    /// Apply one op off a precomputed hash (`h == splitmix64(op.key())`)
    /// — the per-op unit of the hashed batch path.
    fn apply_one_hashed(&self, h: u64, op: MapOp) -> MapReply {
        match op {
            MapOp::Get(k) => MapReply::Value(self.get_hashed(h, k)),
            MapOp::Insert(k, v) => MapReply::Prev(self.insert_hashed(h, k, v)),
            MapOp::Remove(k) => MapReply::Removed(self.remove_hashed(h, k)),
            MapOp::CmpEx(k, e, n) => {
                MapReply::CmpEx(self.compare_exchange_hashed(h, k, e, n))
            }
            MapOp::GetOrInsert(k, v) => {
                MapReply::Existing(self.get_or_insert_hashed(h, k, v))
            }
            MapOp::FetchAdd(k, d) => {
                MapReply::Added(self.fetch_add_hashed(h, k, d))
            }
        }
    }

    /// Apply a batch of operations; `out` is cleared and receives one
    /// reply per op, **in op order**, and the observable effect must
    /// equal applying the ops one at a time in slice order.
    ///
    /// The default loops op-by-op. `KCasRobinHoodMap` overrides it to
    /// borrow its thread-local `OpBuilder`/scratch once for the whole
    /// batch; `Sharded<T>` overrides it to group ops by shard (legal
    /// because ops on different shards touch disjoint keys, hence
    /// commute) and forward each group as one sub-batch.
    fn apply_batch(&self, ops: &[MapOp], out: &mut Vec<MapReply>) {
        out.clear();
        out.extend(ops.iter().map(|&op| self.apply_one(op)));
    }

    /// [`ConcurrentMap::apply_batch`] over hash-carrying ops: every
    /// `(h, op)` pair satisfies `h == splitmix64(op.key())`, so tables
    /// with hashed entry points skip the per-op SplitMix64 entirely.
    /// This is what `Sharded<T>` forwards per-shard sub-batches
    /// through — the facade already hashed every key once to route it,
    /// and this hook hands that hash down (closing the batch-path
    /// double-hash the single-op `*_hashed` entry points closed in
    /// PR 2). Same ordering/equivalence contract as `apply_batch`.
    fn apply_batch_hashed(&self, ops: &[HashedMapOp], out: &mut Vec<MapReply>) {
        out.clear();
        out.extend(ops.iter().map(|&(h, op)| self.apply_one_hashed(h, op)));
    }

    /// Apply `ops` as one **all-or-nothing transaction**: either every
    /// op takes effect at a single linearization point (replies are the
    /// sequential evaluation of `ops` in slice order at that point) or
    /// none does and an error is returned. Unlike [`ConcurrentMap::apply_batch`],
    /// no concurrent operation can observe a state where only some of
    /// the ops have been applied.
    ///
    /// On the K-CAS tables the commit is **one K-CAS** spanning every
    /// touched key/value word (plus the timestamp guards for probed-over
    /// shards), cross-shard on [`sharded::Sharded`] via a single shared
    /// descriptor; `LockedLpMap` commits under two-phase locking of the
    /// home segments. Non-transactional tables keep the default body and
    /// report [`MapError::Unsupported`].
    ///
    /// Errors: [`MapError::TxnConflict`] when the per-key physical plans
    /// overlap irreconcilably (nothing was applied),
    /// [`MapError::TableFull`] when an insert finds no bucket.
    fn apply_txn(&self, ops: &[MapOp]) -> Result<Vec<MapReply>, TxnError> {
        let _ = ops;
        Err(MapError::Unsupported)
    }

    /// Short stable name used in benchmark tables.
    fn name(&self) -> &'static str;

    /// Number of buckets.
    fn capacity(&self) -> usize;

    /// Exact element count when quiesced.
    fn len_quiesced(&self) -> usize;

    /// Structural consistency check, valid only when quiesced (no
    /// concurrent writers); tables without internal invariants (the
    /// chained/LP baselines) report `Ok` by default. The Robin Hood
    /// maps verify DFB ordering here, and sharded facades check every
    /// shard — the end-of-run hook the examples and stress tests call.
    fn check_invariant_quiesced(&self) -> Result<(), String> {
        Ok(())
    }
}

/// Shared spec scaffolding for [`MapKind`] and [`TableKind`]: one name
/// table and one `:N` shard-suffix parser instead of two hand-rolled
/// `parse` copies duplicating the suffix grammar and shard validation.
pub mod spec {
    /// Bare sharded names (`sharded-kcas-rh`) parse as this many shards.
    pub const DEFAULT_SHARDS: u32 = 4;

    /// Shard-count validity shared by every sharded spec: a power of
    /// two no larger than the facade's 2^16 limit.
    pub fn valid_shards(n: u32) -> bool {
        n.is_power_of_two() && n <= 1 << 16
    }

    /// A spec family: flat (suffix-less) names plus sharded families
    /// accepting `base:N` and a bare base (defaulting to
    /// [`DEFAULT_SHARDS`]).
    pub struct SpecTable<K: 'static> {
        /// One entry per suffix-less kind.
        pub flat: &'static [(&'static str, K)],
        /// Sharded families: every accepted base alias plus the
        /// constructor applied to the parsed shard count.
        pub sharded: &'static [(&'static [&'static str], fn(u32) -> K)],
    }

    impl<K: Copy> SpecTable<K> {
        /// Parse `name` or `base:N`. Flat names win over bare sharded
        /// aliases, so `inc-resize-rh-map` is the flat growable table
        /// while `inc-resize-rh-map:8` is its sharded composition.
        pub fn parse(&self, s: &str) -> Option<K> {
            if let Some((base, n)) = s.split_once(':') {
                let shards: u32 = n.parse().ok()?;
                if !valid_shards(shards) {
                    return None;
                }
                return self.family(base).map(|make| make(shards));
            }
            if let Some(&(_, k)) = self.flat.iter().find(|(n, _)| *n == s) {
                return Some(k);
            }
            self.family(s).map(|make| make(DEFAULT_SHARDS))
        }

        fn family(&self, base: &str) -> Option<fn(u32) -> K> {
            self.sharded
                .iter()
                .find(|(aliases, _)| aliases.contains(&base))
                .map(|&(_, make)| make)
        }
    }
}

/// Which map to construct — the spec type consumed by the CLI, the
/// `fig14_batching` experiment, and the kv service example; the
/// key→value parallel of [`TableKind`].
///
/// CLI syntax matches `TableKind`: flat names (`kcas-rh-map`,
/// `locked-lp-map`) and sharded names with a `:N` power-of-two
/// shard-count suffix (`sharded-kcas-rh-map:16`); a bare sharded name
/// defaults to 4 shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapKind {
    /// [`kcas_rh_map::KCasRobinHoodMap`] — the paper's algorithm, lifted
    /// to key→value pairs.
    KCasRhMap,
    /// [`locked_lp::LockedLpMap`] — blocking linear-probing baseline.
    LockedLpMap,
    /// [`resizable::ResizableRobinHoodMap`] — growable key→value table
    /// (non-blocking two-generation migration, spec `inc-resize-rh-map`).
    IncResizableRhMap,
    /// [`sharded::Sharded`]`<KCasRobinHoodMap>` with `shards` shards.
    ShardedKCasRhMap { shards: u32 },
    /// [`sharded::Sharded`]`<LockedLpMap>` with `shards` shards.
    ShardedLockedLpMap { shards: u32 },
    /// [`sharded::Sharded`]`<ResizableRobinHoodMap>` with `shards`
    /// shards (spec `inc-resize-rh-map:N`).
    ShardedIncResizableRhMap { shards: u32 },
}

impl MapKind {
    /// Every buildable kind, including the sharding sweep — the
    /// exhaustive list the test tier iterates.
    pub fn all() -> Vec<MapKind> {
        let mut v = vec![
            MapKind::KCasRhMap,
            MapKind::LockedLpMap,
            MapKind::IncResizableRhMap,
        ];
        for shards in TableKind::SHARD_SWEEP {
            v.push(MapKind::ShardedKCasRhMap { shards });
            v.push(MapKind::ShardedLockedLpMap { shards });
            v.push(MapKind::ShardedIncResizableRhMap { shards });
        }
        v
    }

    pub fn name(&self) -> String {
        match self {
            MapKind::KCasRhMap => "kcas-rh-map".into(),
            MapKind::LockedLpMap => "locked-lp-map".into(),
            MapKind::IncResizableRhMap => "inc-resize-rh-map".into(),
            MapKind::ShardedKCasRhMap { shards } => {
                format!("sharded-kcas-rh-map:{shards}")
            }
            MapKind::ShardedLockedLpMap { shards } => {
                format!("sharded-locked-lp-map:{shards}")
            }
            MapKind::ShardedIncResizableRhMap { shards } => {
                format!("inc-resize-rh-map:{shards}")
            }
        }
    }

    /// Display name (fig14 rows, service banners).
    pub fn display(&self) -> String {
        match self {
            MapKind::KCasRhMap => "K-CAS RH Map".into(),
            MapKind::LockedLpMap => "Locked LP Map".into(),
            MapKind::IncResizableRhMap => "Inc-Resize RH Map".into(),
            MapKind::ShardedKCasRhMap { shards } => {
                format!("Sharded K-CAS RH Map x{shards}")
            }
            MapKind::ShardedLockedLpMap { shards } => {
                format!("Sharded Locked LP Map x{shards}")
            }
            MapKind::ShardedIncResizableRhMap { shards } => {
                format!("Sharded Inc-Resize RH Map x{shards}")
            }
        }
    }

    /// The shared name table behind [`MapKind::parse`].
    pub const SPECS: spec::SpecTable<MapKind> = spec::SpecTable {
        flat: &[
            ("kcas-rh-map", MapKind::KCasRhMap),
            ("locked-lp-map", MapKind::LockedLpMap),
            ("inc-resize-rh-map", MapKind::IncResizableRhMap),
        ],
        sharded: &[
            (&["sharded-kcas-rh-map"], |shards| {
                MapKind::ShardedKCasRhMap { shards }
            }),
            (&["sharded-locked-lp-map"], |shards| {
                MapKind::ShardedLockedLpMap { shards }
            }),
            (&["inc-resize-rh-map", "sharded-inc-resize-rh-map"], |shards| {
                MapKind::ShardedIncResizableRhMap { shards }
            }),
        ],
    };

    /// Parse a CLI map spec (see type docs for the syntax).
    pub fn parse(s: &str) -> Option<MapKind> {
        Self::SPECS.parse(s)
    }

    /// Construct a map with `1 << size_log2` buckets in total; sharded
    /// kinds split that capacity evenly across their shards.
    pub fn build(&self, size_log2: u32) -> Box<dyn ConcurrentMap> {
        match *self {
            MapKind::KCasRhMap => {
                Box::new(kcas_rh_map::KCasRobinHoodMap::new(size_log2))
            }
            MapKind::LockedLpMap => {
                Box::new(locked_lp::LockedLpMap::new(size_log2))
            }
            MapKind::IncResizableRhMap => {
                Box::new(resizable::ResizableRobinHoodMap::new(size_log2))
            }
            MapKind::ShardedKCasRhMap { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(
                    sharded::Sharded::<kcas_rh_map::KCasRobinHoodMap>::kcas_map(
                        size_log2,
                        shards.trailing_zeros(),
                    ),
                )
            }
            MapKind::ShardedLockedLpMap { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(
                    sharded::Sharded::<locked_lp::LockedLpMap>::locked_lp_map(
                        size_log2,
                        shards.trailing_zeros(),
                    ),
                )
            }
            MapKind::ShardedIncResizableRhMap { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(sharded::Sharded::<
                    resizable::ResizableRobinHoodMap,
                >::inc_resizable_map(
                    size_log2, shards.trailing_zeros()
                ))
            }
        }
    }
}

/// Which table to construct — the spec type consumed by the CLI,
/// harness, coordinator, and benches.
///
/// Flat variants name a single table; the `Sharded*` variants carry the
/// shard count (a power of two), which is why `name`/`display` return
/// owned strings and the CLI syntax grew a `:N` suffix
/// (`sharded-kcas-rh:16`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableKind {
    KCasRobinHood,
    TxRobinHood,
    Hopscotch,
    LockFreeLp,
    LockedLp,
    Michael,
    SerialRobinHood,
    /// Epoch-wrapped growable K-CAS Robin Hood — the blocking
    /// (quiescing) baseline ([`resizable::QuiescingResize`]).
    ResizableRobinHood,
    /// Non-blocking growable K-CAS Robin Hood: cooperative
    /// two-generation migration ([`resizable::IncResizableRobinHood`]).
    IncResizableRh,
    /// [`sharded::Sharded`]`<KCasRobinHood>` with `shards` shards.
    ShardedKCasRh { shards: u32 },
    /// [`sharded::Sharded`]`<QuiescingResize>` with `shards` shards.
    ShardedResizableRh { shards: u32 },
    /// [`sharded::Sharded`]`<IncResizableRobinHood>` with `shards`
    /// shards (spec `inc-resize-rh:N`).
    ShardedIncResizableRh { shards: u32 },
}

impl TableKind {
    pub const ALL_CONCURRENT: [TableKind; 6] = [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::Hopscotch,
        TableKind::LockFreeLp,
        TableKind::LockedLp,
        TableKind::Michael,
    ];

    /// Shard counts exercised by tests and the fig13 sweep.
    pub const SHARD_SWEEP: [u32; 3] = [1, 4, 16];

    /// Every buildable kind, including the sharding sweep — the
    /// exhaustive list the test tier iterates.
    pub fn all() -> Vec<TableKind> {
        let mut v = vec![
            TableKind::KCasRobinHood,
            TableKind::TxRobinHood,
            TableKind::Hopscotch,
            TableKind::LockFreeLp,
            TableKind::LockedLp,
            TableKind::Michael,
            TableKind::SerialRobinHood,
            TableKind::ResizableRobinHood,
            TableKind::IncResizableRh,
        ];
        for shards in TableKind::SHARD_SWEEP {
            v.push(TableKind::ShardedKCasRh { shards });
            v.push(TableKind::ShardedResizableRh { shards });
            v.push(TableKind::ShardedIncResizableRh { shards });
        }
        v
    }

    pub fn name(&self) -> String {
        match self {
            TableKind::KCasRobinHood => "kcas-rh".into(),
            TableKind::TxRobinHood => "tx-rh".into(),
            TableKind::Hopscotch => "hopscotch".into(),
            TableKind::LockFreeLp => "lockfree-lp".into(),
            TableKind::LockedLp => "locked-lp".into(),
            TableKind::Michael => "michael".into(),
            TableKind::SerialRobinHood => "serial-rh".into(),
            TableKind::ResizableRobinHood => "resizable-rh".into(),
            TableKind::IncResizableRh => "inc-resize-rh".into(),
            TableKind::ShardedKCasRh { shards } => {
                format!("sharded-kcas-rh:{shards}")
            }
            TableKind::ShardedResizableRh { shards } => {
                format!("sharded-resizable-rh:{shards}")
            }
            TableKind::ShardedIncResizableRh { shards } => {
                format!("inc-resize-rh:{shards}")
            }
        }
    }

    /// Paper display name (Figs. 10-13 / Table 1 rows).
    pub fn display(&self) -> String {
        match self {
            TableKind::KCasRobinHood => "K-CAS Robin Hood".into(),
            TableKind::TxRobinHood => "Transactional RH".into(),
            TableKind::Hopscotch => "Hopscotch Hashing".into(),
            TableKind::LockFreeLp => "Lock-Free LP".into(),
            TableKind::LockedLp => "Locked LP".into(),
            TableKind::Michael => "Maged Michael".into(),
            TableKind::SerialRobinHood => "Serial Robin Hood".into(),
            TableKind::ResizableRobinHood => "Quiescing Resize RH".into(),
            TableKind::IncResizableRh => "Incremental Resize RH".into(),
            TableKind::ShardedKCasRh { shards } => {
                format!("Sharded K-CAS RH x{shards}")
            }
            TableKind::ShardedResizableRh { shards } => {
                format!("Sharded Quiescing RH x{shards}")
            }
            TableKind::ShardedIncResizableRh { shards } => {
                format!("Sharded Inc-Resize RH x{shards}")
            }
        }
    }

    /// The shared name table behind [`TableKind::parse`].
    pub const SPECS: spec::SpecTable<TableKind> = spec::SpecTable {
        flat: &[
            ("kcas-rh", TableKind::KCasRobinHood),
            ("tx-rh", TableKind::TxRobinHood),
            ("hopscotch", TableKind::Hopscotch),
            ("lockfree-lp", TableKind::LockFreeLp),
            ("locked-lp", TableKind::LockedLp),
            ("michael", TableKind::Michael),
            ("serial-rh", TableKind::SerialRobinHood),
            ("resizable-rh", TableKind::ResizableRobinHood),
            ("inc-resize-rh", TableKind::IncResizableRh),
        ],
        sharded: &[
            (&["sharded-kcas-rh"], |shards| {
                TableKind::ShardedKCasRh { shards }
            }),
            (&["sharded-resizable-rh"], |shards| {
                TableKind::ShardedResizableRh { shards }
            }),
            (&["inc-resize-rh", "sharded-inc-resize-rh"], |shards| {
                TableKind::ShardedIncResizableRh { shards }
            }),
        ],
    };

    /// Parse a CLI table spec. Sharded kinds take a `:N` shard-count
    /// suffix (a power of two, at most 2^16 — the facade's limit), e.g.
    /// `sharded-kcas-rh:16`; the bare name defaults to 4 shards.
    pub fn parse(s: &str) -> Option<TableKind> {
        Self::SPECS.parse(s)
    }

    /// Construct a table with `1 << size_log2` buckets in total; sharded
    /// kinds split that capacity evenly across their shards.
    pub fn build(&self, size_log2: u32) -> Box<dyn ConcurrentSet> {
        match *self {
            TableKind::KCasRobinHood => {
                Box::new(kcas_rh::KCasRobinHood::new(size_log2))
            }
            TableKind::TxRobinHood => Box::new(tx_rh::TxRobinHood::new(size_log2)),
            TableKind::Hopscotch => Box::new(hopscotch::Hopscotch::new(size_log2)),
            TableKind::LockFreeLp => {
                Box::new(lockfree_lp::LockFreeLp::new(size_log2))
            }
            TableKind::LockedLp => Box::new(locked_lp::LockedLp::new(size_log2)),
            TableKind::Michael => Box::new(michael::MichaelSet::new(size_log2)),
            TableKind::SerialRobinHood => {
                Box::new(serial_rh::SerialRobinHoodLocked::new(size_log2))
            }
            TableKind::ResizableRobinHood => {
                Box::new(resizable::QuiescingResize::new(size_log2))
            }
            TableKind::IncResizableRh => {
                Box::new(resizable::IncResizableRobinHood::new(size_log2))
            }
            TableKind::ShardedKCasRh { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(sharded::Sharded::<kcas_rh::KCasRobinHood>::kcas(
                    size_log2,
                    shards.trailing_zeros(),
                ))
            }
            TableKind::ShardedResizableRh { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(
                    sharded::Sharded::<resizable::ResizableRobinHood>::resizable(
                        size_log2,
                        shards.trailing_zeros(),
                    ),
                )
            }
            TableKind::ShardedIncResizableRh { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(sharded::Sharded::<
                    resizable::IncResizableRobinHood,
                >::inc_resizable(
                    size_log2, shards.trailing_zeros()
                ))
            }
        }
    }
}

/// Validate a key for the open-addressing tables.
#[inline]
pub(crate) fn check_key(key: u64) {
    assert!(
        key >= 1 && key <= MAX_KEY,
        "key {key} out of range [1, {MAX_KEY}]"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in TableKind::all() {
            assert_eq!(TableKind::parse(&k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(
            TableKind::parse("serial-rh"),
            Some(TableKind::SerialRobinHood)
        );
        assert_eq!(
            TableKind::parse("sharded-kcas-rh:8"),
            Some(TableKind::ShardedKCasRh { shards: 8 })
        );
        assert_eq!(
            TableKind::parse("sharded-kcas-rh"),
            Some(TableKind::ShardedKCasRh { shards: 4 })
        );
        assert_eq!(TableKind::parse("sharded-kcas-rh:3"), None);
        assert_eq!(TableKind::parse("sharded-kcas-rh:0"), None);
        assert_eq!(
            TableKind::parse("inc-resize-rh"),
            Some(TableKind::IncResizableRh)
        );
        assert_eq!(
            TableKind::parse("inc-resize-rh:8"),
            Some(TableKind::ShardedIncResizableRh { shards: 8 })
        );
        assert_eq!(TableKind::parse("inc-resize-rh:3"), None);
        assert_eq!(TableKind::parse("nope"), None);
        assert_eq!(TableKind::parse("nope:4"), None);
    }

    #[test]
    fn map_kind_roundtrip() {
        for k in MapKind::all() {
            assert_eq!(MapKind::parse(&k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(MapKind::parse("kcas-rh-map"), Some(MapKind::KCasRhMap));
        assert_eq!(
            MapKind::parse("sharded-kcas-rh-map:8"),
            Some(MapKind::ShardedKCasRhMap { shards: 8 })
        );
        assert_eq!(
            MapKind::parse("sharded-kcas-rh-map"),
            Some(MapKind::ShardedKCasRhMap { shards: 4 })
        );
        assert_eq!(MapKind::parse("sharded-kcas-rh-map:3"), None);
        assert_eq!(
            MapKind::parse("inc-resize-rh-map"),
            Some(MapKind::IncResizableRhMap)
        );
        assert_eq!(
            MapKind::parse("inc-resize-rh-map:16"),
            Some(MapKind::ShardedIncResizableRhMap { shards: 16 })
        );
        assert_eq!(MapKind::parse("kcas-rh"), None);
        assert_eq!(MapKind::parse("nope:4"), None);
    }

    #[test]
    fn build_all_map_kinds_smoke() {
        for k in MapKind::all() {
            let m = k.build(10);
            assert_eq!(m.get(7), None, "{}", k.name());
            assert_eq!(m.insert(7, 70), None);
            assert_eq!(m.get(7), Some(70));
            assert_eq!(m.insert(7, 71), Some(70), "{}", k.name());
            assert_eq!(m.remove(7), Some(71));
            assert_eq!(m.get(7), None, "{}", k.name());
            assert_eq!(m.remove(7), None);
            assert_eq!(m.capacity(), 1024, "{}", k.name());
            assert_eq!(m.len_quiesced(), 0);
        }
    }

    #[test]
    fn conditional_ops_smoke_all_map_kinds() {
        for k in MapKind::all() {
            let m = k.build(10);
            let n = k.name();
            // All four compare_exchange corners.
            assert_eq!(m.compare_exchange(3, None, None), Ok(()), "{n}");
            assert_eq!(m.compare_exchange(3, Some(1), Some(2)), Err(None));
            assert_eq!(m.compare_exchange(3, None, Some(30)), Ok(()), "{n}");
            assert_eq!(m.compare_exchange(3, None, Some(31)), Err(Some(30)));
            assert_eq!(m.compare_exchange(3, None, None), Err(Some(30)));
            assert_eq!(m.compare_exchange(3, Some(9), Some(31)), Err(Some(30)));
            assert_eq!(m.compare_exchange(3, Some(30), Some(31)), Ok(()), "{n}");
            assert_eq!(m.get(3), Some(31), "{n}");
            assert_eq!(m.compare_exchange(3, Some(30), None), Err(Some(31)));
            assert_eq!(m.compare_exchange(3, Some(31), None), Ok(()), "{n}");
            assert_eq!(m.get(3), None, "{n}");
            // get_or_insert never overwrites.
            assert_eq!(m.get_or_insert(5, 50), None, "{n}");
            assert_eq!(m.get_or_insert(5, 51), Some(50), "{n}");
            assert_eq!(m.get(5), Some(50), "{n}");
            // fetch_add treats a missing key as 0.
            assert_eq!(m.fetch_add(8, 4), None, "{n}");
            assert_eq!(m.fetch_add(8, 3), Some(4), "{n}");
            assert_eq!(m.get(8), Some(7), "{n}");
            assert_eq!(m.len_quiesced(), 2, "{n}");
        }
    }

    #[test]
    fn map_reply_value_extraction() {
        assert_eq!(MapReply::CmpEx(Ok(())).value(), None);
        assert_eq!(MapReply::CmpEx(Err(Some(4))).value(), Some(4));
        assert_eq!(MapReply::CmpEx(Err(None)).value(), None);
        assert_eq!(MapReply::Existing(Some(1)).value(), Some(1));
        assert_eq!(MapReply::Added(None).value(), None);
        assert_eq!(MapOp::CmpEx(9, None, Some(1)).key(), 9);
        assert_eq!(MapOp::GetOrInsert(9, 1).key(), 9);
        assert_eq!(MapOp::FetchAdd(9, 1).key(), 9);
    }

    #[test]
    fn spec_parse_name_roundtrip_property() {
        // Property: for every kind in all(), parse(name()) == kind and
        // the reparse renders the same canonical name — both enums go
        // through the shared spec helper now, so one table drives both.
        for k in TableKind::all() {
            let n = k.name();
            let p = TableKind::parse(&n).unwrap_or_else(|| panic!("{n}"));
            assert_eq!(p, k, "{n}");
            assert_eq!(p.name(), n);
        }
        for k in MapKind::all() {
            let n = k.name();
            let p = MapKind::parse(&n).unwrap_or_else(|| panic!("{n}"));
            assert_eq!(p, k, "{n}");
            assert_eq!(p.name(), n);
        }
        // Shard-suffix grammar, driven by the shared validator: every
        // power of two up to 2^16 parses; zero, non-powers, and
        // overflow are rejected by both enums identically.
        for log2 in 0..=16u32 {
            let shards = 1u32 << log2;
            assert!(spec::valid_shards(shards));
            assert_eq!(
                TableKind::parse(&format!("sharded-kcas-rh:{shards}")),
                Some(TableKind::ShardedKCasRh { shards })
            );
            assert_eq!(
                MapKind::parse(&format!("sharded-kcas-rh-map:{shards}")),
                Some(MapKind::ShardedKCasRhMap { shards })
            );
        }
        for bad in [0u32, 3, 6, 12, (1 << 16) + 1, 1 << 17] {
            assert!(!spec::valid_shards(bad), "{bad}");
            assert_eq!(
                TableKind::parse(&format!("sharded-kcas-rh:{bad}")),
                None
            );
            assert_eq!(
                MapKind::parse(&format!("sharded-kcas-rh-map:{bad}")),
                None
            );
        }
        // Flat names win over their sharded alias; bare sharded names
        // default to DEFAULT_SHARDS.
        assert_eq!(
            MapKind::parse("inc-resize-rh-map"),
            Some(MapKind::IncResizableRhMap)
        );
        assert_eq!(
            MapKind::parse("sharded-inc-resize-rh-map"),
            Some(MapKind::ShardedIncResizableRhMap {
                shards: spec::DEFAULT_SHARDS
            })
        );
        assert_eq!(
            TableKind::parse("sharded-inc-resize-rh"),
            Some(TableKind::ShardedIncResizableRh {
                shards: spec::DEFAULT_SHARDS
            })
        );
    }

    #[test]
    fn apply_txn_defaults_to_unsupported() {
        // A minimal non-transactional impl keeps the trait default and
        // stays conformant by reporting Unsupported.
        struct NoTxn;
        impl ConcurrentMap for NoTxn {
            fn get(&self, _: u64) -> Option<u64> {
                None
            }
            fn insert(&self, _: u64, _: u64) -> Option<u64> {
                None
            }
            fn remove(&self, _: u64) -> Option<u64> {
                None
            }
            fn compare_exchange(
                &self,
                _: u64,
                _: Option<u64>,
                _: Option<u64>,
            ) -> Result<(), Option<u64>> {
                Ok(())
            }
            fn get_or_insert(&self, _: u64, _: u64) -> Option<u64> {
                None
            }
            fn fetch_add(&self, _: u64, _: u64) -> Option<u64> {
                None
            }
            fn name(&self) -> &'static str {
                "no-txn"
            }
            fn capacity(&self) -> usize {
                0
            }
            fn len_quiesced(&self) -> usize {
                0
            }
        }
        assert_eq!(
            NoTxn.apply_txn(&[MapOp::Get(1)]),
            Err(MapError::Unsupported)
        );
        assert_eq!(MapError::TxnConflict.to_string(), "transaction conflict");
    }

    #[test]
    fn build_all_kinds_smoke() {
        for k in TableKind::all() {
            let t = k.build(10);
            assert!(t.add(7), "{}", k.name());
            assert!(t.contains(7));
            assert!(!t.add(7));
            assert!(t.remove(7));
            assert!(!t.contains(7), "{}", k.name());
            assert!(!t.remove(7));
            assert_eq!(t.capacity(), 1024, "{}", k.name());
        }
    }
}
