//! The hash tables: the paper's contribution, all its competitors, and
//! the scaling compositions (resizable epoch wrapper, sharded facade).
//!
//! Every table implements [`ConcurrentSet`] over 62-bit integer keys
//! (the paper benchmarks integer *sets*: `Add/Contains/Remove(key)`).
//! Key 0 is reserved as Nil in the open-addressing tables; the public
//! API therefore requires `1 <= key <= MAX_KEY`.

pub mod hopscotch;
pub mod kcas_rh;
pub mod kcas_rh_map;
pub mod lockfree_lp;
pub mod locked_lp;
pub mod michael;
pub mod resizable;
pub mod serial_rh;
pub mod sharded;
pub mod tx_rh;

/// Largest legal key (62-bit, minus the reserved Nil/Tombstone values).
pub const MAX_KEY: u64 = (1 << 62) - 3;

/// A concurrent set of integer keys — the paper's benchmark interface.
pub trait ConcurrentSet: Send + Sync {
    /// True iff `key` is in the set (paper Fig. 7).
    fn contains(&self, key: u64) -> bool;
    /// Insert; false if already present (paper Fig. 8).
    fn add(&self, key: u64) -> bool;
    /// Delete; false if not present (paper Fig. 9).
    fn remove(&self, key: u64) -> bool;

    /// Short stable name used in benchmark tables.
    fn name(&self) -> &'static str;

    /// Number of buckets (chained tables report the bucket-array length).
    fn capacity(&self) -> usize;

    /// Distance-from-home-bucket per bucket, -1 for empty. Only valid
    /// when quiesced (no concurrent writers); used for invariant checks
    /// and the probe-statistics analytics. Chained tables return empty;
    /// sharded tables concatenate per-shard snapshots in shard order.
    fn dfb_snapshot(&self) -> Vec<i32> {
        Vec::new()
    }

    /// Exact element count when quiesced.
    fn len_quiesced(&self) -> usize;
}

/// Which table to construct — the spec type consumed by the CLI,
/// harness, coordinator, and benches.
///
/// Flat variants name a single table; the `Sharded*` variants carry the
/// shard count (a power of two), which is why `name`/`display` return
/// owned strings and the CLI syntax grew a `:N` suffix
/// (`sharded-kcas-rh:16`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableKind {
    KCasRobinHood,
    TxRobinHood,
    Hopscotch,
    LockFreeLp,
    LockedLp,
    Michael,
    SerialRobinHood,
    /// Epoch-wrapped growable K-CAS Robin Hood ([`resizable`]).
    ResizableRobinHood,
    /// [`sharded::Sharded`]`<KCasRobinHood>` with `shards` shards.
    ShardedKCasRh { shards: u32 },
    /// [`sharded::Sharded`]`<ResizableRobinHood>` with `shards` shards.
    ShardedResizableRh { shards: u32 },
}

impl TableKind {
    pub const ALL_CONCURRENT: [TableKind; 6] = [
        TableKind::KCasRobinHood,
        TableKind::TxRobinHood,
        TableKind::Hopscotch,
        TableKind::LockFreeLp,
        TableKind::LockedLp,
        TableKind::Michael,
    ];

    /// Shard counts exercised by tests and the fig13 sweep.
    pub const SHARD_SWEEP: [u32; 3] = [1, 4, 16];

    /// Every buildable kind, including the sharding sweep — the
    /// exhaustive list the test tier iterates.
    pub fn all() -> Vec<TableKind> {
        let mut v = vec![
            TableKind::KCasRobinHood,
            TableKind::TxRobinHood,
            TableKind::Hopscotch,
            TableKind::LockFreeLp,
            TableKind::LockedLp,
            TableKind::Michael,
            TableKind::SerialRobinHood,
            TableKind::ResizableRobinHood,
        ];
        for shards in TableKind::SHARD_SWEEP {
            v.push(TableKind::ShardedKCasRh { shards });
            v.push(TableKind::ShardedResizableRh { shards });
        }
        v
    }

    pub fn name(&self) -> String {
        match self {
            TableKind::KCasRobinHood => "kcas-rh".into(),
            TableKind::TxRobinHood => "tx-rh".into(),
            TableKind::Hopscotch => "hopscotch".into(),
            TableKind::LockFreeLp => "lockfree-lp".into(),
            TableKind::LockedLp => "locked-lp".into(),
            TableKind::Michael => "michael".into(),
            TableKind::SerialRobinHood => "serial-rh".into(),
            TableKind::ResizableRobinHood => "resizable-rh".into(),
            TableKind::ShardedKCasRh { shards } => {
                format!("sharded-kcas-rh:{shards}")
            }
            TableKind::ShardedResizableRh { shards } => {
                format!("sharded-resizable-rh:{shards}")
            }
        }
    }

    /// Paper display name (Figs. 10-13 / Table 1 rows).
    pub fn display(&self) -> String {
        match self {
            TableKind::KCasRobinHood => "K-CAS Robin Hood".into(),
            TableKind::TxRobinHood => "Transactional RH".into(),
            TableKind::Hopscotch => "Hopscotch Hashing".into(),
            TableKind::LockFreeLp => "Lock-Free LP".into(),
            TableKind::LockedLp => "Locked LP".into(),
            TableKind::Michael => "Maged Michael".into(),
            TableKind::SerialRobinHood => "Serial Robin Hood".into(),
            TableKind::ResizableRobinHood => "Resizable RH".into(),
            TableKind::ShardedKCasRh { shards } => {
                format!("Sharded K-CAS RH x{shards}")
            }
            TableKind::ShardedResizableRh { shards } => {
                format!("Sharded Resizable RH x{shards}")
            }
        }
    }

    /// Parse a CLI table spec. Sharded kinds take a `:N` shard-count
    /// suffix (a power of two, at most 2^16 — the facade's limit), e.g.
    /// `sharded-kcas-rh:16`; the bare name defaults to 4 shards.
    pub fn parse(s: &str) -> Option<TableKind> {
        if let Some((base, n)) = s.split_once(':') {
            let shards: u32 = n.parse().ok()?;
            if !shards.is_power_of_two() || shards > 1 << 16 {
                return None;
            }
            return match base {
                "sharded-kcas-rh" => {
                    Some(TableKind::ShardedKCasRh { shards })
                }
                "sharded-resizable-rh" => {
                    Some(TableKind::ShardedResizableRh { shards })
                }
                _ => None,
            };
        }
        match s {
            "kcas-rh" => Some(TableKind::KCasRobinHood),
            "tx-rh" => Some(TableKind::TxRobinHood),
            "hopscotch" => Some(TableKind::Hopscotch),
            "lockfree-lp" => Some(TableKind::LockFreeLp),
            "locked-lp" => Some(TableKind::LockedLp),
            "michael" => Some(TableKind::Michael),
            "serial-rh" => Some(TableKind::SerialRobinHood),
            "resizable-rh" => Some(TableKind::ResizableRobinHood),
            "sharded-kcas-rh" => Some(TableKind::ShardedKCasRh { shards: 4 }),
            "sharded-resizable-rh" => {
                Some(TableKind::ShardedResizableRh { shards: 4 })
            }
            _ => None,
        }
    }

    /// Construct a table with `1 << size_log2` buckets in total; sharded
    /// kinds split that capacity evenly across their shards.
    pub fn build(&self, size_log2: u32) -> Box<dyn ConcurrentSet> {
        match *self {
            TableKind::KCasRobinHood => {
                Box::new(kcas_rh::KCasRobinHood::new(size_log2))
            }
            TableKind::TxRobinHood => Box::new(tx_rh::TxRobinHood::new(size_log2)),
            TableKind::Hopscotch => Box::new(hopscotch::Hopscotch::new(size_log2)),
            TableKind::LockFreeLp => {
                Box::new(lockfree_lp::LockFreeLp::new(size_log2))
            }
            TableKind::LockedLp => Box::new(locked_lp::LockedLp::new(size_log2)),
            TableKind::Michael => Box::new(michael::MichaelSet::new(size_log2)),
            TableKind::SerialRobinHood => {
                Box::new(serial_rh::SerialRobinHoodLocked::new(size_log2))
            }
            TableKind::ResizableRobinHood => {
                Box::new(resizable::ResizableRobinHood::new(size_log2))
            }
            TableKind::ShardedKCasRh { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(sharded::Sharded::<kcas_rh::KCasRobinHood>::kcas(
                    size_log2,
                    shards.trailing_zeros(),
                ))
            }
            TableKind::ShardedResizableRh { shards } => {
                assert!(shards.is_power_of_two(), "shards must be 2^k");
                Box::new(
                    sharded::Sharded::<resizable::ResizableRobinHood>::resizable(
                        size_log2,
                        shards.trailing_zeros(),
                    ),
                )
            }
        }
    }
}

/// Validate a key for the open-addressing tables.
#[inline]
pub(crate) fn check_key(key: u64) {
    assert!(
        key >= 1 && key <= MAX_KEY,
        "key {key} out of range [1, {MAX_KEY}]"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in TableKind::all() {
            assert_eq!(TableKind::parse(&k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(
            TableKind::parse("serial-rh"),
            Some(TableKind::SerialRobinHood)
        );
        assert_eq!(
            TableKind::parse("sharded-kcas-rh:8"),
            Some(TableKind::ShardedKCasRh { shards: 8 })
        );
        assert_eq!(
            TableKind::parse("sharded-kcas-rh"),
            Some(TableKind::ShardedKCasRh { shards: 4 })
        );
        assert_eq!(TableKind::parse("sharded-kcas-rh:3"), None);
        assert_eq!(TableKind::parse("sharded-kcas-rh:0"), None);
        assert_eq!(TableKind::parse("nope"), None);
        assert_eq!(TableKind::parse("nope:4"), None);
    }

    #[test]
    fn build_all_kinds_smoke() {
        for k in TableKind::all() {
            let t = k.build(10);
            assert!(t.add(7), "{}", k.name());
            assert!(t.contains(7));
            assert!(!t.add(7));
            assert!(t.remove(7));
            assert!(!t.contains(7), "{}", k.name());
            assert!(!t.remove(7));
            assert_eq!(t.capacity(), 1024, "{}", k.name());
        }
    }
}
