//! **K-CAS Robin Hood map** — key→value extension of the paper's set.
//!
//! The paper evaluates a set (`Add/Contains/Remove(key)`); this module
//! extends the same algorithm to a map, which is what Rust's standard
//! library actually shipped Robin Hood hashing as (§2.2). Buckets are
//! *pairs* of K-CAS words (key word + value word); a displacement chain
//! moves both words of each displaced bucket in the **same K-CAS
//! descriptor**, so readers never observe a key paired with another
//! key's value:
//!
//! * `get` records shard timestamps like the set's `contains`; a hit
//!   additionally re-validates the shard timestamp after reading the
//!   value word, because the key→value pairing (not just membership)
//!   must be consistent at the linearization point.
//! * `insert` over an existing key swings only the value word (single
//!   K-CAS word CAS — no relocation, no timestamp bump needed).
//! * `remove` backward-shifts both words of each shifted bucket.
//!
//! Values are 62-bit (`<= kcas::MAX_VALUE`); store indices/handles for
//! larger payloads.
//!
//! ## Conditional ops: one K-CAS each
//!
//! The conditional-first surface (`compare_exchange`, `get_or_insert`,
//! `fetch_add`) rides the same descriptor machinery — each attempt is
//! one probe plus **at most one K-CAS**, never a lock and never a
//! retry loop around separate `get`+`insert` calls:
//!
//! * *insert-if-absent* (`compare_exchange(k, None, Some(v))`,
//!   `get_or_insert`) reuses the insert probe; the commit descriptor's
//!   probed-shard timestamp guards make "the key was absent along the
//!   whole probe path" part of the atomic step, while a present key is
//!   reported via a timestamp-validated pair read with no K-CAS at all.
//! * *swap-if-equal* (`compare_exchange(k, Some(e), Some(v))`) commits
//!   `{key word: k→k, value word: e→v}` — the key word guard pins the
//!   pairing, the value word is simultaneously the compare and the
//!   write.
//! * *remove-if-equal* (`compare_exchange(k, Some(e), None)`) is the
//!   backward-shift chain whose first chain link already carries the
//!   observed value: the expected value is a free guard.
//! * `fetch_add` swings the value word `v → (v + delta) mod 2^62` under
//!   the key word guard, inserting `delta` (absent keys count as 0)
//!   through the insert-if-absent path otherwise.
//!
//! The write paths carry the same descriptor guards as the set (probed
//! shard timestamp guards on `insert`, a chain-terminator guard on
//! `remove` — see `kcas_rh`'s module docs), and the same migration
//! marks: only the *key* word of a bucket is frozen
//! (`FROZEN_TOMB`/`FROZEN_EMPTY` from `kcas_rh`); the value word of a
//! frozen bucket is dead. A generation transfer moves the `(key,
//! value)` pair into the next table and tombstones the source key word
//! in one K-CAS, guarding the source value word so the pair cannot tear
//! mid-transfer. [`super::resizable::ResizableRobinHoodMap`] drives
//! these entry points.

use std::cell::RefCell;

use crate::util::pad::CachePadded;

use super::kcas_rh::{is_frozen, FROZEN_EMPTY, FROZEN_TOMB};
use crate::util::metrics::metrics;
use super::txn::{self, TxnScratch};
use super::{check_key, ConcurrentMap, MapError, MapOp, MapReply, TxnError};
use crate::kcas::{OpBuilder, Word};
use crate::util::hash::{dfb, home_bucket, splitmix64};

const NIL: u64 = 0;

/// Outcome of a frozen-aware lookup ([`KCasRobinHoodMap::get_mig`]).
pub(crate) enum ProbeVal {
    /// Live in this generation, paired with this value.
    Found(u64),
    /// Definitive miss (no frozen bucket crossed; timestamp-validated).
    Absent,
    /// Timestamp-validated miss here, but the probe crossed frozen
    /// buckets — the key may live in the next generation.
    FrozenMiss,
}

/// One attempt of a write path: probe + (at most) one K-CAS.
enum Attempt {
    /// Committed; payload = previous value (insert) / removed value.
    Done(Option<u64>),
    /// Seeded (transfer) insert found the key already present in the
    /// target; nothing was committed.
    Present,
    /// Conditional op found the key present with this value (a
    /// timestamp-validated pair read); nothing was committed.
    Fetched(u64),
    /// Lost a race; re-probe.
    Raced,
}

/// What an insert-shaped probe does when it finds `key` already
/// present — the dispatch point that lets one probe/displacement/guard
/// engine serve `insert`, `get_or_insert`, insert-if-absent, and
/// `fetch_add`. (All modes insert on a miss.)
#[derive(Clone, Copy)]
enum OnExisting {
    /// Plain `insert`: swing the value word under a key-word guard.
    Overwrite,
    /// `get_or_insert` / insert-if-absent: report the validated value,
    /// commit nothing.
    Fetch,
    /// `fetch_add`: swing the value word to `v + delta` (wrapping in
    /// the 62-bit domain) under a key-word guard.
    Add(u64),
}

/// Unwrap a conditional-op result in a standalone (never-frozen)
/// table; only the migration wrappers ever see `Err(MapError::Frozen)`.
fn live<R>(r: Result<R, MapError>) -> R {
    match r {
        Ok(r) => r,
        Err(e) => unreachable!("standalone table error: {e}"),
    }
}

struct Scratch {
    op: OpBuilder,
    seen: Vec<(usize, u64)>,
    bump: Vec<(usize, u64)>,
    /// (key, value) chain observed during remove's shift scan.
    chain: Vec<(u64, u64)>,
    /// `(shard, first-seen timestamp, displaced-here)` along an insert
    /// probe (bump displaced shards, guard probed-over shards).
    guard: Vec<(usize, u64, bool)>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch {
        op: OpBuilder::new(),
        seen: Vec::with_capacity(64),
        bump: Vec::with_capacity(64),
        chain: Vec::with_capacity(64),
        guard: Vec::with_capacity(64),
    });
}

/// Key→value Robin Hood hash map over K-CAS words.
pub struct KCasRobinHoodMap {
    keys: Box<[Word]>,
    vals: Box<[Word]>,
    ts: Box<[CachePadded<Word>]>,
    mask: u64,
    ts_shard_log2: u32,
}

impl KCasRobinHoodMap {
    pub fn new(size_log2: u32) -> Self {
        let ts_shard_log2 = super::kcas_rh::default_shard_log2(size_log2);
        let size = 1usize << size_log2;
        let shards = (size >> ts_shard_log2).max(1);
        Self {
            keys: (0..size).map(|_| Word::new(NIL)).collect(),
            vals: (0..size).map(|_| Word::new(0)).collect(),
            ts: (0..shards).map(|_| CachePadded::new(Word::new(0))).collect(),
            mask: (size - 1) as u64,
            ts_shard_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn shard_of(&self, i: usize) -> usize {
        (i >> self.ts_shard_log2) & (self.ts.len() - 1)
    }

    #[inline]
    fn dist(&self, key: u64, i: usize) -> u64 {
        dfb(home_bucket(key, self.mask), i, self.mask)
    }

    /// Look up `key`. Linearizes at a timestamp-validated point, so the
    /// returned value is the one paired with the key at that instant.
    pub fn get(&self, key: u64) -> Option<u64> {
        check_key(key);
        let home = home_bucket(key, self.mask);
        SCRATCH.with(|s| self.get_in(&mut s.borrow_mut(), home, key))
    }

    /// `get` body against an already-borrowed scratch (the batch path
    /// borrows the thread-local once for a whole batch).
    fn get_in(&self, scratch: &mut Scratch, home: usize, key: u64) -> Option<u64> {
        {
            let seen = &mut scratch.seen;
            'retry: loop {
                seen.clear();
                let mut i = home;
                let mut cur_dist = 0u64;
                let mut hit: Option<u64> = None;
                loop {
                    let shard = self.shard_of(i);
                    if seen.last().map(|&(x, _)| x) != Some(shard) {
                        seen.push((shard, self.ts[shard].read()));
                    }
                    let cur = self.keys[i].read();
                    if cur == key {
                        // Read the paired value, then re-validate the
                        // shard so the pairing is atomic.
                        let v = self.vals[i].read();
                        let (sh, tv) = *seen.last().unwrap();
                        if self.ts[sh].read() != tv {
                            continue 'retry;
                        }
                        hit = Some(v);
                        break;
                    }
                    if cur == NIL || self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break;
                    }
                }
                if hit.is_some() {
                    metrics().probe_len_read.record(cur_dist + 1);
                    return hit;
                }
                for &(shard, v) in seen.iter() {
                    if self.ts[shard].read() != v {
                        continue 'retry;
                    }
                }
                metrics().probe_len_read.record(cur_dist + 1);
                return None;
            }
        }
    }

    /// Insert or update; returns the previous value if the key existed.
    pub fn insert(&self, key: u64, value: u64) -> Option<u64> {
        check_key(key);
        let home = home_bucket(key, self.mask);
        SCRATCH.with(|s| self.insert_in(&mut s.borrow_mut(), home, key, value))
    }

    fn insert_in(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        value: u64,
    ) -> Option<u64> {
        loop {
            match self.try_insert_one(
                scratch,
                home,
                key,
                value,
                None,
                OnExisting::Overwrite,
            ) {
                Ok(Attempt::Done(prev)) => return prev,
                Ok(Attempt::Raced) => continue,
                Ok(Attempt::Present) | Ok(Attempt::Fetched(_)) => {
                    unreachable!("overwrite insert always commits on a hit")
                }
                Err(e) => {
                    unreachable!("standalone table error: {e}")
                }
            }
        }
    }

    /// One full insert-shaped attempt: probe, build the
    /// pair-displacement descriptor, execute (at most) one K-CAS.
    /// `seed` is the generation-transfer hook: `(src key word, src key,
    /// src val word, src val)` — the source key is tombstoned and the
    /// source value guarded in the same descriptor, so a pair moves
    /// between generations atomically. `on_existing` picks what a hit
    /// on a live `key` does (overwrite / fetch / add) — see
    /// [`OnExisting`]; misses always insert.
    fn try_insert_one(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        value: u64,
        seed: Option<(&Word, u64, &Word, u64)>,
        on_existing: OnExisting,
    ) -> Result<Attempt, MapError> {
        assert!(value <= crate::kcas::MAX_VALUE);
        scratch.op.clear();
        scratch.guard.clear();
        let mut active_key = key;
        let mut active_val = value;
        let mut active_dist = 0u64;
        let mut i = home;
        let mut probes = 0usize;
        let mut displaced = 0u64;
        loop {
            assert!(probes <= self.size(), "map is full");
            probes += 1;
            let shard = self.shard_of(i);
            let ts_val = self.ts[shard].read();
            let cur = self.keys[i].read();
            if is_frozen(cur) {
                return Err(MapError::Frozen);
            }
            if cur == NIL {
                scratch.op.push(&self.keys[i], NIL, active_key);
                scratch.op.push(&self.vals[i], self.vals[i].read(), active_val);
                for &(sh, v, displaced) in scratch.guard.iter() {
                    scratch.op.push(&self.ts[sh], v, v + u64::from(displaced));
                }
                if let Some((kw, kv, vw, vv)) = seed {
                    scratch.op.push(kw, kv, FROZEN_TOMB);
                    scratch.op.push(vw, vv, vv);
                }
                metrics().probe_len_write.record(probes as u64);
                return Ok(if scratch.op.execute() {
                    metrics().rh_displacements.add(displaced);
                    Attempt::Done(None)
                } else {
                    Attempt::Raced
                });
            }
            if cur == key {
                metrics().probe_len_write.record(probes as u64);
                if seed.is_some() {
                    // Transfer found the key already in the target:
                    // report without committing (caller handles).
                    return Ok(Attempt::Present);
                }
                match on_existing {
                    OnExisting::Overwrite => {
                        // Overwrite: value word only; pairing stays.
                        // The key could relocate between the key read
                        // and the value CAS; include the key word as a
                        // guard so the pair swap is atomic.
                        let old = self.vals[i].read();
                        scratch.op.clear();
                        scratch.op.push(&self.keys[i], key, key);
                        scratch.op.push(&self.vals[i], old, value);
                        return Ok(if scratch.op.execute() {
                            Attempt::Done(Some(old))
                        } else {
                            Attempt::Raced
                        });
                    }
                    OnExisting::Fetch => {
                        // Report without committing. Like `get`'s hit:
                        // the value read is paired only if the shard
                        // timestamp stayed put around it.
                        let v = self.vals[i].read();
                        return Ok(if self.ts[shard].read() != ts_val {
                            Attempt::Raced
                        } else {
                            Attempt::Fetched(v)
                        });
                    }
                    OnExisting::Add(delta) => {
                        // Counter bump: compare and write share the
                        // value word; the key word guard pins pairing.
                        let old = self.vals[i].read();
                        let new =
                            old.wrapping_add(delta) & crate::kcas::MAX_VALUE;
                        scratch.op.clear();
                        scratch.op.push(&self.keys[i], key, key);
                        scratch.op.push(&self.vals[i], old, new);
                        return Ok(if scratch.op.execute() {
                            Attempt::Done(Some(old))
                        } else {
                            Attempt::Raced
                        });
                    }
                }
            }
            // Probed over an occupied bucket: guard its shard (see
            // kcas_rh module docs — append-past-fresh-Nil race).
            if scratch.guard.last().map(|&(s2, _, _)| s2) != Some(shard) {
                scratch.guard.push((shard, ts_val, false));
            }
            let cur_d = self.dist(cur, i);
            if cur_d < active_dist {
                // Displace the richer pair; upgrade guard to a bump.
                let cur_val = self.vals[i].read();
                scratch.op.push(&self.keys[i], cur, active_key);
                scratch.op.push(&self.vals[i], cur_val, active_val);
                if let Some(last) = scratch.guard.last_mut() {
                    last.2 = true;
                }
                displaced += 1;
                active_key = cur;
                active_val = cur_val;
                active_dist = cur_d;
            }
            i = (i + 1) & self.mask as usize;
            active_dist += 1;
        }
    }

    /// Remove; returns the value that was present.
    pub fn remove(&self, key: u64) -> Option<u64> {
        check_key(key);
        let home = home_bucket(key, self.mask);
        SCRATCH.with(|s| self.remove_in(&mut s.borrow_mut(), home, key))
    }

    fn remove_in(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
    ) -> Option<u64> {
        loop {
            match self.try_remove_one(scratch, home, key, None) {
                Ok(Attempt::Done(prev)) => return prev,
                Ok(Attempt::Raced) => continue,
                Ok(Attempt::Present) | Ok(Attempt::Fetched(_)) => {
                    unreachable!("unconditional remove never reports")
                }
                Err(e) => {
                    unreachable!("standalone table error: {e}")
                }
            }
        }
    }

    /// One full `remove` attempt: probe, collect the pair shift chain,
    /// execute one K-CAS (chain + terminator guard + timestamp bumps).
    /// With `expect = Some(e)` this is remove-if-equal: a hit whose
    /// (validated) paired value differs from `e` reports
    /// [`Attempt::Fetched`] without committing; on a match the chain's
    /// first link (`e → next`) doubles as the value compare, so the
    /// conditional remove is still one K-CAS.
    fn try_remove_one(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        expect: Option<u64>,
    ) -> Result<Attempt, MapError> {
        scratch.seen.clear();
        scratch.op.clear();
        scratch.bump.clear();
        let mut i = home;
        let mut cur_dist = 0u64;
        let mut hit = false;
        loop {
            let shard = self.shard_of(i);
            if scratch.seen.last().map(|&(x, _)| x) != Some(shard) {
                scratch.seen.push((shard, self.ts[shard].read()));
            }
            let cur = self.keys[i].read();
            if is_frozen(cur) {
                return Err(MapError::Frozen);
            }
            if cur == NIL {
                break;
            }
            if cur == key {
                hit = true;
                break;
            }
            if self.dist(cur, i) < cur_dist {
                break;
            }
            i = (i + 1) & self.mask as usize;
            cur_dist += 1;
            if cur_dist as usize > self.size() {
                break;
            }
        }
        metrics().probe_len_write.record(cur_dist + 1);
        if !hit {
            for &(shard, v) in scratch.seen.iter() {
                if self.ts[shard].read() != v {
                    return Ok(Attempt::Raced);
                }
            }
            return Ok(Attempt::Done(None));
        }
        // Backward shift of (key, value) pairs.
        let removed_val = self.vals[i].read();
        if let Some(e) = expect {
            if removed_val != e {
                // Conditional mismatch: report the witness off a
                // validated pair read (same discipline as `get`'s hit
                // path — the hit bucket's shard timestamp must not
                // have moved across the key+value reads).
                let (sh, tv) = *scratch.seen.last().unwrap();
                debug_assert_eq!(sh, self.shard_of(i));
                return Ok(if self.ts[sh].read() != tv {
                    Attempt::Raced
                } else {
                    Attempt::Fetched(removed_val)
                });
            }
            // Match: fall through to the shift chain. Its first link
            // swaps the value word `e -> next`, so "still equals e at
            // the linearization point" is guarded by the K-CAS itself.
        }
        scratch.chain.clear();
        scratch.chain.push((key, removed_val));
        {
            let shard = self.shard_of(i);
            let v = scratch
                .seen
                .iter()
                .rev()
                .find(|&&(s2, _)| s2 == shard)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| self.ts[shard].read());
            scratch.bump.push((shard, v));
        }
        let mut j = (i + 1) & self.mask as usize;
        let terminator;
        loop {
            let shard = self.shard_of(j);
            let ts_val = self.ts[shard].read();
            let nk = self.keys[j].read();
            if is_frozen(nk) {
                return Err(MapError::Frozen);
            }
            if nk == NIL || self.dist(nk, j) == 0 {
                // Guard the terminator's key word: an insert landing in
                // this Nil (or a displacement enriching this at-home
                // pair) would extend the chain under us.
                terminator = (j, nk);
                break;
            }
            if scratch.bump.last().map(|&(s2, _)| s2) != Some(shard) {
                scratch.bump.push((shard, ts_val));
            }
            scratch.chain.push((nk, self.vals[j].read()));
            j = (j + 1) & self.mask as usize;
            if scratch.chain.len() > self.size() {
                return Ok(Attempt::Raced);
            }
        }
        let Scratch { op, chain, bump, .. } = scratch;
        let mut pos = i;
        for (w, &(ck, cv)) in chain.iter().enumerate() {
            let (nk, nv) = chain.get(w + 1).copied().unwrap_or((NIL, 0));
            op.push(&self.keys[pos], ck, nk);
            op.push(&self.vals[pos], cv, nv);
            pos = (pos + 1) & self.mask as usize;
        }
        op.push(&self.keys[terminator.0], terminator.1, terminator.1);
        for &(sh, v) in bump.iter() {
            op.push(&self.ts[sh], v, v + 1);
        }
        Ok(if op.execute() {
            Attempt::Done(Some(removed_val))
        } else {
            Attempt::Raced
        })
    }

    /// Migration-aware `insert` (surfaces frozen sightings to the
    /// resizable wrapper instead of looping on them).
    pub(crate) fn insert_mig(
        &self,
        h: u64,
        key: u64,
        value: u64,
    ) -> Result<Option<u64>, MapError> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            loop {
                match self.try_insert_one(
                    scratch,
                    home,
                    key,
                    value,
                    None,
                    OnExisting::Overwrite,
                )? {
                    Attempt::Done(prev) => return Ok(prev),
                    Attempt::Raced => continue,
                    Attempt::Present | Attempt::Fetched(_) => {
                        unreachable!("overwrite insert always commits on a hit")
                    }
                }
            }
        })
    }

    /// Migration-aware `remove`.
    pub(crate) fn remove_mig(
        &self,
        h: u64,
        key: u64,
    ) -> Result<Option<u64>, MapError> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            loop {
                match self.try_remove_one(scratch, home, key, None)? {
                    Attempt::Done(prev) => return Ok(prev),
                    Attempt::Raced => continue,
                    Attempt::Present | Attempt::Fetched(_) => {
                        unreachable!("unconditional remove never reports")
                    }
                }
            }
        })
    }

    /// Migration-aware `compare_exchange` (see
    /// [`KCasRobinHoodMap::compare_exchange`] for the corner table).
    pub(crate) fn cmpex_mig(
        &self,
        h: u64,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<Result<(), Option<u64>>, MapError> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            self.cmpex_in(&mut s.borrow_mut(), home, key, expected, new)
        })
    }

    /// Migration-aware `get_or_insert`.
    pub(crate) fn get_or_insert_mig(
        &self,
        h: u64,
        key: u64,
        value: u64,
    ) -> Result<Option<u64>, MapError> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            self.get_or_insert_in(&mut s.borrow_mut(), home, key, value)
        })
    }

    /// Migration-aware `fetch_add`.
    pub(crate) fn fetch_add_mig(
        &self,
        h: u64,
        key: u64,
        delta: u64,
    ) -> Result<Option<u64>, MapError> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            self.fetch_add_in(&mut s.borrow_mut(), home, key, delta)
        })
    }

    /// One frozen-aware, timestamp-validated lookup locating the key's
    /// bucket: `Some((i, v))` = `key` lives at bucket `i` paired with
    /// `v` at the linearization point; `None` = validated miss. Retries
    /// timestamp races internally (no K-CAS is involved); any frozen
    /// sighting aborts to the migration wrapper — this powers the
    /// *write*-shaped conditional corners, which must not fall through
    /// generations the way `get_mig` does.
    fn try_probe_one(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
    ) -> Result<Option<(usize, u64)>, MapError> {
        let seen = &mut scratch.seen;
        'retry: loop {
            seen.clear();
            let mut i = home;
            let mut cur_dist = 0u64;
            loop {
                let shard = self.shard_of(i);
                if seen.last().map(|&(x, _)| x) != Some(shard) {
                    seen.push((shard, self.ts[shard].read()));
                }
                let cur = self.keys[i].read();
                if is_frozen(cur) {
                    return Err(MapError::Frozen);
                }
                if cur == key {
                    let v = self.vals[i].read();
                    let (sh, tv) = *seen.last().unwrap();
                    if self.ts[sh].read() != tv {
                        continue 'retry;
                    }
                    metrics().probe_len_read.record(cur_dist + 1);
                    return Ok(Some((i, v)));
                }
                if cur == NIL || self.dist(cur, i) < cur_dist {
                    break;
                }
                i = (i + 1) & self.mask as usize;
                cur_dist += 1;
                if cur_dist as usize > self.size() {
                    break;
                }
            }
            for &(shard, v) in seen.iter() {
                if self.ts[shard].read() != v {
                    continue 'retry;
                }
            }
            metrics().probe_len_read.record(cur_dist + 1);
            return Ok(None);
        }
    }

    /// `compare_exchange` body against borrowed scratch: dispatches the
    /// four `(expected, new)` corners onto the probe engines. Each loop
    /// iteration is one probe + at most one K-CAS.
    fn cmpex_in(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<Result<(), Option<u64>>, MapError> {
        match (expected, new) {
            // Insert-if-absent: the insert descriptor's timestamp
            // guards atomically assert absence along the probe path.
            (None, Some(v)) => loop {
                match self.try_insert_one(
                    scratch,
                    home,
                    key,
                    v,
                    None,
                    OnExisting::Fetch,
                )? {
                    Attempt::Done(prev) => {
                        debug_assert!(prev.is_none());
                        return Ok(Ok(()));
                    }
                    Attempt::Fetched(cur) => return Ok(Err(Some(cur))),
                    Attempt::Raced => continue,
                    Attempt::Present => unreachable!("unseeded insert"),
                }
            },
            // Remove-if-equal: the shift chain's first link is the
            // value compare.
            (Some(e), None) => loop {
                match self.try_remove_one(scratch, home, key, Some(e))? {
                    Attempt::Done(Some(_)) => return Ok(Ok(())),
                    Attempt::Done(None) => return Ok(Err(None)),
                    Attempt::Fetched(cur) => return Ok(Err(Some(cur))),
                    Attempt::Raced => continue,
                    Attempt::Present => unreachable!("remove never seeds"),
                }
            },
            // Swap-if-equal: {key word k→k, value word e→v} — compare
            // and write share the value word.
            (Some(e), Some(v)) => {
                assert!(v <= crate::kcas::MAX_VALUE);
                loop {
                    match self.try_probe_one(scratch, home, key)? {
                        None => return Ok(Err(None)),
                        Some((_, cur)) if cur != e => {
                            return Ok(Err(Some(cur)));
                        }
                        Some((i, _)) => {
                            scratch.op.clear();
                            scratch.op.push(&self.keys[i], key, key);
                            scratch.op.push(&self.vals[i], e, v);
                            if scratch.op.execute() {
                                return Ok(Ok(()));
                            }
                            // Raced: the pair moved or the value
                            // changed; re-probe.
                        }
                    }
                }
            }
            // Absence assertion: a validated miss, no K-CAS at all.
            (None, None) => match self.try_probe_one(scratch, home, key)? {
                None => Ok(Ok(())),
                Some((_, cur)) => Ok(Err(Some(cur))),
            },
        }
    }

    /// `get_or_insert` body against borrowed scratch.
    fn get_or_insert_in(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        value: u64,
    ) -> Result<Option<u64>, MapError> {
        loop {
            match self.try_insert_one(
                scratch,
                home,
                key,
                value,
                None,
                OnExisting::Fetch,
            )? {
                Attempt::Done(prev) => {
                    debug_assert!(prev.is_none());
                    return Ok(None);
                }
                Attempt::Fetched(v) => return Ok(Some(v)),
                Attempt::Raced => continue,
                Attempt::Present => unreachable!("unseeded insert"),
            }
        }
    }

    /// `fetch_add` body against borrowed scratch.
    fn fetch_add_in(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        delta: u64,
    ) -> Result<Option<u64>, MapError> {
        assert!(delta <= crate::kcas::MAX_VALUE);
        loop {
            match self.try_insert_one(
                scratch,
                home,
                key,
                delta,
                None,
                OnExisting::Add(delta),
            )? {
                Attempt::Done(prev) => return Ok(prev),
                Attempt::Raced => continue,
                Attempt::Fetched(_) => unreachable!("Add mode commits"),
                Attempt::Present => unreachable!("unseeded insert"),
            }
        }
    }

    /// Atomic conditional write; see [`super::ConcurrentMap::compare_exchange`]
    /// for the `(expected, new)` corner table. Every corner is a single
    /// K-CAS (or a pure validated read) per attempt.
    pub fn compare_exchange(
        &self,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        live(self.cmpex_mig(splitmix64(key), key, expected, new))
    }

    /// Insert `value` iff absent; returns the pre-existing value
    /// otherwise (`None` = this call inserted). Never overwrites.
    pub fn get_or_insert(&self, key: u64, value: u64) -> Option<u64> {
        live(self.get_or_insert_mig(splitmix64(key), key, value))
    }

    /// Atomic `value += delta` (wrapping in the 62-bit domain; missing
    /// keys count as 0). Returns the previous value.
    pub fn fetch_add(&self, key: u64, delta: u64) -> Option<u64> {
        live(self.fetch_add_mig(splitmix64(key), key, delta))
    }

    /// Frozen-aware lookup (wrapper fast path and the source-generation
    /// read during migration): `FROZEN_TOMB` is skipped without the
    /// distance cut-off, `FROZEN_EMPTY` terminates like Nil, and a hit
    /// re-validates its shard timestamp after the value read so the
    /// pairing is atomic — exactly like the plain `get`.
    pub(crate) fn get_mig(&self, h: u64, key: u64) -> ProbeVal {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let seen = &mut guard.seen;
            'retry: loop {
                seen.clear();
                let mut saw_frozen = false;
                let mut i = home;
                let mut cur_dist = 0u64;
                loop {
                    let shard = self.shard_of(i);
                    if seen.last().map(|&(x, _)| x) != Some(shard) {
                        seen.push((shard, self.ts[shard].read()));
                    }
                    let cur = self.keys[i].read();
                    if cur == key {
                        let v = self.vals[i].read();
                        let (sh, tv) = *seen.last().unwrap();
                        if self.ts[sh].read() != tv {
                            continue 'retry;
                        }
                        metrics().probe_len_read.record(cur_dist + 1);
                        return ProbeVal::Found(v);
                    }
                    if cur == NIL {
                        break;
                    }
                    if cur == FROZEN_EMPTY {
                        saw_frozen = true;
                        break;
                    }
                    if cur == FROZEN_TOMB {
                        saw_frozen = true; // skip; DFB unknowable
                        metrics().tombstone_drift.incr();
                    } else if self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break;
                    }
                }
                for &(shard, v) in seen.iter() {
                    if self.ts[shard].read() != v {
                        continue 'retry;
                    }
                }
                metrics().probe_len_read.record(cur_dist + 1);
                return if saw_frozen {
                    ProbeVal::FrozenMiss
                } else {
                    ProbeVal::Absent
                };
            }
        })
    }

    /// Freeze every bucket in `[start, start+len)`, transferring live
    /// pairs into `target`. Idempotent; safe to race with other helpers.
    pub(crate) fn migrate_range(
        &self,
        target: &KCasRobinHoodMap,
        start: usize,
        len: usize,
    ) -> usize {
        let mut moved = 0;
        for i in start..(start + len).min(self.size()) {
            moved += self.freeze_bucket(target, i);
        }
        moved
    }

    /// Freeze bucket `i` (key word only; the value word of a frozen
    /// bucket is dead). Returns how many pairs this call moved.
    pub(crate) fn freeze_bucket(
        &self,
        target: &KCasRobinHoodMap,
        i: usize,
    ) -> usize {
        loop {
            let cur = self.keys[i].read();
            if is_frozen(cur) {
                return 0;
            }
            if cur == NIL {
                if self.keys[i].cas(NIL, FROZEN_EMPTY) {
                    return 0;
                }
            } else if self.transfer(target, i, cur) {
                return 1;
            }
        }
    }

    /// Freeze `key`'s whole home run (see the set twin for the
    /// protocol argument); afterwards the key definitively does not
    /// live in this generation.
    pub(crate) fn migrate_home_run(
        &self,
        target: &KCasRobinHoodMap,
        h: u64,
    ) -> usize {
        let mut moved = 0;
        let mut i = (h & self.mask) as usize;
        let mut steps = 0usize;
        loop {
            let cur = self.keys[i].read();
            if cur == FROZEN_EMPTY {
                return moved;
            }
            if cur == NIL {
                if self.keys[i].cas(NIL, FROZEN_EMPTY) {
                    return moved;
                }
                continue;
            }
            if cur == FROZEN_TOMB {
                i = (i + 1) & self.mask as usize;
                steps += 1;
                if steps > self.size() {
                    return moved;
                }
                continue;
            }
            if self.transfer(target, i, cur) {
                moved += 1;
            }
        }
    }

    /// Move the live pair at source bucket `i` into `target` and
    /// tombstone the source key word in one K-CAS, guarding the source
    /// value word so the pair cannot tear mid-transfer.
    fn transfer(&self, target: &KCasRobinHoodMap, i: usize, key: u64) -> bool {
        let val = self.vals[i].read();
        let h = splitmix64(key);
        let home = (h & target.mask) as usize;
        let seed = Some((&self.keys[i], key, &self.vals[i], val));
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            match target.try_insert_one(
                scratch,
                home,
                key,
                val,
                seed,
                OnExisting::Overwrite,
            ) {
                Ok(Attempt::Done(None)) => true,
                Ok(Attempt::Done(Some(_))) | Ok(Attempt::Fetched(_)) => {
                    unreachable!("seeded insert never overwrites")
                }
                Ok(Attempt::Present) => {
                    // Cannot happen under the freeze protocol (writers
                    // freeze a key's whole home run before inserting it
                    // into the next generation); defensively freeze
                    // without duplicating.
                    self.keys[i].cas(key, FROZEN_TOMB)
                }
                Ok(Attempt::Raced) => false,
                // Frozen target: this thread stalled across a whole
                // migration and a chained one began freezing `target`
                // (see the set twin). Report no-move; the caller
                // re-reads the source bucket, which helpers tombstoned.
                Err(_) => false,
            }
        })
    }

    // ----- transaction planning ------------------------------------
    //
    // `apply_txn` commits an arbitrary op set with **one** K-CAS. The
    // driver (`maps::txn::commit_kcas`) runs three phases per attempt:
    //
    //   A. `txn_read` every unique key (timestamp-validated probe);
    //   B. evaluate the ops against those reads (pure overlay — no
    //      table access), producing replies + one net transition per
    //      key;
    //   C. stage a physical plan per key into a [`TxnScratch`]:
    //      guards/writes at raw word addresses plus a timestamp ledger,
    //      merged and executed as a single descriptor.
    //
    // The plan methods below mirror `try_insert_one` / `try_remove_one`
    // exactly, except that they *stage* into the shared cross-table
    // scratch instead of executing, so entries from several shards (or
    // both generations of a resize) land in the same descriptor. Each
    // returns `Ok(false)` when the table state no longer matches the
    // phase-A read (the driver restarts the attempt).

    /// Phase A: one timestamp-validated locate of `key` —
    /// `Some((bucket, value))` or a validated miss.
    pub(crate) fn txn_read(
        &self,
        h: u64,
        key: u64,
    ) -> Result<Option<(usize, u64)>, MapError> {
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| self.try_probe_one(&mut s.borrow_mut(), home, key))
    }

    /// Stage a present key's transition `old -> new` at the phase-A
    /// bucket `i`. `old == new` is a pure pairing guard (a read, or an
    /// op set whose net effect leaves the value unchanged): if the key
    /// word still holds `key` and the value word still holds `old` at
    /// commit time, the map still contains `key ↦ old` — no timestamp
    /// guard is needed.
    pub(crate) fn txn_plan_pin(
        &self,
        tx: &mut TxnScratch,
        i: usize,
        key: u64,
        old: u64,
        new: u64,
    ) {
        tx.stage(&self.keys[i], key, key);
        tx.stage(&self.vals[i], old, new);
    }

    /// Stage an absence assertion for `key` (read-miss / CmpEx(None,_)
    /// mismatch arms): timestamp guards along the probe path plus a
    /// guard on the terminator key word — the latter is what catches an
    /// insert claiming the terminating Nil without bumping anything.
    pub(crate) fn txn_plan_absent(
        &self,
        tx: &mut TxnScratch,
        h: u64,
        key: u64,
    ) -> Result<bool, MapError> {
        let mut i = (h & self.mask) as usize;
        let mut cur_dist = 0u64;
        let mut last_shard = usize::MAX;
        loop {
            let shard = self.shard_of(i);
            if shard != last_shard {
                let addr = self.ts[shard].addr();
                if !tx.note_ts(addr, self.ts[shard].read(), 0) {
                    return Ok(false);
                }
                last_shard = shard;
            }
            let cur = self.keys[i].read();
            if is_frozen(cur) {
                return Err(MapError::Frozen);
            }
            if cur == key {
                return Ok(false); // appeared since phase A
            }
            if cur == NIL || self.dist(cur, i) < cur_dist {
                tx.stage(&self.keys[i], cur, cur);
                return Ok(true);
            }
            i = (i + 1) & self.mask as usize;
            cur_dist += 1;
            if cur_dist as usize > self.size() {
                return Ok(false);
            }
        }
    }

    /// Stage an insert of an absent `key` — the `try_insert_one` miss
    /// path (Nil claim + displacement pairs + probed-shard timestamp
    /// guards), staged instead of executed.
    pub(crate) fn txn_plan_insert(
        &self,
        tx: &mut TxnScratch,
        h: u64,
        key: u64,
        value: u64,
    ) -> Result<bool, MapError> {
        assert!(value <= crate::kcas::MAX_VALUE);
        let mut active_key = key;
        let mut active_val = value;
        let mut active_dist = 0u64;
        let mut i = (h & self.mask) as usize;
        let mut probes = 0usize;
        let mut last_shard = usize::MAX;
        loop {
            if probes >= self.size() {
                return Err(MapError::TableFull);
            }
            probes += 1;
            let shard = self.shard_of(i);
            let ts_val = self.ts[shard].read();
            let cur = self.keys[i].read();
            if is_frozen(cur) {
                return Err(MapError::Frozen);
            }
            if cur == NIL {
                tx.stage(&self.keys[i], NIL, active_key);
                tx.stage(&self.vals[i], self.vals[i].read(), active_val);
                return Ok(true);
            }
            if cur == key {
                return Ok(false); // appeared since phase A
            }
            if shard != last_shard {
                if !tx.note_ts(self.ts[shard].addr(), ts_val, 0) {
                    return Ok(false);
                }
                last_shard = shard;
            }
            let cur_d = self.dist(cur, i);
            if cur_d < active_dist {
                // Displace the richer pair; upgrade the shard's
                // timestamp guard to a bump.
                let cur_val = self.vals[i].read();
                tx.stage(&self.keys[i], cur, active_key);
                tx.stage(&self.vals[i], cur_val, active_val);
                if !tx.note_ts(self.ts[shard].addr(), ts_val, 1) {
                    return Ok(false);
                }
                active_key = cur;
                active_val = cur_val;
                active_dist = cur_d;
            }
            i = (i + 1) & self.mask as usize;
            active_dist += 1;
        }
    }

    /// Stage a remove of `key` whose phase-A value was `expect` — the
    /// `try_remove_one` shift chain (pair windows + terminator guard +
    /// shard timestamp bumps), staged instead of executed. The chain's
    /// first link swaps the value word `expect -> next`, so "still
    /// equals the phase-A value at commit" rides the descriptor for
    /// free (replies linearize at the commit point).
    pub(crate) fn txn_plan_remove(
        &self,
        tx: &mut TxnScratch,
        h: u64,
        key: u64,
        expect: u64,
    ) -> Result<bool, MapError> {
        let mut i = (h & self.mask) as usize;
        let mut cur_dist = 0u64;
        loop {
            let cur = self.keys[i].read();
            if is_frozen(cur) {
                return Err(MapError::Frozen);
            }
            if cur == key {
                break;
            }
            if cur == NIL || self.dist(cur, i) < cur_dist {
                return Ok(false); // vanished since phase A
            }
            i = (i + 1) & self.mask as usize;
            cur_dist += 1;
            if cur_dist as usize > self.size() {
                return Ok(false);
            }
        }
        if self.vals[i].read() != expect {
            return Ok(false); // value moved since phase A
        }
        tx.chain.clear();
        tx.chain.push((key, expect));
        let mut last_shard = self.shard_of(i);
        if !tx.note_ts(self.ts[last_shard].addr(), self.ts[last_shard].read(), 1)
        {
            return Ok(false);
        }
        let mut j = (i + 1) & self.mask as usize;
        let terminator;
        loop {
            let shard = self.shard_of(j);
            let ts_val = self.ts[shard].read();
            let nk = self.keys[j].read();
            if is_frozen(nk) {
                return Err(MapError::Frozen);
            }
            if nk == NIL || self.dist(nk, j) == 0 {
                terminator = (j, nk);
                break;
            }
            if shard != last_shard {
                if !tx.note_ts(self.ts[shard].addr(), ts_val, 1) {
                    return Ok(false);
                }
                last_shard = shard;
            }
            tx.chain.push((nk, self.vals[j].read()));
            j = (j + 1) & self.mask as usize;
            if tx.chain.len() > self.size() {
                return Ok(false);
            }
        }
        let mut pos = i;
        for w in 0..tx.chain.len() {
            let (ck, cv) = tx.chain[w];
            let (nk, nv) = tx.chain.get(w + 1).copied().unwrap_or((NIL, 0));
            tx.stage(&self.keys[pos], ck, nk);
            tx.stage(&self.vals[pos], cv, nv);
            pos = (pos + 1) & self.mask as usize;
        }
        tx.stage(&self.keys[terminator.0], terminator.1, terminator.1);
        Ok(true)
    }

    /// One op against an already-borrowed scratch and precomputed home
    /// bucket — the shared body of both batch paths.
    fn apply_one_in(
        &self,
        scratch: &mut Scratch,
        home: usize,
        op: MapOp,
    ) -> MapReply {
        let key = op.key();
        match op {
            MapOp::Get(_) => MapReply::Value(self.get_in(scratch, home, key)),
            MapOp::Insert(_, v) => {
                MapReply::Prev(self.insert_in(scratch, home, key, v))
            }
            MapOp::Remove(_) => {
                MapReply::Removed(self.remove_in(scratch, home, key))
            }
            MapOp::CmpEx(_, e, n) => {
                MapReply::CmpEx(live(self.cmpex_in(scratch, home, key, e, n)))
            }
            MapOp::GetOrInsert(_, v) => MapReply::Existing(live(
                self.get_or_insert_in(scratch, home, key, v),
            )),
            MapOp::FetchAdd(_, d) => {
                MapReply::Added(live(self.fetch_add_in(scratch, home, key, d)))
            }
        }
    }

    /// Apply `ops` in order with the thread-local K-CAS scratch
    /// (descriptor builder + probe lists) borrowed **once** for the
    /// whole batch — the amortisation hook behind `service::batch`.
    /// Replies land in `out` (cleared first), one per op, in op order.
    pub fn apply_batch_local(&self, ops: &[MapOp], out: &mut Vec<MapReply>) {
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            out.clear();
            for &op in ops {
                let key = op.key();
                check_key(key);
                let home = home_bucket(key, self.mask);
                out.push(self.apply_one_in(scratch, home, op));
            }
        })
    }

    /// [`KCasRobinHoodMap::apply_batch_local`] off precomputed hashes:
    /// one scratch borrow per batch *and* zero SplitMix64 evaluations —
    /// what the sharded facade's grouped sub-batches run through.
    pub fn apply_batch_local_hashed(
        &self,
        ops: &[super::HashedMapOp],
        out: &mut Vec<MapReply>,
    ) {
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            out.clear();
            for &(h, op) in ops {
                check_key(op.key());
                let home = (h & self.mask) as usize;
                out.push(self.apply_one_in(scratch, home, op));
            }
        })
    }

    /// Quiesced size.
    pub fn len_quiesced(&self) -> usize {
        (0..self.size()).filter(|&i| self.keys[i].read() != NIL).count()
    }

    /// Quiesced consistency check: RH invariant + every pair readable.
    pub fn check_invariant(&self) -> Result<(), String> {
        let n = self.size();
        for i in 0..n {
            let k = self.keys[i].read();
            if k == NIL {
                continue;
            }
            let d = self.dist(k, i);
            if d == 0 {
                continue;
            }
            let pi = (i + n - 1) & self.mask as usize;
            let prev = self.keys[pi].read();
            if prev == NIL {
                return Err(format!("bucket {i}: dfb {d} after empty"));
            }
            if d > self.dist(prev, pi) + 1 {
                return Err(format!("bucket {i}: invariant broken"));
            }
        }
        Ok(())
    }
}

impl ConcurrentMap for KCasRobinHoodMap {
    fn get(&self, key: u64) -> Option<u64> {
        KCasRobinHoodMap::get(self, key)
    }

    fn insert(&self, key: u64, value: u64) -> Option<u64> {
        KCasRobinHoodMap::insert(self, key, value)
    }

    fn remove(&self, key: u64) -> Option<u64> {
        KCasRobinHoodMap::remove(self, key)
    }

    fn compare_exchange(
        &self,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        KCasRobinHoodMap::compare_exchange(self, key, expected, new)
    }

    fn get_or_insert(&self, key: u64, value: u64) -> Option<u64> {
        KCasRobinHoodMap::get_or_insert(self, key, value)
    }

    fn fetch_add(&self, key: u64, delta: u64) -> Option<u64> {
        KCasRobinHoodMap::fetch_add(self, key, delta)
    }

    /// Hashed entry points (ROADMAP item): reuse the routing hash the
    /// sharded facade already computed (`home == h & mask`).
    fn get_hashed(&self, h: u64, key: u64) -> Option<u64> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| self.get_in(&mut s.borrow_mut(), home, key))
    }

    fn insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| self.insert_in(&mut s.borrow_mut(), home, key, value))
    }

    fn remove_hashed(&self, h: u64, key: u64) -> Option<u64> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| self.remove_in(&mut s.borrow_mut(), home, key))
    }

    fn compare_exchange_hashed(
        &self,
        h: u64,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        live(self.cmpex_mig(h, key, expected, new))
    }

    fn get_or_insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        live(self.get_or_insert_mig(h, key, value))
    }

    fn fetch_add_hashed(&self, h: u64, key: u64, delta: u64) -> Option<u64> {
        live(self.fetch_add_mig(h, key, delta))
    }

    fn apply_batch(&self, ops: &[MapOp], out: &mut Vec<MapReply>) {
        self.apply_batch_local(ops, out)
    }

    fn apply_txn(&self, ops: &[MapOp]) -> Result<Vec<MapReply>, TxnError> {
        txn::commit_kcas(ops, &mut |_h| self)
    }

    fn apply_batch_hashed(
        &self,
        ops: &[super::HashedMapOp],
        out: &mut Vec<MapReply>,
    ) {
        self.apply_batch_local_hashed(ops, out)
    }

    fn name(&self) -> &'static str {
        "kcas-rh-map"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn len_quiesced(&self) -> usize {
        KCasRobinHoodMap::len_quiesced(self)
    }

    fn check_invariant_quiesced(&self) -> Result<(), String> {
        self.check_invariant()
    }
}

impl txn::TxnBackend for KCasRobinHoodMap {
    fn apply_txn_routed(
        shards: &[Self],
        route: &dyn Fn(u64) -> usize,
        ops: &[MapOp],
    ) -> Result<Vec<MapReply>, TxnError> {
        txn::commit_kcas(ops, &mut |h| &shards[route(h)])
    }
}

// SAFETY: all shared state is atomics under the K-CAS protocol.
unsafe impl Send for KCasRobinHoodMap {}
// SAFETY: as for Send — &self methods only touch the bucket/timestamp
// atomics through the K-CAS protocol.
unsafe impl Sync for KCasRobinHoodMap {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::splitmix64;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashMap;
    use std::sync::Arc;

    #[test]
    fn basic_map_semantics() {
        let m = KCasRobinHoodMap::new(8);
        assert_eq!(m.get(1), None);
        assert_eq!(m.insert(1, 100), None);
        assert_eq!(m.get(1), Some(100));
        assert_eq!(m.insert(1, 200), Some(100));
        assert_eq!(m.get(1), Some(200));
        assert_eq!(m.remove(1), Some(200));
        assert_eq!(m.remove(1), None);
        assert_eq!(m.get(1), None);
    }

    #[test]
    fn values_follow_displaced_keys() {
        let m = KCasRobinHoodMap::new(6);
        for k in 1..=50u64 {
            m.insert(k, k * 1000);
        }
        m.check_invariant().unwrap();
        for k in 1..=50u64 {
            assert_eq!(m.get(k), Some(k * 1000), "pair broken for {k}");
        }
        for k in (1..=50u64).step_by(2) {
            assert_eq!(m.remove(k), Some(k * 1000));
        }
        for k in 1..=50u64 {
            let want = if k % 2 == 0 { Some(k * 1000) } else { None };
            assert_eq!(m.get(k), want, "after shift, key {k}");
        }
    }

    #[test]
    fn oracle_property_vs_hashmap() {
        prop::check(
            "kcas-rh-map matches HashMap",
            20,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| {
                        (r.below(3) as u8, 1 + r.below(48), r.below(1000))
                    })
                    .collect::<Vec<(u8, u64, u64)>>()
            },
            |ops| {
                let m = KCasRobinHoodMap::new(7);
                let mut oracle: HashMap<u64, u64> = HashMap::new();
                for &(op, key, val) in ops {
                    let (got, want) = match op {
                        0 => (m.insert(key, val), oracle.insert(key, val)),
                        1 => (m.remove(key), oracle.remove(&key)),
                        _ => (m.get(key), oracle.get(&key).copied()),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got:?} want {want:?}"
                        ));
                    }
                }
                m.check_invariant()?;
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_pairs_never_tear() {
        // Each key's value always encodes its key (value = key * 7).
        // Under churn, a get must never observe a mismatched pair.
        let m = Arc::new(KCasRobinHoodMap::new(8));
        const KEYS: u64 = 100;
        for k in 1..=KEYS {
            m.insert(k, k * 7);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        for tid in 0..3u64 {
            let (m, stop) = (m.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x99, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(KEYS);
                    m.remove(k);
                    m.insert(k, k * 7);
                }
            }));
        }
        for tid in 0..4u64 {
            let (m, stop) = (m.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x9A, tid);
                for _ in 0..30_000 {
                    let k = 1 + r.below(KEYS);
                    if let Some(v) = m.get(k) {
                        assert_eq!(v, k * 7, "torn pair: key {k} value {v}");
                    }
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        m.check_invariant().unwrap();
    }

    #[test]
    fn batch_matches_op_by_op_and_reuses_scratch() {
        let m = KCasRobinHoodMap::new(8);
        let oracle = KCasRobinHoodMap::new(8);
        let ops = vec![
            MapOp::Insert(5, 50),
            MapOp::Get(5),
            MapOp::Insert(5, 51),
            MapOp::Get(5),
            MapOp::Insert(9, 90),
            MapOp::Remove(5),
            MapOp::Get(5),
            MapOp::Remove(5),
            MapOp::Get(9),
        ];
        let mut replies = Vec::new();
        m.apply_batch_local(&ops, &mut replies);
        let expect: Vec<MapReply> =
            ops.iter().map(|&op| oracle.apply_one(op)).collect();
        assert_eq!(replies, expect);
        assert_eq!(
            replies,
            vec![
                MapReply::Prev(None),
                MapReply::Value(Some(50)),
                MapReply::Prev(Some(50)),
                MapReply::Value(Some(51)),
                MapReply::Prev(None),
                MapReply::Removed(Some(51)),
                MapReply::Value(None),
                MapReply::Removed(None),
                MapReply::Value(Some(90)),
            ]
        );
        // Reply buffer is cleared between batches, not appended to.
        m.apply_batch_local(&[MapOp::Get(9)], &mut replies);
        assert_eq!(replies, vec![MapReply::Value(Some(90))]);
    }

    #[test]
    fn hashed_entry_points_agree_with_plain() {
        let m = KCasRobinHoodMap::new(7);
        for k in 1..=60u64 {
            let h = splitmix64(k);
            assert_eq!(ConcurrentMap::insert_hashed(&m, h, k, k + 1), None);
            assert_eq!(ConcurrentMap::get_hashed(&m, h, k), Some(k + 1));
            assert_eq!(m.get(k), Some(k + 1));
        }
        for k in (1..=60u64).step_by(2) {
            let h = splitmix64(k);
            assert_eq!(ConcurrentMap::remove_hashed(&m, h, k), Some(k + 1));
            assert_eq!(ConcurrentMap::get_hashed(&m, h, k), None);
        }
        m.check_invariant().unwrap();
        assert_eq!(m.len_quiesced(), 30);
    }

    #[test]
    fn migrate_range_moves_every_pair_intact() {
        let src = KCasRobinHoodMap::new(6);
        let dst = KCasRobinHoodMap::new(7);
        for k in 1..=40u64 {
            src.insert(k, k * 11);
        }
        let moved = src.migrate_range(&dst, 0, src.size());
        assert_eq!(moved, 40);
        dst.check_invariant().unwrap();
        for k in 1..=40u64 {
            assert_eq!(dst.get(k), Some(k * 11), "pair broken for {k}");
        }
        assert!(matches!(
            src.get_mig(splitmix64(41), 41),
            ProbeVal::FrozenMiss
        ));
    }

    #[test]
    fn migrate_home_run_evicts_the_pair() {
        let src = KCasRobinHoodMap::new(6);
        let dst = KCasRobinHoodMap::new(7);
        for k in 1..=30u64 {
            src.insert(k, k + 500);
        }
        let h = splitmix64(7);
        src.migrate_home_run(&dst, h);
        assert!(!matches!(src.get_mig(h, 7), ProbeVal::Found(_)));
        assert_eq!(dst.get(7), Some(507));
        assert!(src.insert_mig(h, 7, 1).is_err(), "frozen run must abort");
    }

    #[test]
    fn compare_exchange_corners_sequential() {
        let m = KCasRobinHoodMap::new(8);
        // Absent key.
        assert_eq!(m.compare_exchange(5, None, None), Ok(()));
        assert_eq!(m.compare_exchange(5, Some(1), Some(2)), Err(None));
        assert_eq!(m.compare_exchange(5, Some(1), None), Err(None));
        // Insert-if-absent.
        assert_eq!(m.compare_exchange(5, None, Some(50)), Ok(()));
        assert_eq!(m.compare_exchange(5, None, Some(51)), Err(Some(50)));
        assert_eq!(m.compare_exchange(5, None, None), Err(Some(50)));
        // Swap-if-equal.
        assert_eq!(m.compare_exchange(5, Some(49), Some(51)), Err(Some(50)));
        assert_eq!(m.compare_exchange(5, Some(50), Some(51)), Ok(()));
        assert_eq!(m.get(5), Some(51));
        // Remove-if-equal.
        assert_eq!(m.compare_exchange(5, Some(50), None), Err(Some(51)));
        assert_eq!(m.compare_exchange(5, Some(51), None), Ok(()));
        assert_eq!(m.get(5), None);
        assert_eq!(m.len_quiesced(), 0);
        m.check_invariant().unwrap();
    }

    #[test]
    fn get_or_insert_and_fetch_add_sequential() {
        let m = KCasRobinHoodMap::new(8);
        assert_eq!(m.get_or_insert(9, 90), None);
        assert_eq!(m.get_or_insert(9, 91), Some(90));
        assert_eq!(m.get(9), Some(90));
        assert_eq!(m.fetch_add(9, 5), Some(90));
        assert_eq!(m.get(9), Some(95));
        assert_eq!(m.fetch_add(12, 3), None); // missing key counts as 0
        assert_eq!(m.get(12), Some(3));
        // Wrapping stays in the 62-bit value domain.
        let m2 = KCasRobinHoodMap::new(6);
        m2.insert(1, crate::kcas::MAX_VALUE);
        assert_eq!(m2.fetch_add(1, 1), Some(crate::kcas::MAX_VALUE));
        assert_eq!(m2.get(1), Some(0));
    }

    #[test]
    fn conditional_ops_displace_like_inserts() {
        // Force a crowded table so conditional inserts run the full
        // displacement/guard machinery.
        let m = KCasRobinHoodMap::new(6);
        for k in 1..=40u64 {
            assert_eq!(m.compare_exchange(k, None, Some(k * 9)), Ok(()));
        }
        m.check_invariant().unwrap();
        for k in 1..=40u64 {
            assert_eq!(m.get(k), Some(k * 9), "pair broken for {k}");
            assert_eq!(m.get_or_insert(k, 1), Some(k * 9));
        }
        for k in (1..=40u64).step_by(2) {
            assert_eq!(m.compare_exchange(k, Some(k * 9), None), Ok(()));
        }
        m.check_invariant().unwrap();
        for k in 1..=40u64 {
            let want = if k % 2 == 0 { Some(k * 9) } else { None };
            assert_eq!(m.get(k), want, "after conditional remove, key {k}");
        }
    }

    #[test]
    fn concurrent_fetch_add_is_atomic() {
        // The whole point of the native RMW: concurrent increments on
        // one hot counter must never lose an update.
        let m = Arc::new(KCasRobinHoodMap::new(8));
        const THREADS: u64 = 8;
        const INCS: u64 = 5_000;
        let mut hs = Vec::new();
        for _ in 0..THREADS {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..INCS {
                    m.fetch_add(7, 1);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.get(7), Some(THREADS * INCS));
    }

    #[test]
    fn concurrent_get_or_insert_inserts_exactly_once() {
        let m = Arc::new(KCasRobinHoodMap::new(10));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                // Every thread proposes its own value; exactly one
                // proposal per key may win.
                (1..=200u64)
                    .filter(|&k| m.get_or_insert(k, 1000 + tid).is_none())
                    .count()
            }));
        }
        let wins: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(wins, 200, "duplicate or lost conditional inserts");
        for k in 1..=200u64 {
            let v = m.get(k).expect("winner's value survives");
            assert!((1000..1008).contains(&v), "key {k} holds {v}");
        }
    }

    #[test]
    fn concurrent_cmpex_chain_has_single_winner_per_step() {
        // Optimistic-update ladder: every thread tries to advance the
        // counter via compare_exchange(v, v+1); total successes must
        // equal the final value (no double-applied steps).
        let m = Arc::new(KCasRobinHoodMap::new(8));
        m.insert(3, 0);
        let mut hs = Vec::new();
        for _ in 0..6 {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                let mut wins = 0u64;
                for _ in 0..4_000 {
                    let cur = m.get(3).unwrap();
                    if m.compare_exchange(3, Some(cur), Some(cur + 1)).is_ok() {
                        wins += 1;
                    }
                }
                wins
            }));
        }
        let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(m.get(3), Some(total));
    }

    #[test]
    fn conditional_hashed_entry_points_agree_with_plain() {
        let m = KCasRobinHoodMap::new(7);
        for k in 1..=40u64 {
            let h = splitmix64(k);
            assert_eq!(
                ConcurrentMap::compare_exchange_hashed(&m, h, k, None, Some(k)),
                Ok(())
            );
            assert_eq!(
                ConcurrentMap::get_or_insert_hashed(&m, h, k, 0),
                Some(k)
            );
            assert_eq!(ConcurrentMap::fetch_add_hashed(&m, h, k, 2), Some(k));
            assert_eq!(m.get(k), Some(k + 2));
            assert_eq!(
                ConcurrentMap::compare_exchange_hashed(
                    &m,
                    h,
                    k,
                    Some(k + 2),
                    None
                ),
                Ok(())
            );
            assert_eq!(m.get(k), None);
        }
        m.check_invariant().unwrap();
    }

    #[test]
    fn hashed_batch_matches_plain_batch() {
        let hashed = KCasRobinHoodMap::new(8);
        let plain = KCasRobinHoodMap::new(8);
        let ops = vec![
            MapOp::GetOrInsert(4, 40),
            MapOp::FetchAdd(4, 2),
            MapOp::CmpEx(4, Some(42), Some(43)),
            MapOp::CmpEx(4, Some(42), Some(44)),
            MapOp::Get(4),
            MapOp::CmpEx(9, None, Some(90)),
            MapOp::CmpEx(9, Some(90), None),
            MapOp::Get(9),
        ];
        let hashed_ops: Vec<crate::maps::HashedMapOp> =
            ops.iter().map(|&op| (splitmix64(op.key()), op)).collect();
        let mut got = Vec::new();
        hashed.apply_batch_local_hashed(&hashed_ops, &mut got);
        let mut want = Vec::new();
        plain.apply_batch_local(&ops, &mut want);
        assert_eq!(got, want);
        assert_eq!(
            got,
            vec![
                MapReply::Existing(None),
                MapReply::Added(Some(40)),
                MapReply::CmpEx(Ok(())),
                MapReply::CmpEx(Err(Some(43))),
                MapReply::Value(Some(43)),
                MapReply::CmpEx(Ok(())),
                MapReply::CmpEx(Ok(())),
                MapReply::Value(None),
            ]
        );
    }

    #[test]
    fn concurrent_disjoint_inserts() {
        let m = Arc::new(KCasRobinHoodMap::new(12));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 1000;
                for k in base..base + 300 {
                    assert_eq!(m.insert(k, k + 1), None);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.len_quiesced(), 8 * 300);
        for tid in 0..8u64 {
            let base = 1 + tid * 1000;
            for k in base..base + 300 {
                assert_eq!(m.get(k), Some(k + 1));
            }
        }
    }
}
