//! Serial Robin Hood hashing (Celis 1986) — the paper's §2.2 baseline.
//!
//! [`SerialRobinHood`] is the plain single-threaded structure (also the
//! semantic oracle for the concurrent variants); `SerialRobinHoodLocked`
//! wraps it in one mutex so it can stand in wherever a `ConcurrentSet`
//! is required (single-core overhead comparisons, Fig. 10 context).
//!
//! Insertion displaces "richer" entries (lower DFB) per Fig. 1; deletion
//! backward-shifts per Fig. 4; search cuts off on the Robin Hood
//! invariant per Fig. 3.

use std::sync::Mutex;

use super::{check_key, ConcurrentSet};
use crate::util::hash::{dfb, home_bucket};

/// Nil marker (empty bucket).
const NIL: u64 = 0;

/// Plain single-threaded Robin Hood hash set.
pub struct SerialRobinHood {
    table: Vec<u64>,
    mask: u64,
    len: usize,
}

impl SerialRobinHood {
    pub fn new(size_log2: u32) -> Self {
        let size = 1usize << size_log2;
        Self { table: vec![NIL; size], mask: (size - 1) as u64, len: 0 }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Search with the Robin Hood invariant early cut-off (Fig. 3).
    pub fn contains(&self, key: u64) -> bool {
        check_key(key);
        let home = home_bucket(key, self.mask);
        let mut i = home;
        for cur_dist in 0..self.size() as u64 {
            let cur = self.table[i];
            if cur == NIL {
                return false;
            }
            if cur == key {
                return true;
            }
            // Invariant: an occupant closer to home than our probe
            // distance proves the key is absent.
            if dfb(home_bucket(cur, self.mask), i, self.mask) < cur_dist {
                return false;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    /// Robin Hood insertion (Fig. 1): swap with richer occupants, carry
    /// the evicted entry forward until a Nil bucket.
    pub fn add(&mut self, key: u64) -> bool {
        check_key(key);
        assert!(self.len < self.size(), "table full");
        let mut active = key;
        let mut active_dist = 0u64;
        let mut i = home_bucket(active, self.mask);
        loop {
            let cur = self.table[i];
            if cur == NIL {
                self.table[i] = active;
                self.len += 1;
                return true;
            }
            if cur == key && active == key {
                return false; // already present (only match the probe key)
            }
            let cur_dist = dfb(home_bucket(cur, self.mask), i, self.mask);
            if cur_dist < active_dist {
                // Steal from the rich: place `active`, displace `cur`.
                self.table[i] = active;
                active = cur;
                active_dist = cur_dist;
            }
            i = (i + 1) & self.mask as usize;
            active_dist += 1;
        }
    }

    /// Deletion with backward shifting (Fig. 4).
    pub fn remove(&mut self, key: u64) -> bool {
        check_key(key);
        let home = home_bucket(key, self.mask);
        let mut i = home;
        for cur_dist in 0..self.size() as u64 {
            let cur = self.table[i];
            if cur == NIL {
                return false;
            }
            if cur == key {
                self.backward_shift(i);
                self.len -= 1;
                return true;
            }
            if dfb(home_bucket(cur, self.mask), i, self.mask) < cur_dist {
                return false;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    /// Shift successors back over bucket `hole` until a Nil bucket or an
    /// entry already at its home (DFB 0).
    fn backward_shift(&mut self, mut hole: usize) {
        loop {
            let next = (hole + 1) & self.mask as usize;
            let cur = self.table[next];
            if cur == NIL
                || dfb(home_bucket(cur, self.mask), next, self.mask) == 0
            {
                self.table[hole] = NIL;
                return;
            }
            self.table[hole] = cur;
            hole = next;
        }
    }

    /// DFB per bucket, -1 for empty (probe-statistics input).
    pub fn dfb_snapshot(&self) -> Vec<i32> {
        self.table
            .iter()
            .enumerate()
            .map(|(i, &k)| {
                if k == NIL {
                    -1
                } else {
                    dfb(home_bucket(k, self.mask), i, self.mask) as i32
                }
            })
            .collect()
    }

    /// Check the Robin Hood table invariant: along any probe run the DFB
    /// can drop only where an entry is at home; formally, for each
    /// occupied bucket i with occupied predecessor, dfb(i) >= dfb(i-1)-...
    /// The precise statement: for consecutive occupied buckets (i-1, i),
    /// dfb(i) + 1 >= ... — we check the standard formulation:
    /// dfb(i) <= dfb(i-1) + 1, and no entry sits after an empty bucket
    /// closer than its home allows.
    pub fn check_invariant(&self) -> Result<(), String> {
        let n = self.size();
        for i in 0..n {
            let k = self.table[i];
            if k == NIL {
                continue;
            }
            let d = dfb(home_bucket(k, self.mask), i, self.mask);
            let prev = self.table[(i + n - 1) & self.mask as usize];
            if prev == NIL {
                if d != 0 {
                    return Err(format!(
                        "bucket {i}: key {k} has dfb {d} but predecessor empty"
                    ));
                }
            } else {
                let pd = dfb(
                    home_bucket(prev, self.mask),
                    (i + n - 1) & self.mask as usize,
                    self.mask,
                );
                if d > pd + 1 {
                    return Err(format!(
                        "bucket {i}: dfb {d} > predecessor dfb {pd} + 1"
                    ));
                }
            }
        }
        Ok(())
    }
}

/// Mutex-wrapped serial table satisfying [`ConcurrentSet`].
pub struct SerialRobinHoodLocked {
    inner: Mutex<SerialRobinHood>,
}

impl SerialRobinHoodLocked {
    pub fn new(size_log2: u32) -> Self {
        Self { inner: Mutex::new(SerialRobinHood::new(size_log2)) }
    }
}

impl ConcurrentSet for SerialRobinHoodLocked {
    fn contains(&self, key: u64) -> bool {
        self.inner.lock().unwrap().contains(key)
    }
    fn add(&self, key: u64) -> bool {
        self.inner.lock().unwrap().add(key)
    }
    fn remove(&self, key: u64) -> bool {
        self.inner.lock().unwrap().remove(key)
    }
    fn name(&self) -> &'static str {
        "serial-rh"
    }
    fn capacity(&self) -> usize {
        self.inner.lock().unwrap().size()
    }
    fn dfb_snapshot(&self) -> Vec<i32> {
        self.inner.lock().unwrap().dfb_snapshot()
    }
    fn len_quiesced(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;

    #[test]
    fn basic_add_contains_remove() {
        let mut t = SerialRobinHood::new(8);
        assert!(t.add(1));
        assert!(!t.add(1));
        assert!(t.contains(1));
        assert!(!t.contains(2));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert!(!t.contains(1));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn fill_to_high_load_factor() {
        let mut t = SerialRobinHood::new(10);
        let n = (1024.0 * 0.9) as u64;
        for k in 1..=n {
            assert!(t.add(k));
        }
        for k in 1..=n {
            assert!(t.contains(k), "lost key {k}");
        }
        assert!(!t.contains(n + 1));
        t.check_invariant().unwrap();
        assert_eq!(t.len(), n as usize);
    }

    #[test]
    fn removal_backward_shift_preserves_members() {
        let mut t = SerialRobinHood::new(8);
        for k in 1..=200u64 {
            t.add(k);
        }
        for k in (1..=200u64).step_by(2) {
            assert!(t.remove(k));
        }
        t.check_invariant().unwrap();
        for k in 1..=200u64 {
            assert_eq!(t.contains(k), k % 2 == 0, "key {k}");
        }
    }

    #[test]
    fn mean_dfb_stays_low_at_80_percent() {
        // Celis: expected successful probe ~2.6 even at high LF.
        let mut t = SerialRobinHood::new(14);
        let n = ((1 << 14) as f64 * 0.8) as u64;
        for k in 1..=n {
            t.add(k);
        }
        let snap = t.dfb_snapshot();
        let (mut sum, mut cnt) = (0i64, 0i64);
        for d in snap {
            if d >= 0 {
                sum += d as i64;
                cnt += 1;
            }
        }
        let mean = sum as f64 / cnt as f64;
        assert!(mean < 4.0, "mean DFB {mean}");
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "serial-rh matches HashSet",
            40,
            |r: &mut Rng| {
                (0..400)
                    .map(|_| (r.below(3) as u8, 1 + r.below(64)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let mut t = SerialRobinHood::new(8);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got}, want {want}"
                        ));
                    }
                }
                t.check_invariant()?;
                if t.len() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn locked_wrapper_is_a_concurrent_set() {
        let t = SerialRobinHoodLocked::new(8);
        let tref: &dyn ConcurrentSet = &t;
        assert!(tref.add(5));
        assert!(tref.contains(5));
        assert_eq!(tref.len_quiesced(), 1);
    }

    #[test]
    fn wraparound_at_table_end() {
        // Keys that hash near the end of a tiny table must wrap.
        let mut t = SerialRobinHood::new(4);
        let mut added = Vec::new();
        for k in 1..=14u64 {
            t.add(k);
            added.push(k);
        }
        t.check_invariant().unwrap();
        for k in added {
            assert!(t.contains(k));
        }
    }
}
