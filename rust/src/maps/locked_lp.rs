//! Locked linear probing — the paper's blocking LP baseline ("a
//! standard linear probing scheme with the same locking strategy as
//! Hopscotch Hashing").
//!
//! Mutating operations take the home bucket's *segment lock* (sharded
//! exactly like Hopscotch/our timestamp shards); bucket writes are still
//! single-word atomics because a probe may claim a bucket in a
//! neighbouring segment. Reads are lock-free (linear probing never
//! relocates, so no validation is needed). Tombstone deletion gives the
//! contamination behaviour the paper discusses for Table 1.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::pad::CachePadded;

use super::{check_key, ConcurrentSet};
use crate::util::hash::home_bucket;

const EMPTY: u64 = 0;
const TOMBSTONE: u64 = 1;
const BIAS: u64 = 2;

/// Buckets per lock segment (matches Hopscotch below).
pub const MIN_SEG_LOG2: u32 = 6;

pub struct LockedLp {
    table: Box<[AtomicU64]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    mask: u64,
    seg_log2: u32,
}

impl LockedLp {
    pub fn new(size_log2: u32) -> Self {
        // Bounded, cache-resident lock table (see kcas_rh).
        Self::with_segments(
            size_log2,
            super::kcas_rh::default_shard_log2(size_log2).max(MIN_SEG_LOG2),
        )
    }

    pub fn with_segments(size_log2: u32, seg_log2: u32) -> Self {
        let size = 1usize << size_log2;
        let nlocks = (size >> seg_log2).max(1);
        Self {
            table: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            locks: (0..nlocks)
                .map(|_| CachePadded::new(Mutex::new(())))
                .collect(),
            mask: (size - 1) as u64,
            seg_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn lock_of(&self, i: usize) -> &Mutex<()> {
        &self.locks[(i >> self.seg_log2) & (self.locks.len() - 1)]
    }
}

impl ConcurrentSet for LockedLp {
    fn contains(&self, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let mut i = home_bucket(key, self.mask);
        for _ in 0..self.size() {
            let cur = self.table[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return false;
            }
            if cur == k {
                return true;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn add(&self, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        // Same-key operations serialize on the home lock, so a
        // scan-then-claim with tombstone reuse is race-free for `key`;
        // claims still CAS because *other* keys (holding other locks)
        // may target the same bucket.
        'rescan: loop {
            let mut reusable: Option<usize> = None;
            let mut i = home;
            for _ in 0..=self.size() {
                let cur = self.table[i].load(Ordering::Acquire);
                if cur == k {
                    return false;
                }
                if cur == TOMBSTONE && reusable.is_none() {
                    reusable = Some(i);
                }
                if cur == EMPTY {
                    let slot = reusable.unwrap_or(i);
                    let expected = if reusable.is_some() { TOMBSTONE } else { EMPTY };
                    if self
                        .table[slot]
                        .compare_exchange(
                            expected,
                            k,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        return true;
                    }
                    continue 'rescan; // bucket stolen by another key
                }
                i = (i + 1) & self.mask as usize;
            }
            if let Some(slot) = reusable {
                if self
                    .table[slot]
                    .compare_exchange(
                        TOMBSTONE,
                        k,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return true;
                }
                continue 'rescan;
            }
            panic!("locked LP table is full");
        }
    }

    fn remove(&self, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        let mut i = home;
        for _ in 0..self.size() {
            let cur = self.table[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return false;
            }
            if cur == k {
                return self
                    .table[i]
                    .compare_exchange(
                        k,
                        TOMBSTONE,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok();
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn name(&self) -> &'static str {
        "locked-lp"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let v = self.table[i].load(Ordering::Acquire);
                if v == EMPTY || v == TOMBSTONE {
                    -1
                } else {
                    crate::util::hash::dfb(
                        home_bucket(v - BIAS, self.mask),
                        i,
                        self.mask,
                    ) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        self.table
            .iter()
            .filter(|b| {
                let v = b.load(Ordering::Acquire);
                v != EMPTY && v != TOMBSTONE
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = LockedLp::new(8);
        assert!(t.add(1));
        assert!(!t.add(1));
        assert!(t.contains(1));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert!(!t.contains(1));
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "locked-lp matches HashSet",
            30,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = LockedLp::new(8);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_same_key_exactly_once() {
        let t = Arc::new(LockedLp::new(12));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=400u64).filter(|&k| t.add(k)).count()
            }));
        }
        let total: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 400);
        assert_eq!(t.len_quiesced(), 400);
    }

    #[test]
    fn small_table_one_lock() {
        // size 16 with 64-bucket segments -> single lock; still correct.
        let t = LockedLp::new(4);
        for k in 1..=10u64 {
            assert!(t.add(k));
        }
        assert_eq!(t.len_quiesced(), 10);
    }
}
