//! Locked linear probing — the paper's blocking LP baseline ("a
//! standard linear probing scheme with the same locking strategy as
//! Hopscotch Hashing").
//!
//! Mutating operations take the home bucket's *segment lock* (sharded
//! exactly like Hopscotch/our timestamp shards); bucket writes are still
//! single-word atomics because a probe may claim a bucket in a
//! neighbouring segment. Reads are lock-free (linear probing never
//! relocates, so no validation is needed). Tombstone deletion gives the
//! contamination behaviour the paper discusses for Table 1.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::pad::CachePadded;

use super::txn;
use super::{
    check_key, ConcurrentMap, ConcurrentSet, MapOp, MapReply, TxnError,
};
use crate::util::hash::{home_bucket, splitmix64};
use crate::util::metrics::metrics;

const EMPTY: u64 = 0;
const TOMBSTONE: u64 = 1;
const BIAS: u64 = 2;

/// Buckets per lock segment (matches Hopscotch below).
pub const MIN_SEG_LOG2: u32 = 6;

pub struct LockedLp {
    table: Box<[AtomicU64]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    mask: u64,
    seg_log2: u32,
}

impl LockedLp {
    pub fn new(size_log2: u32) -> Self {
        // Bounded, cache-resident lock table (see kcas_rh).
        Self::with_segments(
            size_log2,
            super::kcas_rh::default_shard_log2(size_log2).max(MIN_SEG_LOG2),
        )
    }

    pub fn with_segments(size_log2: u32, seg_log2: u32) -> Self {
        let size = 1usize << size_log2;
        let nlocks = (size >> seg_log2).max(1);
        Self {
            table: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            locks: (0..nlocks)
                .map(|_| CachePadded::new(Mutex::new(())))
                .collect(),
            mask: (size - 1) as u64,
            seg_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn lock_of(&self, i: usize) -> &Mutex<()> {
        &self.locks[(i >> self.seg_log2) & (self.locks.len() - 1)]
    }
}

impl ConcurrentSet for LockedLp {
    // The plain trio routes through the hashed twins so the sharded
    // facade's single SplitMix64 is reused rather than recomputed
    // (linear probing derives nothing but the home bucket from it).

    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let mut i = (h & self.mask) as usize;
        for _ in 0..self.size() {
            let cur = self.table[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return false;
            }
            if cur == k {
                return true;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let home = (h & self.mask) as usize;
        let _guard = self.lock_of(home).lock().unwrap();
        // Same-key operations serialize on the home lock, so a
        // scan-then-claim with tombstone reuse is race-free for `key`;
        // claims still CAS because *other* keys (holding other locks)
        // may target the same bucket.
        'rescan: loop {
            let mut reusable: Option<usize> = None;
            let mut i = home;
            for _ in 0..=self.size() {
                let cur = self.table[i].load(Ordering::Acquire);
                if cur == k {
                    return false;
                }
                if cur == TOMBSTONE && reusable.is_none() {
                    reusable = Some(i);
                }
                if cur == EMPTY {
                    let slot = reusable.unwrap_or(i);
                    let expected = if reusable.is_some() { TOMBSTONE } else { EMPTY };
                    if self
                        .table[slot]
                        .compare_exchange(
                            expected,
                            k,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        return true;
                    }
                    continue 'rescan; // bucket stolen by another key
                }
                i = (i + 1) & self.mask as usize;
            }
            if let Some(slot) = reusable {
                if self
                    .table[slot]
                    .compare_exchange(
                        TOMBSTONE,
                        k,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return true;
                }
                continue 'rescan;
            }
            panic!("locked LP table is full");
        }
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let home = (h & self.mask) as usize;
        let _guard = self.lock_of(home).lock().unwrap();
        let mut i = home;
        for _ in 0..self.size() {
            let cur = self.table[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return false;
            }
            if cur == k {
                return self
                    .table[i]
                    .compare_exchange(
                        k,
                        TOMBSTONE,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok();
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn name(&self) -> &'static str {
        "locked-lp"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let v = self.table[i].load(Ordering::Acquire);
                if v == EMPTY || v == TOMBSTONE {
                    -1
                } else {
                    crate::util::hash::dfb(
                        home_bucket(v - BIAS, self.mask),
                        i,
                        self.mask,
                    ) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        self.table
            .iter()
            .filter(|b| {
                let v = b.load(Ordering::Acquire);
                v != EMPTY && v != TOMBSTONE
            })
            .count()
    }
}

/// **Locked LP map** — the blocking key→value baseline for the service
/// layer, mirroring [`LockedLp`]'s segment-locking strategy.
///
/// Unlike the set, *all* operations (including `get`) take the home
/// bucket's segment lock: a map read must return the value *paired*
/// with the key, and the lock is what serialises same-key value
/// overwrites against readers (every operation on key `k` locks
/// `home(k)`'s segment, so the pair read cannot tear). Slots in
/// neighbouring segments are still claimed by CAS on the key word,
/// because a probe may cross segment boundaries; value words are only
/// ever written by operations on the key currently claiming the slot,
/// which the home lock serialises.
pub struct LockedLpMap {
    keys: Box<[AtomicU64]>,
    vals: Box<[AtomicU64]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    mask: u64,
    seg_log2: u32,
}

impl LockedLpMap {
    pub fn new(size_log2: u32) -> Self {
        let seg_log2 =
            super::kcas_rh::default_shard_log2(size_log2).max(MIN_SEG_LOG2);
        let size = 1usize << size_log2;
        let nlocks = (size >> seg_log2).max(1);
        Self {
            keys: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            vals: (0..size).map(|_| AtomicU64::new(0)).collect(),
            locks: (0..nlocks)
                .map(|_| CachePadded::new(Mutex::new(())))
                .collect(),
            mask: (size - 1) as u64,
            seg_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn lock_of(&self, i: usize) -> &Mutex<()> {
        &self.locks[(i >> self.seg_log2) & (self.locks.len() - 1)]
    }

    /// Probe for `key` (biased); `Some(slot)` if present. Caller holds
    /// the home-segment lock.
    fn find(&self, k: u64, home: usize) -> Option<usize> {
        let mut i = home;
        for _ in 0..self.size() {
            let cur = self.keys[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return None;
            }
            if cur == k {
                return Some(i);
            }
            i = (i + 1) & self.mask as usize;
        }
        None
    }

    /// Insert-or-overwrite body; caller holds the home-segment lock
    /// (`k` is biased). Returns the previous value. Slot claims still
    /// CAS because probes for *other* keys (holding other locks) may
    /// target the same bucket.
    fn upsert_locked(&self, k: u64, home: usize, value: u64) -> Option<u64> {
        'rescan: loop {
            let mut reusable: Option<usize> = None;
            let mut i = home;
            for _ in 0..=self.size() {
                let cur = self.keys[i].load(Ordering::Acquire);
                if cur == k {
                    // Overwrite in place: same-key ops hold this lock.
                    return Some(self.vals[i].swap(value, Ordering::AcqRel));
                }
                if cur == TOMBSTONE && reusable.is_none() {
                    reusable = Some(i);
                }
                if cur == EMPTY {
                    let slot = reusable.unwrap_or(i);
                    let expected =
                        if reusable.is_some() { TOMBSTONE } else { EMPTY };
                    if self
                        .keys[slot]
                        .compare_exchange(
                            expected,
                            k,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        self.vals[slot].store(value, Ordering::Release);
                        return None;
                    }
                    continue 'rescan; // bucket stolen by another key
                }
                i = (i + 1) & self.mask as usize;
            }
            if let Some(slot) = reusable {
                if self
                    .keys[slot]
                    .compare_exchange(
                        TOMBSTONE,
                        k,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    self.vals[slot].store(value, Ordering::Release);
                    return None;
                }
                continue 'rescan;
            }
            panic!("locked LP map is full");
        }
    }

    /// `compare_exchange` body for a precomputed home bucket: the whole
    /// check-then-act runs under the home-segment lock — the blocking
    /// reference semantics the K-CAS map's single-descriptor version is
    /// checked against.
    fn cmpex_at(
        &self,
        key: u64,
        home: usize,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        if let Some(v) = new {
            assert!(v <= crate::kcas::MAX_VALUE);
        }
        let k = key + BIAS;
        let _guard = self.lock_of(home).lock().unwrap();
        match self.find(k, home) {
            Some(i) => {
                let cur = self.vals[i].load(Ordering::Acquire);
                match (expected, new) {
                    (Some(e), Some(v)) if cur == e => {
                        self.vals[i].store(v, Ordering::Release);
                        Ok(())
                    }
                    (Some(e), None) if cur == e => {
                        // Same-key ops serialise on this lock; a plain
                        // tombstone store suffices (see `remove`).
                        self.keys[i].store(TOMBSTONE, Ordering::Release);
                        Ok(())
                    }
                    _ => Err(Some(cur)),
                }
            }
            None => match (expected, new) {
                (None, Some(v)) => {
                    let prev = self.upsert_locked(k, home, v);
                    debug_assert!(prev.is_none());
                    Ok(())
                }
                (None, None) => Ok(()),
                (Some(_), _) => Err(None),
            },
        }
    }

    /// `get_or_insert` body for a precomputed home bucket.
    fn get_or_insert_at(&self, key: u64, home: usize, value: u64) -> Option<u64> {
        assert!(value <= crate::kcas::MAX_VALUE);
        let k = key + BIAS;
        let _guard = self.lock_of(home).lock().unwrap();
        match self.find(k, home) {
            Some(i) => Some(self.vals[i].load(Ordering::Acquire)),
            None => {
                let prev = self.upsert_locked(k, home, value);
                debug_assert!(prev.is_none());
                None
            }
        }
    }

    /// `fetch_add` body for a precomputed home bucket.
    fn fetch_add_at(&self, key: u64, home: usize, delta: u64) -> Option<u64> {
        assert!(delta <= crate::kcas::MAX_VALUE);
        let k = key + BIAS;
        let _guard = self.lock_of(home).lock().unwrap();
        match self.find(k, home) {
            Some(i) => {
                let cur = self.vals[i].load(Ordering::Acquire);
                self.vals[i].store(
                    cur.wrapping_add(delta) & crate::kcas::MAX_VALUE,
                    Ordering::Release,
                );
                Some(cur)
            }
            None => {
                let prev = self.upsert_locked(k, home, delta);
                debug_assert!(prev.is_none());
                None
            }
        }
    }
}

impl ConcurrentMap for LockedLpMap {
    // The plain entry points route through the hashed twins (one
    // SplitMix64 per op, reused by the sharded facade).

    fn get(&self, key: u64) -> Option<u64> {
        self.get_hashed(splitmix64(key), key)
    }

    fn insert(&self, key: u64, value: u64) -> Option<u64> {
        self.insert_hashed(splitmix64(key), key, value)
    }

    fn remove(&self, key: u64) -> Option<u64> {
        self.remove_hashed(splitmix64(key), key)
    }

    fn compare_exchange(
        &self,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        self.compare_exchange_hashed(splitmix64(key), key, expected, new)
    }

    fn get_or_insert(&self, key: u64, value: u64) -> Option<u64> {
        self.get_or_insert_hashed(splitmix64(key), key, value)
    }

    fn fetch_add(&self, key: u64, delta: u64) -> Option<u64> {
        self.fetch_add_hashed(splitmix64(key), key, delta)
    }

    fn get_hashed(&self, h: u64, key: u64) -> Option<u64> {
        check_key(key);
        let home = (h & self.mask) as usize;
        let _guard = self.lock_of(home).lock().unwrap();
        self.find(key + BIAS, home)
            .map(|i| self.vals[i].load(Ordering::Acquire))
    }

    fn insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        check_key(key);
        assert!(value <= crate::kcas::MAX_VALUE);
        let home = (h & self.mask) as usize;
        let _guard = self.lock_of(home).lock().unwrap();
        self.upsert_locked(key + BIAS, home, value)
    }

    fn remove_hashed(&self, h: u64, key: u64) -> Option<u64> {
        check_key(key);
        let home = (h & self.mask) as usize;
        let _guard = self.lock_of(home).lock().unwrap();
        let i = self.find(key + BIAS, home)?;
        let v = self.vals[i].load(Ordering::Acquire);
        // Only same-key ops (serialised by the home lock) write a
        // claimed slot's key; a plain store back to TOMBSTONE is safe.
        self.keys[i].store(TOMBSTONE, Ordering::Release);
        Some(v)
    }

    fn compare_exchange_hashed(
        &self,
        h: u64,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        check_key(key);
        self.cmpex_at(key, (h & self.mask) as usize, expected, new)
    }

    fn get_or_insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        check_key(key);
        self.get_or_insert_at(key, (h & self.mask) as usize, value)
    }

    fn fetch_add_hashed(&self, h: u64, key: u64, delta: u64) -> Option<u64> {
        check_key(key);
        self.fetch_add_at(key, (h & self.mask) as usize, delta)
    }

    fn apply_txn(&self, ops: &[MapOp]) -> Result<Vec<MapReply>, TxnError> {
        txn::TxnBackend::apply_txn_routed(
            std::slice::from_ref(self),
            &|_| 0,
            ops,
        )
    }

    fn name(&self) -> &'static str {
        "locked-lp-map"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn len_quiesced(&self) -> usize {
        self.keys
            .iter()
            .filter(|b| {
                let v = b.load(Ordering::Acquire);
                v != EMPTY && v != TOMBSTONE
            })
            .count()
    }
}

/// **Two-phase locking** reference transaction: every key's
/// home-segment lock is acquired up front in global `(shard, segment)`
/// order (deadlock-free — single-key ops hold at most one lock and
/// never wait while holding it), then reads, overlay evaluation, and
/// writes all happen inside the critical section. Blocking but
/// trivially serialisable: the semantic oracle the K-CAS commit (and
/// the OCC baseline's anomalies) are measured against in `fig18_txn`.
impl txn::TxnBackend for LockedLpMap {
    fn apply_txn_routed(
        shards: &[Self],
        route: &dyn Fn(u64) -> usize,
        ops: &[MapOp],
    ) -> Result<Vec<MapReply>, TxnError> {
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        let m = metrics();
        m.txn_attempts.incr();
        m.txn_ops.record(ops.len() as u64);
        let (keys, key_of) = txn::collect_keys(ops);
        // Growing phase: sorted, deduplicated lock set.
        let mut lock_ids: Vec<(usize, usize)> = keys
            .iter()
            .map(|&k| {
                let h = splitmix64(k);
                let s = route(h);
                let shard = &shards[s];
                let home = (h & shard.mask) as usize;
                (s, (home >> shard.seg_log2) & (shard.locks.len() - 1))
            })
            .collect();
        lock_ids.sort_unstable();
        lock_ids.dedup();
        let _guards: Vec<_> = lock_ids
            .iter()
            .map(|&(s, l)| shards[s].locks[l].lock().unwrap())
            .collect();
        // Read, evaluate, write back — all inside the lock envelope.
        let reads: Vec<Option<u64>> = keys
            .iter()
            .map(|&k| {
                let h = splitmix64(k);
                let shard = &shards[route(h)];
                let home = (h & shard.mask) as usize;
                shard
                    .find(k + BIAS, home)
                    .map(|i| shard.vals[i].load(Ordering::Acquire))
            })
            .collect();
        let mut finals = reads.clone();
        let mut replies = Vec::with_capacity(ops.len());
        txn::eval_ops(ops, &key_of, &mut finals, &mut replies);
        for (idx, &k) in keys.iter().enumerate() {
            if reads[idx] == finals[idx] {
                continue;
            }
            let h = splitmix64(k);
            let shard = &shards[route(h)];
            let home = (h & shard.mask) as usize;
            match finals[idx] {
                Some(v) => {
                    shard.upsert_locked(k + BIAS, home, v);
                }
                None => {
                    if let Some(i) = shard.find(k + BIAS, home) {
                        shard.keys[i].store(TOMBSTONE, Ordering::Release);
                    }
                }
            }
        }
        m.txn_commits.incr();
        m.txn_span.record(keys.len() as u64);
        Ok(replies)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = LockedLp::new(8);
        assert!(t.add(1));
        assert!(!t.add(1));
        assert!(t.contains(1));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert!(!t.contains(1));
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "locked-lp matches HashSet",
            30,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = LockedLp::new(8);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_same_key_exactly_once() {
        let t = Arc::new(LockedLp::new(12));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=400u64).filter(|&k| t.add(k)).count()
            }));
        }
        let total: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 400);
        assert_eq!(t.len_quiesced(), 400);
    }

    #[test]
    fn small_table_one_lock() {
        // size 16 with 64-bucket segments -> single lock; still correct.
        let t = LockedLp::new(4);
        for k in 1..=10u64 {
            assert!(t.add(k));
        }
        assert_eq!(t.len_quiesced(), 10);
    }

    #[test]
    fn map_basic_semantics() {
        let m = LockedLpMap::new(8);
        assert_eq!(m.get(1), None);
        assert_eq!(m.insert(1, 100), None);
        assert_eq!(m.get(1), Some(100));
        assert_eq!(m.insert(1, 200), Some(100));
        assert_eq!(m.get(1), Some(200));
        assert_eq!(m.remove(1), Some(200));
        assert_eq!(m.remove(1), None);
        assert_eq!(m.get(1), None);
        assert_eq!(m.len_quiesced(), 0);
    }

    #[test]
    fn map_oracle_property_vs_hashmap() {
        use std::collections::HashMap;
        prop::check(
            "locked-lp-map matches HashMap",
            20,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| {
                        (r.below(3) as u8, 1 + r.below(48), r.below(1000))
                    })
                    .collect::<Vec<(u8, u64, u64)>>()
            },
            |ops| {
                let m = LockedLpMap::new(7);
                let mut oracle: HashMap<u64, u64> = HashMap::new();
                for &(op, key, val) in ops {
                    let (got, want) = match op {
                        0 => (m.insert(key, val), oracle.insert(key, val)),
                        1 => (m.remove(key), oracle.remove(&key)),
                        _ => (m.get(key), oracle.get(&key).copied()),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got:?} want {want:?}"
                        ));
                    }
                }
                if m.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn map_tombstone_reuse_keeps_pairs() {
        let m = LockedLpMap::new(6);
        for k in 1..=40u64 {
            m.insert(k, k * 10);
        }
        for k in (1..=40u64).step_by(2) {
            assert_eq!(m.remove(k), Some(k * 10));
        }
        // Re-insert through the tombstones with new values.
        for k in (1..=40u64).step_by(2) {
            assert_eq!(m.insert(k, k * 11), None);
        }
        for k in 1..=40u64 {
            let want = if k % 2 == 1 { k * 11 } else { k * 10 };
            assert_eq!(m.get(k), Some(want), "key {k}");
        }
    }

    #[test]
    fn map_conditional_ops_reference_semantics() {
        let m = LockedLpMap::new(8);
        assert_eq!(m.compare_exchange(5, None, None), Ok(()));
        assert_eq!(m.compare_exchange(5, Some(1), Some(2)), Err(None));
        assert_eq!(m.compare_exchange(5, None, Some(50)), Ok(()));
        assert_eq!(m.compare_exchange(5, None, Some(51)), Err(Some(50)));
        assert_eq!(m.compare_exchange(5, Some(50), Some(51)), Ok(()));
        assert_eq!(m.compare_exchange(5, Some(50), None), Err(Some(51)));
        assert_eq!(m.compare_exchange(5, Some(51), None), Ok(()));
        assert_eq!(m.get(5), None);
        assert_eq!(m.get_or_insert(6, 60), None);
        assert_eq!(m.get_or_insert(6, 61), Some(60));
        assert_eq!(m.fetch_add(6, 2), Some(60));
        assert_eq!(m.fetch_add(7, 9), None);
        assert_eq!(m.get(6), Some(62));
        assert_eq!(m.get(7), Some(9));
        // Conditional insert through a tombstone (reuse path).
        assert_eq!(m.remove(7), Some(9));
        assert_eq!(m.compare_exchange(7, None, Some(70)), Ok(()));
        assert_eq!(m.get(7), Some(70));
    }

    #[test]
    fn map_concurrent_fetch_add_is_atomic() {
        let m = Arc::new(LockedLpMap::new(8));
        const THREADS: u64 = 4;
        const INCS: u64 = 5_000;
        let mut hs = Vec::new();
        for _ in 0..THREADS {
            let m = m.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..INCS {
                    m.fetch_add(3, 1);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.get(3), Some(THREADS * INCS));
    }

    #[test]
    fn hashed_entry_points_agree_with_plain() {
        let t = LockedLp::new(7);
        let m = LockedLpMap::new(7);
        for k in 1..=50u64 {
            let h = splitmix64(k);
            assert!(ConcurrentSet::add_hashed(&t, h, k));
            assert!(ConcurrentSet::contains_hashed(&t, h, k));
            assert!(t.contains(k));
            assert_eq!(ConcurrentMap::insert_hashed(&m, h, k, k + 1), None);
            assert_eq!(ConcurrentMap::get_hashed(&m, h, k), Some(k + 1));
        }
        for k in (1..=50u64).step_by(2) {
            let h = splitmix64(k);
            assert!(ConcurrentSet::remove_hashed(&t, h, k));
            assert_eq!(ConcurrentMap::remove_hashed(&m, h, k), Some(k + 1));
        }
        assert_eq!(t.len_quiesced(), 25);
        assert_eq!(m.len_quiesced(), 25);
    }

    #[test]
    fn map_concurrent_pairs_never_tear() {
        // Value always encodes its key; concurrent churn must never
        // surface a mismatched pair through the locked read path.
        let m = Arc::new(LockedLpMap::new(8));
        const KEYS: u64 = 80;
        for k in 1..=KEYS {
            m.insert(k, k * 3);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        for tid in 0..2u64 {
            let (m, stop) = (m.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x11, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(KEYS);
                    m.remove(k);
                    m.insert(k, k * 3);
                }
            }));
        }
        for tid in 0..2u64 {
            let (m, stop) = (m.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x12, tid);
                for _ in 0..20_000 {
                    let k = 1 + r.below(KEYS);
                    if let Some(v) = m.get(k) {
                        assert_eq!(v, k * 3, "torn pair: key {k} value {v}");
                    }
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}
