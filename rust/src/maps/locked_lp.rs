//! Locked linear probing — the paper's blocking LP baseline ("a
//! standard linear probing scheme with the same locking strategy as
//! Hopscotch Hashing").
//!
//! Mutating operations take the home bucket's *segment lock* (sharded
//! exactly like Hopscotch/our timestamp shards); bucket writes are still
//! single-word atomics because a probe may claim a bucket in a
//! neighbouring segment. Reads are lock-free (linear probing never
//! relocates, so no validation is needed). Tombstone deletion gives the
//! contamination behaviour the paper discusses for Table 1.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::pad::CachePadded;

use super::{check_key, ConcurrentMap, ConcurrentSet};
use crate::util::hash::home_bucket;

const EMPTY: u64 = 0;
const TOMBSTONE: u64 = 1;
const BIAS: u64 = 2;

/// Buckets per lock segment (matches Hopscotch below).
pub const MIN_SEG_LOG2: u32 = 6;

pub struct LockedLp {
    table: Box<[AtomicU64]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    mask: u64,
    seg_log2: u32,
}

impl LockedLp {
    pub fn new(size_log2: u32) -> Self {
        // Bounded, cache-resident lock table (see kcas_rh).
        Self::with_segments(
            size_log2,
            super::kcas_rh::default_shard_log2(size_log2).max(MIN_SEG_LOG2),
        )
    }

    pub fn with_segments(size_log2: u32, seg_log2: u32) -> Self {
        let size = 1usize << size_log2;
        let nlocks = (size >> seg_log2).max(1);
        Self {
            table: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            locks: (0..nlocks)
                .map(|_| CachePadded::new(Mutex::new(())))
                .collect(),
            mask: (size - 1) as u64,
            seg_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn lock_of(&self, i: usize) -> &Mutex<()> {
        &self.locks[(i >> self.seg_log2) & (self.locks.len() - 1)]
    }
}

impl ConcurrentSet for LockedLp {
    fn contains(&self, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let mut i = home_bucket(key, self.mask);
        for _ in 0..self.size() {
            let cur = self.table[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return false;
            }
            if cur == k {
                return true;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn add(&self, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        // Same-key operations serialize on the home lock, so a
        // scan-then-claim with tombstone reuse is race-free for `key`;
        // claims still CAS because *other* keys (holding other locks)
        // may target the same bucket.
        'rescan: loop {
            let mut reusable: Option<usize> = None;
            let mut i = home;
            for _ in 0..=self.size() {
                let cur = self.table[i].load(Ordering::Acquire);
                if cur == k {
                    return false;
                }
                if cur == TOMBSTONE && reusable.is_none() {
                    reusable = Some(i);
                }
                if cur == EMPTY {
                    let slot = reusable.unwrap_or(i);
                    let expected = if reusable.is_some() { TOMBSTONE } else { EMPTY };
                    if self
                        .table[slot]
                        .compare_exchange(
                            expected,
                            k,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        return true;
                    }
                    continue 'rescan; // bucket stolen by another key
                }
                i = (i + 1) & self.mask as usize;
            }
            if let Some(slot) = reusable {
                if self
                    .table[slot]
                    .compare_exchange(
                        TOMBSTONE,
                        k,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return true;
                }
                continue 'rescan;
            }
            panic!("locked LP table is full");
        }
    }

    fn remove(&self, key: u64) -> bool {
        check_key(key);
        let k = key + BIAS;
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        let mut i = home;
        for _ in 0..self.size() {
            let cur = self.table[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return false;
            }
            if cur == k {
                return self
                    .table[i]
                    .compare_exchange(
                        k,
                        TOMBSTONE,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok();
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn name(&self) -> &'static str {
        "locked-lp"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let v = self.table[i].load(Ordering::Acquire);
                if v == EMPTY || v == TOMBSTONE {
                    -1
                } else {
                    crate::util::hash::dfb(
                        home_bucket(v - BIAS, self.mask),
                        i,
                        self.mask,
                    ) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        self.table
            .iter()
            .filter(|b| {
                let v = b.load(Ordering::Acquire);
                v != EMPTY && v != TOMBSTONE
            })
            .count()
    }
}

/// **Locked LP map** — the blocking key→value baseline for the service
/// layer, mirroring [`LockedLp`]'s segment-locking strategy.
///
/// Unlike the set, *all* operations (including `get`) take the home
/// bucket's segment lock: a map read must return the value *paired*
/// with the key, and the lock is what serialises same-key value
/// overwrites against readers (every operation on key `k` locks
/// `home(k)`'s segment, so the pair read cannot tear). Slots in
/// neighbouring segments are still claimed by CAS on the key word,
/// because a probe may cross segment boundaries; value words are only
/// ever written by operations on the key currently claiming the slot,
/// which the home lock serialises.
pub struct LockedLpMap {
    keys: Box<[AtomicU64]>,
    vals: Box<[AtomicU64]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    mask: u64,
    seg_log2: u32,
}

impl LockedLpMap {
    pub fn new(size_log2: u32) -> Self {
        let seg_log2 =
            super::kcas_rh::default_shard_log2(size_log2).max(MIN_SEG_LOG2);
        let size = 1usize << size_log2;
        let nlocks = (size >> seg_log2).max(1);
        Self {
            keys: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            vals: (0..size).map(|_| AtomicU64::new(0)).collect(),
            locks: (0..nlocks)
                .map(|_| CachePadded::new(Mutex::new(())))
                .collect(),
            mask: (size - 1) as u64,
            seg_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn lock_of(&self, i: usize) -> &Mutex<()> {
        &self.locks[(i >> self.seg_log2) & (self.locks.len() - 1)]
    }

    /// Probe for `key` (biased); `Some(slot)` if present. Caller holds
    /// the home-segment lock.
    fn find(&self, k: u64, home: usize) -> Option<usize> {
        let mut i = home;
        for _ in 0..self.size() {
            let cur = self.keys[i].load(Ordering::Acquire);
            if cur == EMPTY {
                return None;
            }
            if cur == k {
                return Some(i);
            }
            i = (i + 1) & self.mask as usize;
        }
        None
    }
}

impl ConcurrentMap for LockedLpMap {
    fn get(&self, key: u64) -> Option<u64> {
        check_key(key);
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        self.find(key + BIAS, home)
            .map(|i| self.vals[i].load(Ordering::Acquire))
    }

    fn insert(&self, key: u64, value: u64) -> Option<u64> {
        check_key(key);
        assert!(value <= crate::kcas::MAX_VALUE);
        let k = key + BIAS;
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        'rescan: loop {
            let mut reusable: Option<usize> = None;
            let mut i = home;
            for _ in 0..=self.size() {
                let cur = self.keys[i].load(Ordering::Acquire);
                if cur == k {
                    // Overwrite in place: same-key ops hold this lock.
                    return Some(self.vals[i].swap(value, Ordering::AcqRel));
                }
                if cur == TOMBSTONE && reusable.is_none() {
                    reusable = Some(i);
                }
                if cur == EMPTY {
                    let slot = reusable.unwrap_or(i);
                    let expected =
                        if reusable.is_some() { TOMBSTONE } else { EMPTY };
                    if self
                        .keys[slot]
                        .compare_exchange(
                            expected,
                            k,
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_ok()
                    {
                        self.vals[slot].store(value, Ordering::Release);
                        return None;
                    }
                    continue 'rescan; // bucket stolen by another key
                }
                i = (i + 1) & self.mask as usize;
            }
            if let Some(slot) = reusable {
                if self
                    .keys[slot]
                    .compare_exchange(
                        TOMBSTONE,
                        k,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    self.vals[slot].store(value, Ordering::Release);
                    return None;
                }
                continue 'rescan;
            }
            panic!("locked LP map is full");
        }
    }

    fn remove(&self, key: u64) -> Option<u64> {
        check_key(key);
        let home = home_bucket(key, self.mask);
        let _guard = self.lock_of(home).lock().unwrap();
        let i = self.find(key + BIAS, home)?;
        let v = self.vals[i].load(Ordering::Acquire);
        // Only same-key ops (serialised by the home lock) write a
        // claimed slot's key; a plain store back to TOMBSTONE is safe.
        self.keys[i].store(TOMBSTONE, Ordering::Release);
        Some(v)
    }

    fn name(&self) -> &'static str {
        "locked-lp-map"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn len_quiesced(&self) -> usize {
        self.keys
            .iter()
            .filter(|b| {
                let v = b.load(Ordering::Acquire);
                v != EMPTY && v != TOMBSTONE
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = LockedLp::new(8);
        assert!(t.add(1));
        assert!(!t.add(1));
        assert!(t.contains(1));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert!(!t.contains(1));
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "locked-lp matches HashSet",
            30,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = LockedLp::new(8);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_same_key_exactly_once() {
        let t = Arc::new(LockedLp::new(12));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=400u64).filter(|&k| t.add(k)).count()
            }));
        }
        let total: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 400);
        assert_eq!(t.len_quiesced(), 400);
    }

    #[test]
    fn small_table_one_lock() {
        // size 16 with 64-bucket segments -> single lock; still correct.
        let t = LockedLp::new(4);
        for k in 1..=10u64 {
            assert!(t.add(k));
        }
        assert_eq!(t.len_quiesced(), 10);
    }

    #[test]
    fn map_basic_semantics() {
        let m = LockedLpMap::new(8);
        assert_eq!(m.get(1), None);
        assert_eq!(m.insert(1, 100), None);
        assert_eq!(m.get(1), Some(100));
        assert_eq!(m.insert(1, 200), Some(100));
        assert_eq!(m.get(1), Some(200));
        assert_eq!(m.remove(1), Some(200));
        assert_eq!(m.remove(1), None);
        assert_eq!(m.get(1), None);
        assert_eq!(m.len_quiesced(), 0);
    }

    #[test]
    fn map_oracle_property_vs_hashmap() {
        use std::collections::HashMap;
        prop::check(
            "locked-lp-map matches HashMap",
            20,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| {
                        (r.below(3) as u8, 1 + r.below(48), r.below(1000))
                    })
                    .collect::<Vec<(u8, u64, u64)>>()
            },
            |ops| {
                let m = LockedLpMap::new(7);
                let mut oracle: HashMap<u64, u64> = HashMap::new();
                for &(op, key, val) in ops {
                    let (got, want) = match op {
                        0 => (m.insert(key, val), oracle.insert(key, val)),
                        1 => (m.remove(key), oracle.remove(&key)),
                        _ => (m.get(key), oracle.get(&key).copied()),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got:?} want {want:?}"
                        ));
                    }
                }
                if m.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn map_tombstone_reuse_keeps_pairs() {
        let m = LockedLpMap::new(6);
        for k in 1..=40u64 {
            m.insert(k, k * 10);
        }
        for k in (1..=40u64).step_by(2) {
            assert_eq!(m.remove(k), Some(k * 10));
        }
        // Re-insert through the tombstones with new values.
        for k in (1..=40u64).step_by(2) {
            assert_eq!(m.insert(k, k * 11), None);
        }
        for k in 1..=40u64 {
            let want = if k % 2 == 1 { k * 11 } else { k * 10 };
            assert_eq!(m.get(k), Some(want), "key {k}");
        }
    }

    #[test]
    fn map_concurrent_pairs_never_tear() {
        // Value always encodes its key; concurrent churn must never
        // surface a mismatched pair through the locked read path.
        let m = Arc::new(LockedLpMap::new(8));
        const KEYS: u64 = 80;
        for k in 1..=KEYS {
            m.insert(k, k * 3);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        for tid in 0..2u64 {
            let (m, stop) = (m.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x11, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(KEYS);
                    m.remove(k);
                    m.insert(k, k * 3);
                }
            }));
        }
        for tid in 0..2u64 {
            let (m, stop) = (m.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(0x12, tid);
                for _ in 0..20_000 {
                    let k = 1 + r.below(KEYS);
                    if let Some(v) = m.get(k) {
                        assert_eq!(v, k * 3, "torn pair: key {k} value {v}");
                    }
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}
