//! Lock-free linear probing (Nielsen & Karlsson [29]) — baseline.
//!
//! An open-addressing set where every bucket is a single word moving
//! through [29]'s state machine:
//!
//! ```text
//!   EMPTY ──claim──> BUSY ──publish──> INSERTING(k) ──win──> MEMBER(k)
//!     ^                                     │  lose/remove        │ remove
//!     └────────── (reusable) COLLIDED <─────┴─────────────────────┘
//! ```
//!
//! Matching the implementation the paper benchmarks, buckets hold a
//! **pointer to a heap node** (§4.2: "lock-free linear probing ...
//! use[s] dynamic memory allocation, meaning that a pointer dereference
//! is needed for every bucket access") — this is what blows up its
//! cache-miss row in Table 1. The INSERTING/MEMBER distinction rides in
//! the pointer's low bit; removed/defeated nodes are leaked (the paper
//! runs all algorithms without a memory reclaimer).
//!
//! `COLLIDED` doubles as the tombstone state and is *recycled* by later
//! insertions — without recycling, an update-heavy run exhausts the
//! table. Duplicate-key races on recycled buckets are resolved by the
//! publish-then-verify protocol: an inserter that finds another
//! `INSERTING(k)` at an earlier probe position, or a `MEMBER(k)`
//! anywhere, self-collides and reports the key already present.

use std::sync::atomic::{AtomicU64, Ordering};

use super::{check_key, ConcurrentSet};
use crate::util::hash::{home_bucket, splitmix64};

const EMPTY: u64 = 0;
const BUSY: u64 = 1;
const COLLIDED: u64 = 2;
/// Low bit set on a node pointer = still INSERTING (not yet a member).
const INS_BIT: u64 = 1;

#[repr(align(16))]
struct Node {
    key: u64,
}

#[inline]
fn is_ptr(w: u64) -> bool {
    w > 15
}

#[inline]
fn node_key(w: u64) -> u64 {
    debug_assert!(is_ptr(w));
    // SAFETY: a bucket word > 15 is always a published node pointer
    // (16-byte alignment keeps real addresses above the sentinel
    // range), and nodes are never freed while the set lives.
    unsafe { (*((w & !INS_BIT) as *const Node)).key }
}

#[inline]
fn is_key_state(w: u64, key: u64) -> bool {
    is_ptr(w) && node_key(w) == key
}

#[inline]
fn is_member(w: u64) -> bool {
    is_ptr(w) && w & INS_BIT == 0
}

pub struct LockFreeLp {
    table: Box<[AtomicU64]>,
    mask: u64,
}

// SAFETY: raw node pointers are confined to the bucket protocol —
// published by CAS into the atomic bucket words and never freed while
// the set lives (reclaimer-free, as in the paper's setup).
unsafe impl Send for LockFreeLp {}
// SAFETY: as for Send — all shared mutation goes through the bucket
// atomics.
unsafe impl Sync for LockFreeLp {}

impl LockFreeLp {
    pub fn new(size_log2: u32) -> Self {
        let size = 1usize << size_log2;
        Self {
            table: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            mask: (size - 1) as u64,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn load(&self, i: usize) -> u64 {
        self.table[i].load(Ordering::Acquire)
    }
}

impl ConcurrentSet for LockFreeLp {
    // The plain trio routes through the hashed twins so the sharded
    // facade's routing hash is reused for the home bucket instead of
    // recomputed (the benches compare tables off the same entry
    // points, so the baseline shouldn't pay a second SplitMix64).

    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let mut i = (h & self.mask) as usize;
        for _ in 0..self.size() {
            let cur = self.load(i);
            if cur == EMPTY {
                return false;
            }
            if is_key_state(cur, key) {
                return true;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        let mut node: *mut Node = std::ptr::null_mut();
        'retry: loop {
            // Phase 1: scan the cluster for the key and the first
            // reusable bucket.
            let mut reusable: Option<usize> = None;
            let mut i = home;
            let mut end = None;
            for _ in 0..self.size() {
                let cur = self.load(i);
                if is_key_state(cur, key) {
                    if !node.is_null() {
                        // SAFETY: `node` is our own allocation and was
                        // never published (its insert CAS didn't run).
                        unsafe { drop(Box::from_raw(node)) };
                    }
                    return false;
                }
                if cur == COLLIDED && reusable.is_none() {
                    reusable = Some(i);
                }
                if cur == EMPTY {
                    end = Some(i);
                    break;
                }
                i = (i + 1) & self.mask as usize;
            }
            let slot = match reusable.or(end) {
                Some(s) => s,
                None => panic!("lock-free LP table is full"),
            };
            // Phase 2: claim and publish (dynamic allocation per entry,
            // as in the paper's benchmarked implementation).
            let expected = if Some(slot) == end { EMPTY } else { COLLIDED };
            if self
                .table[slot]
                .compare_exchange(expected, BUSY, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue 'retry; // lost the claim; rescan
            }
            if node.is_null() {
                node = Box::into_raw(Box::new(Node { key }));
            }
            let ins = node as u64 | INS_BIT;
            self.table[slot].store(ins, Ordering::Release);
            // Phase 3: verify. Lose to any MEMBER(k), or to an
            // INSERTING(k) at an earlier probe position.
            let my_dist = (slot.wrapping_sub(home)) & self.mask as usize;
            let mut j = home;
            for d in 0..self.size() {
                if j != slot {
                    let cur = self.load(j);
                    if cur == EMPTY {
                        break;
                    }
                    if is_key_state(cur, key) && (is_member(cur) || d < my_dist)
                    {
                        // Self-collide; if the CAS fails, a remover
                        // already took our visible insert (add+remove —
                        // still a successful add). Node leaks either way
                        // (no reclaimer, per the paper).
                        return self
                            .table[slot]
                            .compare_exchange(
                                ins,
                                COLLIDED,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_err();
                    }
                }
                j = (j + 1) & self.mask as usize;
            }
            // Phase 4: commit. Failure means a remover deleted our
            // in-flight insert — still a successful add.
            let _ = self.table[slot].compare_exchange(
                ins,
                node as u64,
                Ordering::AcqRel,
                Ordering::Acquire,
            );
            return true;
        }
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let mut i = (h & self.mask) as usize;
        for _ in 0..self.size() {
            let cur = self.load(i);
            if cur == EMPTY {
                return false;
            }
            if is_key_state(cur, key) {
                // Delete the earliest visible instance (node leaks).
                if self
                    .table[i]
                    .compare_exchange(
                        cur,
                        COLLIDED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    return true;
                }
                // State changed under us (concurrent remove, or the
                // inserter committed INSERTING -> MEMBER): re-examine.
                continue;
            }
            i = (i + 1) & self.mask as usize;
        }
        false
    }

    fn name(&self) -> &'static str {
        "lockfree-lp"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let v = self.load(i);
                if !is_ptr(v) {
                    -1 // EMPTY / BUSY / COLLIDED
                } else {
                    crate::util::hash::dfb(
                        home_bucket(node_key(v), self.mask),
                        i,
                        self.mask,
                    ) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        self.table
            .iter()
            .filter(|b| is_ptr(b.load(Ordering::Acquire)))
            .count()
    }
}

impl LockFreeLp {
    /// Tombstone (COLLIDED) count — the contamination metric.
    pub fn tombstones(&self) -> usize {
        self.table
            .iter()
            .filter(|b| b.load(Ordering::Acquire) == COLLIDED)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = LockFreeLp::new(8);
        assert!(t.add(1));
        assert!(!t.add(1));
        assert!(t.contains(1));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert!(!t.contains(1));
        assert_eq!(t.tombstones(), 1);
    }

    #[test]
    fn tombstones_are_recycled() {
        // Endless add/remove of the same working set must not exhaust
        // the table (the whole point of COLLIDED recycling).
        let t = LockFreeLp::new(6); // 64 buckets
        for round in 0..100u64 {
            for k in 1..=40u64 {
                assert!(t.add(k), "round {round} add {k}");
            }
            for k in 1..=40u64 {
                assert!(t.remove(k), "round {round} remove {k}");
            }
        }
        assert_eq!(t.len_quiesced(), 0);
        assert!(t.tombstones() <= 64);
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "lockfree-lp matches HashSet",
            30,
            |r: &mut Rng| {
                (0..400)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = LockFreeLp::new(8);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                if t.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hashed_entry_points_agree_with_plain() {
        let t = LockFreeLp::new(8);
        for k in 1..=60u64 {
            let h = splitmix64(k);
            assert!(ConcurrentSet::add_hashed(&t, h, k));
            assert!(!t.add(k));
            assert!(ConcurrentSet::contains_hashed(&t, h, k));
        }
        for k in (1..=60u64).step_by(2) {
            assert!(ConcurrentSet::remove_hashed(&t, splitmix64(k), k));
            assert!(!t.contains(k));
        }
        assert_eq!(t.len_quiesced(), 30);
    }

    #[test]
    fn concurrent_no_duplicates_no_losses() {
        let t = Arc::new(LockFreeLp::new(12));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=500u64).filter(|&k| t.add(k)).count() as u64
            }));
        }
        let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 500, "duplicate or lost insertions");
        assert_eq!(t.len_quiesced(), 500);
    }

    #[test]
    fn concurrent_recycled_buckets_stay_consistent() {
        // Heavy same-key churn over a tiny table: exercises COLLIDED
        // recycling + verify-phase races.
        let t = Arc::new(LockFreeLp::new(7));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(71, tid);
                for _ in 0..10_000 {
                    let k = 1 + r.below(32);
                    match r.below(3) {
                        0 => {
                            t.add(k);
                        }
                        1 => {
                            t.remove(k);
                        }
                        _ => {
                            t.contains(k);
                        }
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // No duplicate visible instances of any key.
        for k in 1..=32u64 {
            let visible = (0..t.size())
                .filter(|&i| is_key_state(t.load(i), k))
                .count();
            assert!(visible <= 1, "key {k} visible {visible} times");
        }
    }

    #[test]
    fn concurrent_remove_exactly_once() {
        let t = Arc::new(LockFreeLp::new(12));
        for k in 1..=500u64 {
            t.add(k);
        }
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=500u64).filter(|&k| t.remove(k)).count() as u64
            }));
        }
        let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 500);
        assert_eq!(t.len_quiesced(), 0);
    }
}
