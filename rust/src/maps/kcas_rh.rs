//! **K-CAS Robin Hood** — the paper's core contribution (§3).
//!
//! An obstruction-free Robin Hood hash set built on [`crate::kcas`]:
//!
//! * every bucket is a K-CAS [`Word`] holding a key (0 = Nil);
//! * a sharded *timestamp* array (one K-CAS word per
//!   `2^ts_shard_log2` buckets, cache-padded — paper Fig. 6) versions
//!   table regions;
//! * `Add` summarises its whole displacement chain (Fig. 1) plus one
//!   timestamp increment per touched shard into a single K-CAS
//!   descriptor (Fig. 8);
//! * `Remove` does the same for its backward-shift chain (Figs. 4, 9);
//! * `Contains` records the timestamps seen along its probe and, on a
//!   miss, re-validates them — retrying if any region moved under it
//!   (Fig. 7), which closes the paper's Fig. 5 reader/remover race.
//!
//! Progress (paper §3.5): `Contains` and the miss path of `Remove` are
//! obstruction-free; `Add` and the hit path of `Remove` inherit the
//! K-CAS's progress (lock-free phase-1 installs with helping).

use std::cell::RefCell;

use crate::util::pad::CachePadded;

use super::{check_key, ConcurrentSet};
use crate::kcas::{OpBuilder, Word};
use crate::util::hash::{dfb, home_bucket, splitmix64};

const NIL: u64 = 0;

/// Timestamp sharding: at least 64 buckets per shard, and at most
/// `2^MAX_TS_SHARDS_LOG2` shards in total. The paper shards timestamps
/// "identical to how locks are sharded in blocking hash tables like
/// Hopscotch" — a *bounded* lock table, not one lock per 64 buckets.
/// Keeping the timestamp array small (8192 shards × 128 B = 1 MiB)
/// keeps it cache-resident, which is what lets K-CAS Robin Hood's read
/// path stay at ~1 memory miss per probe (§Perf in EXPERIMENTS.md:
/// 3.1 → 5.0 ops/µs single-core at 2^23 from this change alone).
pub const MIN_BUCKETS_PER_SHARD_LOG2: u32 = 6;
pub const MAX_TS_SHARDS_LOG2: u32 = 13;

/// Shard exponent for a given table size.
pub(crate) fn default_shard_log2(size_log2: u32) -> u32 {
    MIN_BUCKETS_PER_SHARD_LOG2
        .max(size_log2.saturating_sub(MAX_TS_SHARDS_LOG2))
}

/// Per-thread scratch: descriptor builder + timestamp lists, reused
/// across operations so the hot path never allocates.
struct Scratch {
    op: OpBuilder,
    /// (shard, value) pairs recorded during a probe, for validation.
    seen: Vec<(usize, u64)>,
    /// (shard, value) pairs to increment in the descriptor.
    bump: Vec<(usize, u64)>,
    /// Backward-shift chain values observed during `remove`.
    chain: Vec<u64>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch {
        op: OpBuilder::new(),
        seen: Vec::with_capacity(64),
        bump: Vec::with_capacity(64),
        chain: Vec::with_capacity(64),
    });
}

/// The paper's K-CAS Robin Hood hash set.
pub struct KCasRobinHood {
    table: Box<[Word]>,
    ts: Box<[CachePadded<Word>]>,
    mask: u64,
    ts_shard_log2: u32,
}

impl KCasRobinHood {
    pub fn new(size_log2: u32) -> Self {
        Self::with_shards(size_log2, default_shard_log2(size_log2))
    }

    /// `2^size_log2` buckets, `2^ts_shard_log2` buckets per timestamp.
    pub fn with_shards(size_log2: u32, ts_shard_log2: u32) -> Self {
        let size = 1usize << size_log2;
        let shards = (size >> ts_shard_log2).max(1);
        Self {
            table: (0..size).map(|_| Word::new(NIL)).collect(),
            ts: (0..shards).map(|_| CachePadded::new(Word::new(0))).collect(),
            mask: (size - 1) as u64,
            ts_shard_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn shard_of(&self, i: usize) -> usize {
        (i >> self.ts_shard_log2) & (self.ts.len() - 1)
    }

    /// Bucket word without bounds check (all indices are pre-masked).
    #[inline(always)]
    fn bucket(&self, i: usize) -> &Word {
        debug_assert!(i < self.table.len());
        unsafe { self.table.get_unchecked(i) }
    }

    /// Timestamp word without bounds check (shard_of masks).
    #[inline(always)]
    fn ts_word(&self, shard: usize) -> &Word {
        debug_assert!(shard < self.ts.len());
        unsafe { &self.ts.get_unchecked(shard) }
    }

    #[inline]
    fn dist(&self, key: u64, i: usize) -> u64 {
        dfb(home_bucket(key, self.mask), i, self.mask)
    }

    /// Record `shard`'s current timestamp in `list` if it isn't the most
    /// recent entry (probes move linearly, so shards repeat contiguously).
    #[inline]
    fn record_ts(&self, list: &mut Vec<(usize, u64)>, i: usize) {
        let shard = self.shard_of(i);
        if list.last().map(|&(s, _)| s) != Some(shard) {
            list.push((shard, self.ts_word(shard).read()));
        }
    }
}

impl KCasRobinHood {
    /// Slow-path `contains` (probe crosses timestamp shards): record
    /// every shard's timestamp in the per-thread scratch list.
    #[cold]
    fn contains_multi_shard(&self, key: u64, home: usize) -> bool {
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let seen = &mut guard.seen;
            'retry: loop {
                seen.clear();
                let mut i = home;
                let mut found_key = false;
                let mut cur_dist = 0u64;
                loop {
                    // Timestamp BEFORE the key read (Fig. 7 line 9-10).
                    self.record_ts(seen, i);
                    let cur = self.bucket(i).read();
                    if cur == key {
                        found_key = true;
                        break;
                    }
                    if cur == NIL {
                        break;
                    }
                    // Robin Hood invariant cut-off (lines 13-14).
                    if self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break; // table full of other keys
                    }
                }
                if found_key {
                    return true;
                }
                // Miss: validate every recorded timestamp (lines 16-21).
                for &(shard, v) in seen.iter() {
                    if self.ts_word(shard).read() != v {
                        continue 'retry;
                    }
                }
                return false;
            }
        })
    }
}

impl ConcurrentSet for KCasRobinHood {
    /// Paper Fig. 7, with a fast path for the common case where the
    /// whole probe stays inside one timestamp shard (~96% of probes at
    /// 64+ buckets/shard): the single (shard, timestamp) pair lives in
    /// registers — no thread-local scratch, no heap traffic.
    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    /// Hashed entry point (ROADMAP item): the sharded facade already
    /// computed `splitmix64(key)` for routing; the home bucket is just
    /// `h & mask`, so no second hash here.
    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        'retry: loop {
            let shard0 = self.shard_of(home);
            let ts0 = self.ts_word(shard0).read();
            let mut i = home;
            let mut cur_dist = 0u64;
            loop {
                if self.shard_of(i) != shard0 {
                    // Probe crosses into another shard: take the
                    // general multi-shard path from scratch.
                    return self.contains_multi_shard(key, home);
                }
                let cur = self.bucket(i).read();
                if cur == key {
                    return true;
                }
                if cur == NIL {
                    break;
                }
                if self.dist(cur, i) < cur_dist {
                    break;
                }
                i = (i + 1) & self.mask as usize;
                cur_dist += 1;
                if cur_dist as usize > self.size() {
                    break;
                }
            }
            // Miss: validate the single recorded timestamp (Fig. 7
            // lines 16-21 degenerate to one comparison).
            if self.ts_word(shard0).read() == ts0 {
                return false;
            }
            continue 'retry;
        }
    }

    /// Paper Fig. 8.
    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            'retry: loop {
                scratch.op.clear();
                scratch.bump.clear();
                let mut active = key;
                let mut active_dist = 0u64;
                let mut i = home;
                let mut probes = 0usize;
                loop {
                    assert!(
                        probes <= self.size(),
                        "K-CAS Robin Hood table is full"
                    );
                    probes += 1;
                    let shard = self.shard_of(i);
                    // Timestamp read precedes the key read (line 10-11).
                    let ts_val = self.ts_word(shard).read();
                    let cur = self.bucket(i).read();
                    if cur == NIL {
                        // Lines 12-16: commit the whole reorganisation.
                        scratch.op.push(self.bucket(i), NIL, active);
                        for &(sh, v) in scratch.bump.iter() {
                            scratch.op.push(self.ts_word(sh), v, v + 1);
                        }
                        if scratch.op.execute() {
                            return true;
                        }
                        continue 'retry;
                    }
                    if cur == key {
                        return false; // line 18: already a member
                    }
                    let cur_d = self.dist(cur, i);
                    if cur_d < active_dist {
                        // Lines 19-26: steal from the rich.
                        scratch.op.push(self.bucket(i), cur, active);
                        // add_timestamp_increment (line 23): dedup by
                        // most-recent shard — probes advance linearly.
                        if scratch.bump.last().map(|&(s2, _)| s2) != Some(shard)
                        {
                            scratch.bump.push((shard, ts_val));
                        }
                        active = cur;
                        active_dist = cur_d;
                    }
                    i = (i + 1) & self.mask as usize;
                    active_dist += 1;
                }
            }
        })
    }

    /// Paper Fig. 9.
    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            'retry: loop {
                scratch.seen.clear();
                scratch.op.clear();
                scratch.bump.clear();
                let mut i = home;
                let mut cur_dist = 0u64;
                let mut hit = false;
                loop {
                    self.record_ts(&mut scratch.seen, i);
                    let cur = self.bucket(i).read();
                    if cur == NIL {
                        break;
                    }
                    if cur == key {
                        hit = true;
                        break;
                    }
                    if self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break;
                    }
                }
                if !hit {
                    // Miss path: timestamp validation (lines 23-28).
                    for &(shard, v) in scratch.seen.iter() {
                        if self.ts_word(shard).read() != v {
                            continue 'retry;
                        }
                    }
                    return false;
                }
                // Hit at bucket i: backward-shift chain (shuffle_items).
                // Collect successor keys until Nil or an at-home entry.
                scratch.chain.clear();
                scratch.chain.push(key);
                // Timestamp of the removal bucket itself.
                {
                    let shard = self.shard_of(i);
                    let v = scratch
                        .seen
                        .iter()
                        .rev()
                        .find(|&&(s2, _)| s2 == shard)
                        .map(|&(_, v)| v)
                        .unwrap_or_else(|| self.ts_word(shard).read());
                    scratch.bump.push((shard, v));
                }
                let mut j = (i + 1) & self.mask as usize;
                loop {
                    let shard = self.shard_of(j);
                    let ts_val = self.ts_word(shard).read();
                    let nk = self.bucket(j).read();
                    if nk == NIL || self.dist(nk, j) == 0 {
                        break;
                    }
                    if scratch.bump.last().map(|&(s2, _)| s2) != Some(shard) {
                        scratch.bump.push((shard, ts_val));
                    }
                    scratch.chain.push(nk);
                    j = (j + 1) & self.mask as usize;
                    if scratch.chain.len() > self.size() {
                        continue 'retry; // table churned under us
                    }
                }
                // Descriptor: shift each chain entry back one bucket and
                // Nil the last, plus the timestamp bumps.
                let mut pos = i;
                for w in 0..scratch.chain.len() {
                    let next_val = scratch
                        .chain
                        .get(w + 1)
                        .copied()
                        .unwrap_or(NIL);
                    scratch.op.push(self.bucket(pos), scratch.chain[w], next_val);
                    pos = (pos + 1) & self.mask as usize;
                }
                for &(sh, v) in scratch.bump.iter() {
                    scratch.op.push(self.ts_word(sh), v, v + 1);
                }
                if scratch.op.execute() {
                    return true;
                }
                continue 'retry;
            }
        })
    }

    fn name(&self) -> &'static str {
        "kcas-rh"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let k = self.table[i].read();
                if k == NIL {
                    -1
                } else {
                    self.dist(k, i) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        (0..self.size())
            .filter(|&i| self.table[i].read() != NIL)
            .count()
    }
}

impl KCasRobinHood {
    /// Robin Hood invariant over the whole table (quiesced only):
    /// an entry with DFB > 0 must follow an occupied bucket whose DFB
    /// is at least DFB - 1.
    pub fn check_invariant(&self) -> Result<(), String> {
        let n = self.size();
        for i in 0..n {
            let k = self.table[i].read();
            if k == NIL {
                continue;
            }
            let d = self.dist(k, i);
            if d == 0 {
                continue;
            }
            let pi = (i + n - 1) & self.mask as usize;
            let prev = self.table[pi].read();
            if prev == NIL {
                return Err(format!(
                    "bucket {i}: key {k} dfb {d} after empty bucket"
                ));
            }
            let pd = self.dist(prev, pi);
            if d > pd + 1 {
                return Err(format!("bucket {i}: dfb {d} > prev dfb {pd}+1"));
            }
        }
        Ok(())
    }

    /// Key stored at bucket `i`, if occupied (quiesced use: resize
    /// migration, diagnostics).
    pub fn key_at(&self, i: usize) -> Option<u64> {
        let k = self.table[i].read();
        if k == NIL {
            None
        } else {
            Some(k)
        }
    }

    /// Sum of all timestamp values (diagnostics: total relocations).
    pub fn total_relocations(&self) -> u64 {
        self.ts.iter().map(|t| t.read()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = KCasRobinHood::new(8);
        assert!(!t.contains(3));
        assert!(t.add(3));
        assert!(!t.add(3));
        assert!(t.contains(3));
        assert!(t.remove(3));
        assert!(!t.remove(3));
        assert!(!t.contains(3));
        assert_eq!(t.len_quiesced(), 0);
    }

    #[test]
    fn displacement_chains_at_high_lf() {
        let t = KCasRobinHood::new(10);
        let n = (1024.0 * 0.85) as u64;
        for k in 1..=n {
            assert!(t.add(k));
        }
        t.check_invariant().unwrap();
        for k in 1..=n {
            assert!(t.contains(k), "lost {k}");
        }
        assert!(!t.contains(n + 1));
        assert_eq!(t.len_quiesced(), n as usize);
    }

    #[test]
    fn remove_backward_shift() {
        let t = KCasRobinHood::new(8);
        for k in 1..=180u64 {
            t.add(k);
        }
        for k in (1..=180u64).step_by(3) {
            assert!(t.remove(k));
        }
        t.check_invariant().unwrap();
        for k in 1..=180u64 {
            assert_eq!(t.contains(k), k % 3 != 1, "key {k}");
        }
    }

    #[test]
    fn timestamps_advance_on_relocation() {
        let t = KCasRobinHood::new(6);
        for k in 1..=50u64 {
            t.add(k);
        }
        let before = t.total_relocations();
        for k in 1..=25u64 {
            t.remove(k);
        }
        // Backward shifts at 78% LF must have bumped timestamps.
        assert!(t.total_relocations() > before);
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "kcas-rh matches HashSet",
            25,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = KCasRobinHood::new(7);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                t.check_invariant()?;
                if t.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_disjoint_threads_deterministic() {
        let t = Arc::new(KCasRobinHood::new(12));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 1000;
                for k in base..base + 300 {
                    assert!(t.add(k));
                }
                for k in (base..base + 300).step_by(2) {
                    assert!(t.remove(k));
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
        assert_eq!(t.len_quiesced(), 8 * 150);
        for tid in 0..8u64 {
            let base = 1 + tid * 1000;
            for k in base..base + 300 {
                assert_eq!(t.contains(k), (k - base) % 2 == 1);
            }
        }
    }

    #[test]
    fn concurrent_contended_churn() {
        // All threads fight over the same small key range; afterwards
        // the table must be internally consistent and agree with a
        // replay count bound.
        let t = Arc::new(KCasRobinHood::new(9));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(99, tid);
                for _ in 0..4000 {
                    let k = 1 + r.below(128);
                    match r.below(3) {
                        0 => {
                            t.add(k);
                        }
                        1 => {
                            t.remove(k);
                        }
                        _ => {
                            t.contains(k);
                        }
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
        // Every remaining key must be findable (internal consistency).
        let snap = t.dfb_snapshot();
        let mut live = 0;
        for (i, &d) in snap.iter().enumerate() {
            if d >= 0 {
                let k = t.table[i].read();
                assert!(t.contains(k), "table holds {k} but contains=false");
                live += 1;
            }
        }
        assert_eq!(live, t.len_quiesced());
    }

    #[test]
    fn fig5_reader_remover_race_regression() {
        // The paper's Fig. 5 scenario: a reader probing for a key that a
        // concurrent remover's backward shift keeps relocating. Without
        // timestamp validation the reader could miss a present key.
        // Here keys CHURN+1.. stay in the table forever; readers must
        // never observe them absent.
        let t = Arc::new(KCasRobinHood::new(7));
        const CHURN: u64 = 60;
        for k in 1..=CHURN + 30 {
            t.add(k);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        // Remover/re-adder churns the low keys, forcing backward shifts.
        for tid in 0..2u64 {
            let t = t.clone();
            let stop = stop.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(5, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(CHURN);
                    t.remove(k);
                    t.add(k);
                }
            }));
        }
        // Readers: stable keys must always be present.
        for tid in 0..4u64 {
            let t = t.clone();
            let stop = stop.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(7, tid);
                let mut checks = 0u64;
                while checks < 30_000 {
                    let k = CHURN + 1 + r.below(30);
                    assert!(
                        t.contains(k),
                        "Fig. 5 race: stable key {k} reported absent"
                    );
                    checks += 1;
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
    }

    #[test]
    fn hashed_entry_points_agree_with_plain() {
        let t = KCasRobinHood::new(8);
        for k in 1..=120u64 {
            let h = crate::util::hash::splitmix64(k);
            assert!(t.add_hashed(h, k));
            assert!(!t.add(k));
            assert!(t.contains_hashed(h, k));
            assert!(t.contains(k));
        }
        for k in (1..=120u64).step_by(2) {
            let h = crate::util::hash::splitmix64(k);
            assert!(t.remove_hashed(h, k));
            assert!(!t.remove(k));
            assert!(!t.contains_hashed(h, k));
        }
        t.check_invariant().unwrap();
        assert_eq!(t.len_quiesced(), 60);
    }

    #[test]
    fn custom_shard_width() {
        let t = KCasRobinHood::with_shards(8, 2); // 4 buckets per shard
        for k in 1..=100u64 {
            t.add(k);
        }
        assert_eq!(t.len_quiesced(), 100);
        t.check_invariant().unwrap();
    }
}
