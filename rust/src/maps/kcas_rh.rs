//! **K-CAS Robin Hood** — the paper's core contribution (§3).
//!
//! An obstruction-free Robin Hood hash set built on [`crate::kcas`]:
//!
//! * every bucket is a K-CAS [`Word`] holding a key (0 = Nil);
//! * a sharded *timestamp* array (one K-CAS word per
//!   `2^ts_shard_log2` buckets, cache-padded — paper Fig. 6) versions
//!   table regions;
//! * `Add` summarises its whole displacement chain (Fig. 1) plus one
//!   timestamp increment per touched shard into a single K-CAS
//!   descriptor (Fig. 8);
//! * `Remove` does the same for its backward-shift chain (Figs. 4, 9);
//! * `Contains` records the timestamps seen along its probe and, on a
//!   miss, re-validates them — retrying if any region moved under it
//!   (Fig. 7), which closes the paper's Fig. 5 reader/remover race.
//!
//! Progress (paper §3.5): `Contains` and the miss path of `Remove` are
//! obstruction-free; `Add` and the hit path of `Remove` inherit the
//! K-CAS's progress (lock-free phase-1 installs with helping).
//!
//! ## Write-path guards (beyond the paper's Fig. 8/9)
//!
//! Two descriptor entries were added to make concurrent reorganisation
//! *mutually visible* between writers (the paper's timestamps only
//! protect readers):
//!
//! * `Add` includes one timestamp **guard** (`v -> v`, a no-op CAS) per
//!   shard it probed *over* without displacing. Without it, an add that
//!   probed bucket `j-1` while occupied could commit its key at `j`
//!   after a concurrent remove's backward shift turned `j-1` into Nil —
//!   stranding the new key behind an empty bucket, unreachable to every
//!   probe (an append-past-fresh-Nil variant of the Fig. 5 race).
//! * `Remove` includes a value guard on its chain **terminator** (the
//!   Nil or at-home bucket that ended the shift scan). Without it, an
//!   add landing in that Nil (or a displacement enriching the at-home
//!   key) between the scan and the commit would leave a key stranded
//!   past the freshly shifted-in Nil.
//!
//! Both guards are also what make the two-generation migration in
//! [`super::resizable`] sound: they uphold the invariant that no live
//! key is ever stored beyond an empty (or migration-frozen-empty)
//! bucket of its probe run.
//!
//! ## Migration marks (two-generation incremental resize)
//!
//! [`super::resizable::IncResizableRobinHood`] freezes this table one
//! bucket at a time while draining it into a double-size successor. A
//! frozen bucket holds one of two reserved words above [`super::MAX_KEY`]:
//! [`FROZEN_EMPTY`] (was Nil — still a probe terminator, nothing can be
//! inserted here again) or [`FROZEN_TOMB`] (its key was transferred to
//! the next generation in the same K-CAS — probes must skip it without
//! applying the Robin Hood distance cut-off, because the original key's
//! DFB is no longer recoverable). The `*_mig` entry points surface
//! frozen sightings to the wrapper instead of retrying; the plain trait
//! entry points never observe a frozen word (only the wrapper freezes).

use std::cell::RefCell;

use crate::util::pad::CachePadded;

use super::{check_key, ConcurrentSet};
use crate::kcas::{OpBuilder, Word};
use crate::util::hash::{dfb, home_bucket, splitmix64};
use crate::util::metrics::metrics;

const NIL: u64 = 0;

/// Migration mark for a bucket whose key was transferred to the next
/// generation (the transfer K-CAS swings `key -> FROZEN_TOMB`). Probes
/// skip it without the distance cut-off. Above `MAX_KEY`, so it can
/// never collide with a live key.
pub(crate) const FROZEN_TOMB: u64 = (1 << 62) - 1;

/// Migration mark for a bucket frozen while empty (`Nil ->
/// FROZEN_EMPTY`). Still a probe terminator: nothing was ever stored
/// past it in any run, and nothing can be inserted into it again.
pub(crate) const FROZEN_EMPTY: u64 = (1 << 62) - 2;

/// Is `v` one of the two migration marks?
#[inline(always)]
pub(crate) fn is_frozen(v: u64) -> bool {
    v >= FROZEN_EMPTY
}

/// A migration-frozen bucket was encountered: this generation cannot
/// answer the operation; the resizable wrapper must re-route it.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Frozen;

/// Outcome of a frozen-aware membership probe ([`KCasRobinHood::probe_mig`]).
pub(crate) enum Probe {
    /// The key is live in this generation.
    Found,
    /// Definitive miss: no frozen bucket seen along the (timestamp-
    /// validated) probe, so the key is in no generation as of the probe.
    Absent,
    /// Timestamp-validated miss in *this* generation, but the probe
    /// crossed frozen buckets — the key may live in the next one.
    FrozenMiss,
}

/// One attempt of a write path: probe + (at most) one K-CAS.
enum Attempt {
    /// The operation committed (or concluded without needing a CAS);
    /// the payload is the operation's return value.
    Done(bool),
    /// The K-CAS (or a miss validation) lost a race; re-probe.
    Raced,
}

/// Timestamp sharding: at least 64 buckets per shard, and at most
/// `2^MAX_TS_SHARDS_LOG2` shards in total. The paper shards timestamps
/// "identical to how locks are sharded in blocking hash tables like
/// Hopscotch" — a *bounded* lock table, not one lock per 64 buckets.
/// Keeping the timestamp array small (8192 shards × 128 B = 1 MiB)
/// keeps it cache-resident, which is what lets K-CAS Robin Hood's read
/// path stay at ~1 memory miss per probe (§Perf in EXPERIMENTS.md:
/// 3.1 → 5.0 ops/µs single-core at 2^23 from this change alone).
pub const MIN_BUCKETS_PER_SHARD_LOG2: u32 = 6;
pub const MAX_TS_SHARDS_LOG2: u32 = 13;

/// Shard exponent for a given table size.
pub(crate) fn default_shard_log2(size_log2: u32) -> u32 {
    MIN_BUCKETS_PER_SHARD_LOG2
        .max(size_log2.saturating_sub(MAX_TS_SHARDS_LOG2))
}

/// Per-thread scratch: descriptor builder + timestamp lists, reused
/// across operations so the hot path never allocates.
struct Scratch {
    op: OpBuilder,
    /// (shard, value) pairs recorded during a probe, for validation.
    seen: Vec<(usize, u64)>,
    /// (shard, value) pairs to increment in the descriptor.
    bump: Vec<(usize, u64)>,
    /// Backward-shift chain values observed during `remove`.
    chain: Vec<u64>,
    /// `(shard, first-seen timestamp, displaced-here)` recorded along an
    /// add probe: displaced shards get a bump (`v -> v+1`), probed-over
    /// shards a guard (`v -> v`) — see the module docs.
    guard: Vec<(usize, u64, bool)>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch {
        op: OpBuilder::new(),
        seen: Vec::with_capacity(64),
        bump: Vec::with_capacity(64),
        chain: Vec::with_capacity(64),
        guard: Vec::with_capacity(64),
    });
}

/// The paper's K-CAS Robin Hood hash set.
pub struct KCasRobinHood {
    table: Box<[Word]>,
    ts: Box<[CachePadded<Word>]>,
    mask: u64,
    ts_shard_log2: u32,
}

impl KCasRobinHood {
    pub fn new(size_log2: u32) -> Self {
        Self::with_shards(size_log2, default_shard_log2(size_log2))
    }

    /// `2^size_log2` buckets, `2^ts_shard_log2` buckets per timestamp.
    pub fn with_shards(size_log2: u32, ts_shard_log2: u32) -> Self {
        let size = 1usize << size_log2;
        let shards = (size >> ts_shard_log2).max(1);
        Self {
            table: (0..size).map(|_| Word::new(NIL)).collect(),
            ts: (0..shards).map(|_| CachePadded::new(Word::new(0))).collect(),
            mask: (size - 1) as u64,
            ts_shard_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn shard_of(&self, i: usize) -> usize {
        (i >> self.ts_shard_log2) & (self.ts.len() - 1)
    }

    /// Bucket word without bounds check (all indices are pre-masked).
    #[inline(always)]
    fn bucket(&self, i: usize) -> &Word {
        debug_assert!(i < self.table.len());
        // SAFETY: every caller masks `i` by the power-of-two table
        // mask, so it is always in bounds (debug-asserted above).
        unsafe { self.table.get_unchecked(i) }
    }

    /// Timestamp word without bounds check (shard_of masks).
    #[inline(always)]
    fn ts_word(&self, shard: usize) -> &Word {
        debug_assert!(shard < self.ts.len());
        // SAFETY: shard_of masks by the power-of-two shard-array
        // length, so `shard` is always in bounds.
        unsafe { self.ts.get_unchecked(shard) }
    }

    #[inline]
    fn dist(&self, key: u64, i: usize) -> u64 {
        dfb(home_bucket(key, self.mask), i, self.mask)
    }

    /// Record `shard`'s current timestamp in `list` if it isn't the most
    /// recent entry (probes move linearly, so shards repeat contiguously).
    #[inline]
    fn record_ts(&self, list: &mut Vec<(usize, u64)>, i: usize) {
        let shard = self.shard_of(i);
        if list.last().map(|&(s, _)| s) != Some(shard) {
            list.push((shard, self.ts_word(shard).read()));
        }
    }
}

impl KCasRobinHood {
    /// Slow-path `contains` (probe crosses timestamp shards): record
    /// every shard's timestamp in the per-thread scratch list.
    #[cold]
    fn contains_multi_shard(&self, key: u64, home: usize) -> bool {
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let seen = &mut guard.seen;
            'retry: loop {
                seen.clear();
                let mut i = home;
                let mut found_key = false;
                let mut cur_dist = 0u64;
                loop {
                    // Timestamp BEFORE the key read (Fig. 7 line 9-10).
                    self.record_ts(seen, i);
                    let cur = self.bucket(i).read();
                    if cur == key {
                        found_key = true;
                        break;
                    }
                    if cur == NIL {
                        break;
                    }
                    // Robin Hood invariant cut-off (lines 13-14).
                    if self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break; // table full of other keys
                    }
                }
                if found_key {
                    metrics().probe_len_read.record(cur_dist + 1);
                    return true;
                }
                // Miss: validate every recorded timestamp (lines 16-21).
                for &(shard, v) in seen.iter() {
                    if self.ts_word(shard).read() != v {
                        continue 'retry;
                    }
                }
                metrics().probe_len_read.record(cur_dist + 1);
                return false;
            }
        })
    }
}

impl ConcurrentSet for KCasRobinHood {
    /// Paper Fig. 7, with a fast path for the common case where the
    /// whole probe stays inside one timestamp shard (~96% of probes at
    /// 64+ buckets/shard): the single (shard, timestamp) pair lives in
    /// registers — no thread-local scratch, no heap traffic.
    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    /// Hashed entry point (ROADMAP item): the sharded facade already
    /// computed `splitmix64(key)` for routing; the home bucket is just
    /// `h & mask`, so no second hash here.
    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        'retry: loop {
            let shard0 = self.shard_of(home);
            let ts0 = self.ts_word(shard0).read();
            let mut i = home;
            let mut cur_dist = 0u64;
            loop {
                if self.shard_of(i) != shard0 {
                    // Probe crosses into another shard: take the
                    // general multi-shard path from scratch.
                    return self.contains_multi_shard(key, home);
                }
                let cur = self.bucket(i).read();
                if cur == key {
                    metrics().probe_len_read.record(cur_dist + 1);
                    return true;
                }
                if cur == NIL {
                    break;
                }
                if self.dist(cur, i) < cur_dist {
                    break;
                }
                i = (i + 1) & self.mask as usize;
                cur_dist += 1;
                if cur_dist as usize > self.size() {
                    break;
                }
            }
            // Miss: validate the single recorded timestamp (Fig. 7
            // lines 16-21 degenerate to one comparison).
            if self.ts_word(shard0).read() == ts0 {
                metrics().probe_len_read.record(cur_dist + 1);
                return false;
            }
            continue 'retry;
        }
    }

    /// Paper Fig. 8.
    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        match self.add_mig(h, key) {
            Ok(added) => added,
            // Only the resizable wrapper ever freezes buckets, and it
            // routes all traffic through `add_mig` itself.
            Err(Frozen) => unreachable!("frozen bucket in standalone table"),
        }
    }

    /// Paper Fig. 9.
    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        match self.remove_mig(h, key) {
            Ok(removed) => removed,
            Err(Frozen) => unreachable!("frozen bucket in standalone table"),
        }
    }

    fn name(&self) -> &'static str {
        "kcas-rh"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let k = self.table[i].read();
                if k == NIL {
                    -1
                } else {
                    self.dist(k, i) as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        (0..self.size())
            .filter(|&i| self.table[i].read() != NIL)
            .count()
    }
}

/// Write paths (single-attempt bodies shared by the plain entry points,
/// the migration-aware `*_mig` twins, and the generation-transfer
/// machinery) and the migration primitives themselves.
impl KCasRobinHood {
    /// One full `add` attempt (paper Fig. 8): probe, build the
    /// displacement descriptor, execute one K-CAS. `seed` is an extra
    /// entry `(word, expected, new)` committed atomically with the
    /// insert — the generation transfer passes the source bucket here
    /// (`key -> FROZEN_TOMB`) so a key is never in two generations.
    ///
    /// `Done(false)` (already a member) never commits the seed.
    fn try_add_one(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
        seed: Option<(&Word, u64, u64)>,
    ) -> Result<Attempt, Frozen> {
        scratch.op.clear();
        scratch.guard.clear();
        let mut active = key;
        let mut active_dist = 0u64;
        let mut i = home;
        let mut probes = 0usize;
        let mut displaced = 0u64;
        loop {
            assert!(probes <= self.size(), "K-CAS Robin Hood table is full");
            probes += 1;
            let shard = self.shard_of(i);
            // Timestamp read precedes the key read (line 10-11).
            let ts_val = self.ts_word(shard).read();
            let cur = self.bucket(i).read();
            if is_frozen(cur) {
                return Err(Frozen);
            }
            if cur == NIL {
                // Lines 12-16: commit the whole reorganisation, plus
                // one timestamp bump per displaced shard and one guard
                // per probed-over shard (module docs).
                scratch.op.push(self.bucket(i), NIL, active);
                for &(sh, v, displaced) in scratch.guard.iter() {
                    scratch.op.push(self.ts_word(sh), v, v + u64::from(displaced));
                }
                if let Some((word, old, new)) = seed {
                    scratch.op.push(word, old, new);
                }
                metrics().probe_len_write.record(probes as u64);
                return Ok(if scratch.op.execute() {
                    metrics().rh_displacements.add(displaced);
                    Attempt::Done(true)
                } else {
                    Attempt::Raced
                });
            }
            if cur == key {
                metrics().probe_len_write.record(probes as u64);
                return Ok(Attempt::Done(false)); // line 18: member
            }
            // Probed over an occupied bucket: its shard's timestamp now
            // guards this attempt (dedup by most-recent shard — probes
            // advance linearly, so shards repeat contiguously).
            if scratch.guard.last().map(|&(s2, _, _)| s2) != Some(shard) {
                scratch.guard.push((shard, ts_val, false));
            }
            let cur_d = self.dist(cur, i);
            if cur_d < active_dist {
                // Lines 19-26: steal from the rich; upgrade the shard's
                // guard to a bump (add_timestamp_increment, line 23).
                scratch.op.push(self.bucket(i), cur, active);
                if let Some(last) = scratch.guard.last_mut() {
                    last.2 = true;
                }
                displaced += 1;
                active = cur;
                active_dist = cur_d;
            }
            i = (i + 1) & self.mask as usize;
            active_dist += 1;
        }
    }

    /// One full `remove` attempt (paper Fig. 9): probe, collect the
    /// backward-shift chain, execute one K-CAS.
    fn try_remove_one(
        &self,
        scratch: &mut Scratch,
        home: usize,
        key: u64,
    ) -> Result<Attempt, Frozen> {
        scratch.seen.clear();
        scratch.op.clear();
        scratch.bump.clear();
        let mut i = home;
        let mut cur_dist = 0u64;
        let mut hit = false;
        loop {
            self.record_ts(&mut scratch.seen, i);
            let cur = self.bucket(i).read();
            if is_frozen(cur) {
                return Err(Frozen);
            }
            if cur == NIL {
                break;
            }
            if cur == key {
                hit = true;
                break;
            }
            if self.dist(cur, i) < cur_dist {
                break;
            }
            i = (i + 1) & self.mask as usize;
            cur_dist += 1;
            if cur_dist as usize > self.size() {
                break;
            }
        }
        metrics().probe_len_write.record(cur_dist + 1);
        if !hit {
            // Miss path: timestamp validation (lines 23-28).
            for &(shard, v) in scratch.seen.iter() {
                if self.ts_word(shard).read() != v {
                    return Ok(Attempt::Raced);
                }
            }
            return Ok(Attempt::Done(false));
        }
        // Hit at bucket i: backward-shift chain (shuffle_items).
        // Collect successor keys until Nil or an at-home entry.
        scratch.chain.clear();
        scratch.chain.push(key);
        // Timestamp of the removal bucket itself.
        {
            let shard = self.shard_of(i);
            let v = scratch
                .seen
                .iter()
                .rev()
                .find(|&&(s2, _)| s2 == shard)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| self.ts_word(shard).read());
            scratch.bump.push((shard, v));
        }
        let mut j = (i + 1) & self.mask as usize;
        let terminator;
        loop {
            let shard = self.shard_of(j);
            let ts_val = self.ts_word(shard).read();
            let nk = self.bucket(j).read();
            if is_frozen(nk) {
                // The shift chain crosses a migrating region: the
                // wrapper must re-route this remove to the new
                // generation (after freezing the key's home run).
                return Err(Frozen);
            }
            if nk == NIL || self.dist(nk, j) == 0 {
                // Chain terminator. Guard its value in the descriptor:
                // an add landing in this Nil (or a displacement
                // enriching this at-home key) between scan and commit
                // would extend the chain under us (module docs).
                terminator = (j, nk);
                break;
            }
            if scratch.bump.last().map(|&(s2, _)| s2) != Some(shard) {
                scratch.bump.push((shard, ts_val));
            }
            scratch.chain.push(nk);
            j = (j + 1) & self.mask as usize;
            if scratch.chain.len() > self.size() {
                return Ok(Attempt::Raced); // table churned under us
            }
        }
        // Descriptor: shift each chain entry back one bucket and Nil
        // the last, plus the terminator guard and the timestamp bumps.
        let Scratch { op, chain, bump, .. } = scratch;
        let mut pos = i;
        for (w, &cur) in chain.iter().enumerate() {
            let next_val = chain.get(w + 1).copied().unwrap_or(NIL);
            op.push(self.bucket(pos), cur, next_val);
            pos = (pos + 1) & self.mask as usize;
        }
        op.push(self.bucket(terminator.0), terminator.1, terminator.1);
        for &(sh, v) in bump.iter() {
            op.push(self.ts_word(sh), v, v + 1);
        }
        Ok(if op.execute() { Attempt::Done(true) } else { Attempt::Raced })
    }

    /// Migration-aware `add`: like [`ConcurrentSet::add_hashed`] but
    /// surfaces frozen sightings instead of looping on them.
    pub(crate) fn add_mig(&self, h: u64, key: u64) -> Result<bool, Frozen> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            loop {
                match self.try_add_one(scratch, home, key, None)? {
                    Attempt::Done(r) => return Ok(r),
                    Attempt::Raced => continue,
                }
            }
        })
    }

    /// Migration-aware `remove`.
    pub(crate) fn remove_mig(&self, h: u64, key: u64) -> Result<bool, Frozen> {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            loop {
                match self.try_remove_one(scratch, home, key)? {
                    Attempt::Done(r) => return Ok(r),
                    Attempt::Raced => continue,
                }
            }
        })
    }

    /// Frozen-aware membership probe (wrapper fast path *and* the
    /// source-generation read during migration). `FROZEN_TOMB` is
    /// skipped without the distance cut-off; `FROZEN_EMPTY` terminates
    /// like Nil. Misses are timestamp-validated exactly like Fig. 7
    /// before either `Absent` or `FrozenMiss` is trusted.
    pub(crate) fn probe_mig(&self, h: u64, key: u64) -> Probe {
        check_key(key);
        let home = (h & self.mask) as usize;
        SCRATCH.with(|s| {
            let mut guard = s.borrow_mut();
            let seen = &mut guard.seen;
            'retry: loop {
                seen.clear();
                let mut saw_frozen = false;
                let mut i = home;
                let mut cur_dist = 0u64;
                loop {
                    self.record_ts(seen, i);
                    let cur = self.bucket(i).read();
                    if cur == key {
                        metrics().probe_len_read.record(cur_dist + 1);
                        return Probe::Found;
                    }
                    if cur == NIL {
                        break;
                    }
                    if cur == FROZEN_EMPTY {
                        saw_frozen = true;
                        break;
                    }
                    if cur == FROZEN_TOMB {
                        saw_frozen = true; // skip; DFB unknowable
                        metrics().tombstone_drift.incr();
                    } else if self.dist(cur, i) < cur_dist {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    cur_dist += 1;
                    if cur_dist as usize > self.size() {
                        break;
                    }
                }
                for &(shard, v) in seen.iter() {
                    if self.ts_word(shard).read() != v {
                        continue 'retry;
                    }
                }
                metrics().probe_len_read.record(cur_dist + 1);
                return if saw_frozen { Probe::FrozenMiss } else { Probe::Absent };
            }
        })
    }

    /// Freeze every bucket in `[start, start+len)` of this (source)
    /// generation, transferring live keys into `target`. Idempotent and
    /// safe to race with other helpers. Returns the keys moved by this
    /// caller.
    pub(crate) fn migrate_range(
        &self,
        target: &KCasRobinHood,
        start: usize,
        len: usize,
    ) -> usize {
        let mut moved = 0;
        for i in start..(start + len).min(self.size()) {
            moved += self.freeze_bucket(target, i);
        }
        moved
    }

    /// Freeze bucket `i` (empty -> [`FROZEN_EMPTY`], live key ->
    /// transferred + [`FROZEN_TOMB`]); returns how many keys this call
    /// moved (0 or 1).
    pub(crate) fn freeze_bucket(&self, target: &KCasRobinHood, i: usize) -> usize {
        loop {
            let cur = self.bucket(i).read();
            if is_frozen(cur) {
                return 0;
            }
            if cur == NIL {
                if self.bucket(i).cas(NIL, FROZEN_EMPTY) {
                    return 0;
                }
            } else if self.transfer(target, i, cur) {
                return 1;
            }
            // Lost a race (bucket churned under us): re-read.
        }
    }

    /// Freeze `key`'s whole home run in this source generation: from the
    /// home bucket forward, transfer every live key and freeze every
    /// Nil, stopping once a frozen-empty terminator exists. Afterwards
    /// the key definitively does not live in this generation and can
    /// never re-enter it (adds abort on the frozen marks), so the caller
    /// may operate on `target` alone.
    pub(crate) fn migrate_home_run(&self, target: &KCasRobinHood, h: u64) -> usize {
        let mut moved = 0;
        let mut i = (h & self.mask) as usize;
        let mut steps = 0usize;
        loop {
            let cur = self.bucket(i).read();
            if cur == FROZEN_EMPTY {
                return moved;
            }
            if cur == NIL {
                if self.bucket(i).cas(NIL, FROZEN_EMPTY) {
                    return moved;
                }
                continue; // bucket changed; re-read
            }
            if cur == FROZEN_TOMB {
                i = (i + 1) & self.mask as usize;
                steps += 1;
                if steps > self.size() {
                    return moved; // whole table already frozen
                }
                continue;
            }
            if self.transfer(target, i, cur) {
                moved += 1;
            }
            // Re-read bucket i: on success it is now FROZEN_TOMB.
        }
    }

    /// Move live `key` (read from source bucket `i`) into `target` and
    /// tombstone the source bucket in **one K-CAS** — readers never see
    /// the key in zero or two generations. Returns false if the source
    /// bucket changed underneath (caller re-reads).
    fn transfer(&self, target: &KCasRobinHood, i: usize, key: u64) -> bool {
        let h = splitmix64(key);
        let home = (h & target.mask) as usize;
        let seed = Some((self.bucket(i), key, FROZEN_TOMB));
        SCRATCH.with(|s| {
            let scratch = &mut *s.borrow_mut();
            match target.try_add_one(scratch, home, key, seed) {
                Ok(Attempt::Done(true)) => true,
                Ok(Attempt::Done(false)) => {
                    // Already in `target`: cannot happen under the
                    // freeze protocol (writers freeze a key's whole home
                    // run before inserting it into the next generation).
                    // Defensively freeze without duplicating.
                    self.bucket(i).cas(key, FROZEN_TOMB)
                }
                Ok(Attempt::Raced) => false,
                // Frozen target: this thread stalled across a whole
                // migration — helpers drained the source, a chained
                // migration began freezing `target`, and our probe of
                // it hit a mark. Our seed (source bucket still holding
                // `key`) can no longer match either; report no-move and
                // let the caller re-read the (now tombstoned) bucket.
                Err(Frozen) => false,
            }
        })
    }
}

impl KCasRobinHood {
    /// Robin Hood invariant over the whole table (quiesced only):
    /// an entry with DFB > 0 must follow an occupied bucket whose DFB
    /// is at least DFB - 1.
    pub fn check_invariant(&self) -> Result<(), String> {
        let n = self.size();
        for i in 0..n {
            let k = self.table[i].read();
            if k == NIL {
                continue;
            }
            let d = self.dist(k, i);
            if d == 0 {
                continue;
            }
            let pi = (i + n - 1) & self.mask as usize;
            let prev = self.table[pi].read();
            if prev == NIL {
                return Err(format!(
                    "bucket {i}: key {k} dfb {d} after empty bucket"
                ));
            }
            let pd = self.dist(prev, pi);
            if d > pd + 1 {
                return Err(format!("bucket {i}: dfb {d} > prev dfb {pd}+1"));
            }
        }
        Ok(())
    }

    /// Key stored at bucket `i`, if occupied (quiesced use: resize
    /// migration, diagnostics).
    pub fn key_at(&self, i: usize) -> Option<u64> {
        let k = self.table[i].read();
        if k == NIL {
            None
        } else {
            Some(k)
        }
    }

    /// Sum of all timestamp values (diagnostics: total relocations).
    pub fn total_relocations(&self) -> u64 {
        self.ts.iter().map(|t| t.read()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = KCasRobinHood::new(8);
        assert!(!t.contains(3));
        assert!(t.add(3));
        assert!(!t.add(3));
        assert!(t.contains(3));
        assert!(t.remove(3));
        assert!(!t.remove(3));
        assert!(!t.contains(3));
        assert_eq!(t.len_quiesced(), 0);
    }

    #[test]
    fn displacement_chains_at_high_lf() {
        let t = KCasRobinHood::new(10);
        let n = (1024.0 * 0.85) as u64;
        for k in 1..=n {
            assert!(t.add(k));
        }
        t.check_invariant().unwrap();
        for k in 1..=n {
            assert!(t.contains(k), "lost {k}");
        }
        assert!(!t.contains(n + 1));
        assert_eq!(t.len_quiesced(), n as usize);
    }

    #[test]
    fn remove_backward_shift() {
        let t = KCasRobinHood::new(8);
        for k in 1..=180u64 {
            t.add(k);
        }
        for k in (1..=180u64).step_by(3) {
            assert!(t.remove(k));
        }
        t.check_invariant().unwrap();
        for k in 1..=180u64 {
            assert_eq!(t.contains(k), k % 3 != 1, "key {k}");
        }
    }

    #[test]
    fn timestamps_advance_on_relocation() {
        let t = KCasRobinHood::new(6);
        for k in 1..=50u64 {
            t.add(k);
        }
        let before = t.total_relocations();
        for k in 1..=25u64 {
            t.remove(k);
        }
        // Backward shifts at 78% LF must have bumped timestamps.
        assert!(t.total_relocations() > before);
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "kcas-rh matches HashSet",
            25,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = KCasRobinHood::new(7);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                t.check_invariant()?;
                if t.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_disjoint_threads_deterministic() {
        let t = Arc::new(KCasRobinHood::new(12));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let base = 1 + tid * 1000;
                for k in base..base + 300 {
                    assert!(t.add(k));
                }
                for k in (base..base + 300).step_by(2) {
                    assert!(t.remove(k));
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
        assert_eq!(t.len_quiesced(), 8 * 150);
        for tid in 0..8u64 {
            let base = 1 + tid * 1000;
            for k in base..base + 300 {
                assert_eq!(t.contains(k), (k - base) % 2 == 1);
            }
        }
    }

    #[test]
    fn concurrent_contended_churn() {
        // All threads fight over the same small key range; afterwards
        // the table must be internally consistent and agree with a
        // replay count bound.
        let t = Arc::new(KCasRobinHood::new(9));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(99, tid);
                for _ in 0..4000 {
                    let k = 1 + r.below(128);
                    match r.below(3) {
                        0 => {
                            t.add(k);
                        }
                        1 => {
                            t.remove(k);
                        }
                        _ => {
                            t.contains(k);
                        }
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
        // Every remaining key must be findable (internal consistency).
        let snap = t.dfb_snapshot();
        let mut live = 0;
        for (i, &d) in snap.iter().enumerate() {
            if d >= 0 {
                let k = t.table[i].read();
                assert!(t.contains(k), "table holds {k} but contains=false");
                live += 1;
            }
        }
        assert_eq!(live, t.len_quiesced());
    }

    #[test]
    fn fig5_reader_remover_race_regression() {
        // The paper's Fig. 5 scenario: a reader probing for a key that a
        // concurrent remover's backward shift keeps relocating. Without
        // timestamp validation the reader could miss a present key.
        // Here keys CHURN+1.. stay in the table forever; readers must
        // never observe them absent.
        let t = Arc::new(KCasRobinHood::new(7));
        const CHURN: u64 = 60;
        for k in 1..=CHURN + 30 {
            t.add(k);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        // Remover/re-adder churns the low keys, forcing backward shifts.
        for tid in 0..2u64 {
            let t = t.clone();
            let stop = stop.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(5, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(CHURN);
                    t.remove(k);
                    t.add(k);
                }
            }));
        }
        // Readers: stable keys must always be present.
        for tid in 0..4u64 {
            let t = t.clone();
            let stop = stop.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(7, tid);
                let mut checks = 0u64;
                while checks < 30_000 {
                    let k = CHURN + 1 + r.below(30);
                    assert!(
                        t.contains(k),
                        "Fig. 5 race: stable key {k} reported absent"
                    );
                    checks += 1;
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
    }

    #[test]
    fn hashed_entry_points_agree_with_plain() {
        let t = KCasRobinHood::new(8);
        for k in 1..=120u64 {
            let h = crate::util::hash::splitmix64(k);
            assert!(t.add_hashed(h, k));
            assert!(!t.add(k));
            assert!(t.contains_hashed(h, k));
            assert!(t.contains(k));
        }
        for k in (1..=120u64).step_by(2) {
            let h = crate::util::hash::splitmix64(k);
            assert!(t.remove_hashed(h, k));
            assert!(!t.remove(k));
            assert!(!t.contains_hashed(h, k));
        }
        t.check_invariant().unwrap();
        assert_eq!(t.len_quiesced(), 60);
    }

    #[test]
    fn custom_shard_width() {
        let t = KCasRobinHood::with_shards(8, 2); // 4 buckets per shard
        for k in 1..=100u64 {
            t.add(k);
        }
        assert_eq!(t.len_quiesced(), 100);
        t.check_invariant().unwrap();
    }

    #[test]
    fn migrate_range_drains_every_key() {
        let src = KCasRobinHood::new(7);
        let dst = KCasRobinHood::new(8);
        for k in 1..=80u64 {
            src.add(k);
        }
        let moved = src.migrate_range(&dst, 0, src.capacity());
        assert_eq!(moved, 80);
        assert_eq!(dst.len_quiesced(), 80);
        dst.check_invariant().unwrap();
        for k in 1..=80u64 {
            assert!(dst.contains(k), "lost {k} in transfer");
        }
        // Source is fully frozen: every bucket holds a mark, and probes
        // report FrozenMiss rather than a clean Absent.
        for i in 0..src.capacity() {
            assert!(is_frozen(src.table[i].read()), "bucket {i} not frozen");
        }
        assert!(matches!(
            src.probe_mig(splitmix64(81), 81),
            Probe::FrozenMiss
        ));
    }

    #[test]
    fn migrate_home_run_evicts_the_key() {
        let src = KCasRobinHood::new(7);
        let dst = KCasRobinHood::new(8);
        for k in 1..=60u64 {
            src.add(k);
        }
        for k in [1u64, 17, 42] {
            let h = splitmix64(k);
            src.migrate_home_run(&dst, h);
            // The key left the source atomically and landed in target.
            assert!(!matches!(src.probe_mig(h, k), Probe::Found));
            assert!(dst.contains(k), "{k} not transferred");
            // Idempotent: a second run freeze is a no-op.
            assert_eq!(src.migrate_home_run(&dst, h), 0);
        }
        // Untouched runs still answer from the source.
        let mut found_in_src = 0;
        for k in 1..=60u64 {
            if matches!(src.probe_mig(splitmix64(k), k), Probe::Found) {
                found_in_src += 1;
            }
        }
        assert!(found_in_src > 0, "home-run freeze drained the whole table");
    }

    #[test]
    fn frozen_buckets_abort_writers() {
        let t = KCasRobinHood::new(7);
        let key = 5u64;
        let h = splitmix64(key);
        let home = (h & t.mask) as usize;
        assert!(t.bucket(home).cas(NIL, FROZEN_EMPTY));
        assert!(t.add_mig(h, key).is_err(), "add must abort on frozen home");
        assert!(matches!(t.probe_mig(h, key), Probe::FrozenMiss));
    }

    #[test]
    fn probe_mig_matches_contains_on_clean_tables() {
        let t = KCasRobinHood::new(8);
        for k in 1..=120u64 {
            t.add(k);
        }
        for k in 1..=240u64 {
            let h = splitmix64(k);
            match t.probe_mig(h, k) {
                Probe::Found => assert!(t.contains(k)),
                Probe::Absent => assert!(!t.contains(k)),
                Probe::FrozenMiss => panic!("frozen in standalone table"),
            }
        }
    }
}
