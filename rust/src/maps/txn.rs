//! **Multi-key transactions** — the paper's K-CAS substrate, finally
//! used for what it is: `k` words committed atomically per §2.3, where
//! `k` now spans *several keys* (and, through [`super::sharded`],
//! several shards' bucket arrays) instead of one bucket chain.
//!
//! ## Commit protocol (`commit_kcas`)
//!
//! One attempt is three phases against [`super::kcas_rh_map::KCasRobinHoodMap`]
//! tables:
//!
//! 1. **Read** — every unique key gets one timestamp-validated probe
//!    (`txn_read`), yielding its bucket + value or a validated miss.
//! 2. **Evaluate** — the op list is folded over those reads as a pure
//!    overlay ([`eval_ops`]): replies are computed, and each key ends
//!    with one *net* transition (e.g. `Insert` then `Remove` of the
//!    same key nets to "must stay absent").
//! 3. **Plan + commit** — each key's net transition is lowered to
//!    physical word entries in a shared [`TxnScratch`]:
//!
//!    * present → present: key-word + value-word pin at the phase-1
//!      bucket (a pure pairing guard when the value is unchanged);
//!    * absent → present: the insert probe's Nil claim, displacement
//!      pairs, and probed-shard timestamp guards;
//!    * present → absent: the remove shift chain, terminator guard,
//!      and shard timestamp bumps;
//!    * absent → absent: timestamp guards along the probe path plus a
//!      terminator key-word guard.
//!
//!    Timestamp words are tracked in a ledger keyed by **word address**
//!    (valid across shards and generations); each word contributes a
//!    single `first_read -> first_read + bumps` entry. The merged
//!    entry set executes as **one** K-CAS — `OpBuilder` sorts it by
//!    address, so concurrent transactions acquire words in a global
//!    order and cannot livelock-cycle.
//!
//! Lost races (a guard moved between phases) retry indefinitely — the
//! commit itself is lock-free, exactly like the single-key ops.
//! *Structural* conflicts — two per-key plans claiming the same word
//! with different contents (two inserts racing for one Nil, a shift
//! chain crossing a pinned bucket) — are deterministic under quiescence,
//! so they retry a bounded number of times and then surface as
//! [`MapError::TxnConflict`].
//!
//! ## Baselines
//!
//! [`apply_txn_occ`] is the comparison point from the lock-free
//! open-addressing literature (see PAPERS.md): optimistic read →
//! validate → per-key CAS commit with best-effort rollback. Its commit
//! is **not** atomic — concurrent readers can observe a half-applied
//! transaction, which is precisely the gap `fig18_txn` measures against
//! the native descriptor commit. `LockedLpMap` contributes the 2PL
//! reference implementation (see `locked_lp.rs`).

use std::cell::RefCell;

use super::kcas_rh_map::KCasRobinHoodMap;
use super::{check_key, ConcurrentMap, MapError, MapOp, MapReply, TxnError};
use crate::kcas::{OpBuilder, Word};
use crate::util::hash::splitmix64;
use crate::util::metrics::metrics;

/// Structural conflicts are deterministic when nothing else is running,
/// so a handful of retries distinguishes "transient overlap while the
/// table churned" from "this op set intrinsically collides".
const MAX_CONFLICT_RETRIES: u32 = 8;

/// Cross-table commit accumulator: physical word entries plus the
/// timestamp ledger, merged into one descriptor at commit time.
///
/// Unlike `OpBuilder` it tolerates the same word being staged by
/// several per-key plans *if* the entries agree (pure guards); the
/// merge happens before the descriptor's duplicate-address check.
pub(crate) struct TxnScratch {
    op: OpBuilder,
    /// Staged entries `(word address, expected, new)` — unshifted.
    entries: Vec<(usize, u64, u64)>,
    /// Timestamp ledger `(word address, first read, pending bumps)`.
    ts: Vec<(usize, u64, u64)>,
    /// Remove-plan shift chain scratch (`(key, value)` windows).
    pub(crate) chain: Vec<(u64, u64)>,
}

thread_local! {
    static TXN: RefCell<TxnScratch> = RefCell::new(TxnScratch {
        op: OpBuilder::new(),
        entries: Vec::with_capacity(64),
        ts: Vec::with_capacity(16),
        chain: Vec::with_capacity(64),
    });
}

/// Outcome of one commit attempt.
enum Commit {
    /// Descriptor executed; payload = entry count (the txn span).
    Committed(u64),
    /// A guard moved underneath us; replan from fresh reads.
    Raced,
    /// Two per-key plans disagreed about the same word.
    Conflict,
}

impl TxnScratch {
    fn clear(&mut self) {
        self.entries.clear();
        self.ts.clear();
    }

    /// Stage `*word: old -> new` into the commit descriptor.
    #[inline]
    pub(crate) fn stage(&mut self, word: &Word, old: u64, new: u64) {
        self.entries.push((word.addr(), old, new));
    }

    /// Record a read of the shard-timestamp word at `addr` plus `bump`
    /// pending increments. Returns false when the same word was read
    /// twice with different values within this attempt — the attempt
    /// is already stale and must restart.
    pub(crate) fn note_ts(&mut self, addr: usize, val: u64, bump: u64) -> bool {
        for e in self.ts.iter_mut() {
            if e.0 == addr {
                if e.1 != val {
                    return false;
                }
                e.2 += bump;
                return true;
            }
        }
        self.ts.push((addr, val, bump));
        true
    }

    /// Merge the staged entries into one descriptor and execute it.
    fn execute(&mut self) -> Commit {
        let TxnScratch { op, entries, ts, .. } = self;
        for &(addr, first, bumps) in ts.iter() {
            entries.push((addr, first, first + bumps));
        }
        entries.sort_unstable();
        op.clear();
        let mut idx = 0;
        while idx < entries.len() {
            let (addr, old, new) = entries[idx];
            let mut end = idx + 1;
            while end < entries.len() && entries[end].0 == addr {
                end += 1;
            }
            // Same word staged by more than one per-key plan: identical
            // pure guards (`old == new`) merge into one entry; anything
            // else — a displacement write under another key's pin, two
            // inserts claiming one Nil — is a structural conflict.
            if end - idx > 1
                && entries[idx..end]
                    .iter()
                    .any(|&(_, o, n)| o != old || n != new || o != n)
            {
                return Commit::Conflict;
            }
            op.push_addr(addr, old, new);
            idx = end;
        }
        // The registry's descriptor slots hold at most MAX_ENTRIES
        // words; an op set whose plans exceed that is deterministically
        // uncommittable, which is what Conflict reports.
        if op.len() > crate::kcas::MAX_ENTRIES {
            return Commit::Conflict;
        }
        let span = op.len() as u64;
        if op.execute() {
            Commit::Committed(span)
        } else {
            Commit::Raced
        }
    }
}

/// Cross-shard transaction dispatch, implemented by every map that can
/// commit (or lock) a multi-key op set spanning several same-typed
/// tables. `Sharded<T>` forwards `apply_txn` here with its router, so
/// a single commit can span multiple shards' bucket arrays.
pub(crate) trait TxnBackend: ConcurrentMap + Sized {
    fn apply_txn_routed(
        shards: &[Self],
        route: &dyn Fn(u64) -> usize,
        ops: &[MapOp],
    ) -> Result<Vec<MapReply>, TxnError>;
}

/// Collect the unique keys of `ops` (first-seen order) and the per-op
/// index into that list. Transactions are small; linear scan beats a
/// hash set.
pub(crate) fn collect_keys(ops: &[MapOp]) -> (Vec<u64>, Vec<usize>) {
    let mut keys: Vec<u64> = Vec::with_capacity(ops.len());
    let mut key_of: Vec<usize> = Vec::with_capacity(ops.len());
    for op in ops {
        let k = op.key();
        check_key(k);
        let idx = keys.iter().position(|&k2| k2 == k).unwrap_or_else(|| {
            keys.push(k);
            keys.len() - 1
        });
        key_of.push(idx);
    }
    (keys, key_of)
}

/// Fold `ops` (in list order) over the per-key `state` overlay,
/// pushing one reply per op. On return `state` holds each key's net
/// transition target. Pure — no table access; replies linearize at
/// whatever point the caller commits the net transitions.
pub(crate) fn eval_ops(
    ops: &[MapOp],
    key_of: &[usize],
    state: &mut [Option<u64>],
    replies: &mut Vec<MapReply>,
) {
    for (op, &idx) in ops.iter().zip(key_of) {
        let cur = state[idx];
        let reply = match *op {
            MapOp::Get(_) => MapReply::Value(cur),
            MapOp::Insert(_, v) => {
                assert!(v <= crate::kcas::MAX_VALUE);
                state[idx] = Some(v);
                MapReply::Prev(cur)
            }
            MapOp::Remove(_) => {
                state[idx] = None;
                MapReply::Removed(cur)
            }
            MapOp::CmpEx(_, e, n) => {
                if cur == e {
                    if let Some(v) = n {
                        assert!(v <= crate::kcas::MAX_VALUE);
                    }
                    state[idx] = n;
                    MapReply::CmpEx(Ok(()))
                } else {
                    MapReply::CmpEx(Err(cur))
                }
            }
            MapOp::GetOrInsert(_, v) => {
                if cur.is_none() {
                    assert!(v <= crate::kcas::MAX_VALUE);
                    state[idx] = Some(v);
                }
                MapReply::Existing(cur)
            }
            MapOp::FetchAdd(_, d) => {
                assert!(d <= crate::kcas::MAX_VALUE);
                let new =
                    cur.unwrap_or(0).wrapping_add(d) & crate::kcas::MAX_VALUE;
                state[idx] = Some(new);
                MapReply::Added(cur)
            }
        };
        replies.push(reply);
    }
}

/// The native K-CAS transaction driver (see the module docs for the
/// protocol). `resolve` maps a key's hash to the table that currently
/// owns it, *re-invoked on every attempt* — the sharded facade routes
/// here, and the resizable wrapper migrates the key's home run and
/// re-targets the live generation, exactly like `cmpex_mig`.
pub(crate) fn commit_kcas<'a>(
    ops: &[MapOp],
    resolve: &mut dyn FnMut(u64) -> &'a KCasRobinHoodMap,
) -> Result<Vec<MapReply>, TxnError> {
    if ops.is_empty() {
        return Ok(Vec::new());
    }
    let m = metrics();
    m.txn_ops.record(ops.len() as u64);
    let (keys, key_of) = collect_keys(ops);
    let hashes: Vec<u64> = keys.iter().map(|&k| splitmix64(k)).collect();
    let mut reads: Vec<Option<(usize, u64)>> = vec![None; keys.len()];
    let mut finals: Vec<Option<u64>> = vec![None; keys.len()];
    let mut replies: Vec<MapReply> = Vec::with_capacity(ops.len());
    let mut conflicts = 0u32;
    loop {
        m.txn_attempts.incr();
        let outcome = TXN.with(|t| -> Result<Commit, MapError> {
            let tx = &mut *t.borrow_mut();
            tx.clear();
            let mut tables: Vec<&KCasRobinHoodMap> =
                Vec::with_capacity(keys.len());
            // Phase 1: validated read of every unique key.
            for (idx, (&key, &h)) in keys.iter().zip(&hashes).enumerate() {
                let table = resolve(h);
                tables.push(table);
                match table.txn_read(h, key) {
                    Ok(r) => reads[idx] = r,
                    Err(MapError::Frozen) => return Ok(Commit::Raced),
                    Err(e) => return Err(e),
                }
            }
            // Phase 2: pure overlay evaluation.
            for (f, r) in finals.iter_mut().zip(&reads) {
                *f = r.map(|(_, v)| v);
            }
            replies.clear();
            eval_ops(ops, &key_of, &mut finals, &mut replies);
            // Phase 3: lower each key's net transition to word entries.
            for (idx, (&key, &h)) in keys.iter().zip(&hashes).enumerate() {
                let table = tables[idx];
                let planned = match (reads[idx], finals[idx]) {
                    (Some((i, v0)), Some(v1)) => {
                        table.txn_plan_pin(tx, i, key, v0, v1);
                        Ok(true)
                    }
                    (Some((_, v0)), None) => {
                        table.txn_plan_remove(tx, h, key, v0)
                    }
                    (None, Some(v1)) => table.txn_plan_insert(tx, h, key, v1),
                    (None, None) => table.txn_plan_absent(tx, h, key),
                };
                match planned {
                    Ok(true) => {}
                    Ok(false) | Err(MapError::Frozen) => {
                        return Ok(Commit::Raced);
                    }
                    Err(e) => return Err(e),
                }
            }
            Ok(tx.execute())
        })?;
        match outcome {
            Commit::Committed(span) => {
                m.txn_commits.incr();
                m.txn_span.record(span);
                return Ok(std::mem::take(&mut replies));
            }
            Commit::Raced => m.txn_retries.incr(),
            Commit::Conflict => {
                conflicts += 1;
                if conflicts >= MAX_CONFLICT_RETRIES {
                    m.txn_conflicts.incr();
                    return Err(MapError::TxnConflict);
                }
                m.txn_retries.incr();
            }
        }
    }
}

/// OCC baseline: read every key, evaluate, then validate-and-commit
/// with one `compare_exchange` per changed key (in sorted key order),
/// rolling back best-effort on a mid-commit failure.
///
/// **Weaker isolation than `apply_txn`**: the per-key commits are not
/// atomic as a group, so concurrent readers can observe a partially
/// applied transaction (and a failed rollback can leave one behind).
/// It exists as the comparison arm for `fig18_txn` — conservation is
/// asserted only for the native K-CAS and 2PL cells.
pub fn apply_txn_occ(
    map: &dyn ConcurrentMap,
    ops: &[MapOp],
) -> Result<Vec<MapReply>, TxnError> {
    if ops.is_empty() {
        return Ok(Vec::new());
    }
    let m = metrics();
    m.txn_ops.record(ops.len() as u64);
    let (keys, key_of) = collect_keys(ops);
    let mut order: Vec<usize> = (0..keys.len()).collect();
    order.sort_unstable_by_key(|&i| keys[i]);
    let mut replies: Vec<MapReply> = Vec::with_capacity(ops.len());
    loop {
        m.txn_attempts.incr();
        let reads: Vec<Option<u64>> =
            keys.iter().map(|&k| map.get(k)).collect();
        let mut finals = reads.clone();
        replies.clear();
        eval_ops(ops, &key_of, &mut finals, &mut replies);
        let mut done: Vec<usize> = Vec::with_capacity(order.len());
        let mut ok = true;
        for &i in &order {
            if reads[i] == finals[i] {
                // Read-only key: revalidate it in place.
                if map.get(keys[i]) != reads[i] {
                    ok = false;
                    break;
                }
                continue;
            }
            if map.compare_exchange(keys[i], reads[i], finals[i]).is_err() {
                ok = false;
                break;
            }
            done.push(i);
        }
        if ok {
            m.txn_commits.incr();
            m.txn_span.record(done.len() as u64);
            return Ok(std::mem::take(&mut replies));
        }
        for &i in done.iter().rev() {
            let _ = map.compare_exchange(keys[i], finals[i], reads[i]);
        }
        m.txn_retries.incr();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_ops_overlay_semantics() {
        // Two ops on the same key see each other; replies match a
        // sequential HashMap run.
        let ops = [
            MapOp::Get(5),
            MapOp::Insert(5, 10),
            MapOp::FetchAdd(5, 3),
            MapOp::CmpEx(5, Some(13), Some(99)),
            MapOp::Remove(5),
            MapOp::CmpEx(5, None, None),
        ];
        let (keys, key_of) = collect_keys(&ops);
        assert_eq!(keys, vec![5]);
        let mut state = vec![None];
        let mut replies = Vec::new();
        eval_ops(&ops, &key_of, &mut state, &mut replies);
        assert_eq!(
            replies,
            vec![
                MapReply::Value(None),
                MapReply::Prev(None),
                MapReply::Added(Some(10)),
                MapReply::CmpEx(Ok(())),
                MapReply::Removed(Some(99)),
                MapReply::CmpEx(Ok(())),
            ]
        );
        assert_eq!(state, vec![None]);
    }

    #[test]
    fn collect_keys_dedups_preserving_first_seen_order() {
        let ops = [
            MapOp::Insert(7, 1),
            MapOp::Insert(3, 1),
            MapOp::Remove(7),
            MapOp::Get(9),
        ];
        let (keys, key_of) = collect_keys(&ops);
        assert_eq!(keys, vec![7, 3, 9]);
        assert_eq!(key_of, vec![0, 1, 0, 2]);
    }

    #[test]
    fn txn_scratch_merges_identical_guards_and_rejects_overlap() {
        let w = Word::new(4);
        let x = Word::new(6);
        TXN.with(|t| {
            let tx = &mut *t.borrow_mut();
            tx.clear();
            tx.stage(&w, 4, 4);
            tx.stage(&w, 4, 4); // identical pure guard: merges
            tx.stage(&x, 6, 7);
            assert!(matches!(tx.execute(), Commit::Committed(2)));
        });
        assert_eq!((w.read(), x.read()), (4, 7));
        TXN.with(|t| {
            let tx = &mut *t.borrow_mut();
            tx.clear();
            tx.stage(&w, 4, 4);
            tx.stage(&w, 4, 5); // guard vs write: structural conflict
            assert!(matches!(tx.execute(), Commit::Conflict));
        });
        assert_eq!(w.read(), 4);
    }

    #[test]
    fn ts_ledger_detects_torn_reads_and_accumulates_bumps() {
        TXN.with(|t| {
            let tx = &mut *t.borrow_mut();
            tx.clear();
            assert!(tx.note_ts(0x1000, 5, 0));
            assert!(tx.note_ts(0x1000, 5, 1));
            assert!(tx.note_ts(0x1000, 5, 1));
            assert!(!tx.note_ts(0x1000, 6, 0)); // same word, drifted
            assert_eq!(tx.ts, vec![(0x1000, 5, 2)]);
        });
    }
}
