//! **Sharded facade** — the first ROADMAP scaling milestone.
//!
//! The paper's K-CAS Robin Hood table wins on probe length and load
//! factor, but a single bucket array is still one contention domain:
//! every displacement chain, timestamp bump, and descriptor install
//! lands in the same memory region. Maier, Sanders & Dementiev
//! ("Concurrent Hash Tables: Fast and General?(!)") show that
//! partitioning work across independent sub-tables is the standard
//! route to multi-socket scaling, and the split-ordered-lists line
//! (Shalev & Shavit) motivates leaving each partition's non-blocking
//! protocol untouched. [`Sharded<T>`] does exactly that: a power-of-two
//! array of inner [`ConcurrentSet`]s, each running the unmodified
//! per-shard protocol, with keys routed by the **high bits** of the
//! same SplitMix64 hash the tables use internally. Home buckets come
//! from the *low* bits (`hash & mask`), so conditioning on the high
//! bits leaves each shard's in-table hash distribution exactly uniform
//! — probe lengths inside a shard are indistinguishable from an
//! unsharded table at the same load factor.
//!
//! Composing with the growable engines layers two granularities of
//! resize isolation: [`super::resizable::QuiescingResize`] shards each
//! carry their own epoch RwLock, so a grow quiesces **one shard** (1/N
//! of the keyspace) while the other N-1 keep serving; and
//! [`super::resizable::IncResizableRobinHood`] shards don't pause even
//! that one — a growing shard keeps serving through its own
//! two-generation migration (`sharded-inc-resize-rh:N`).
//!
//! `dfb_snapshot` concatenates per-shard snapshots in shard order
//! (aggregation preserves each shard's Robin Hood run structure) and
//! `len_quiesced`/`capacity` sum across shards, so all quiesced
//! analytics and invariant checks keep working through the facade.
//!
//! The facade is generic over *both* table interfaces: `Sharded<T>` is
//! a [`ConcurrentSet`] when `T` is one, and a [`ConcurrentMap`] when
//! `T` is one — so `Sharded<KCasRobinHoodMap>` gets the identical
//! high-bit routing as the set compositions. The map side additionally
//! overrides [`ConcurrentMap::apply_batch`]: a batch is grouped by
//! shard (stable within each shard, so same-key order is preserved;
//! ops on different shards touch disjoint keys and commute) and each
//! group is forwarded as one contiguous sub-batch, letting the inner
//! map amortise its per-thread K-CAS scratch across the group.

use std::cell::RefCell;

use super::txn;
use super::{
    ConcurrentMap, ConcurrentSet, HashedMapOp, MapError, MapOp, MapReply,
    TxnError,
};
use crate::util::hash::splitmix64;
use crate::util::metrics::metrics;

/// Per-thread scratch for [`ConcurrentMap::apply_batch`] grouping, so
/// batch routing never allocates on the steady-state hot path. The
/// batch paths *take* it out of the thread-local for the duration of
/// the batch (leaving a fresh empty scratch) rather than holding the
/// `RefCell` borrow across inner-shard calls — a nested `Sharded`
/// facade re-entering this thread-local mid-batch must find it
/// borrowable, not panic.
#[derive(Default)]
struct BatchScratch {
    /// (shard, original index), sorted to form per-shard runs.
    order: Vec<(u32, u32)>,
    /// `(splitmix64(op.key()), op)` pairs — `apply_batch` hashes each
    /// op once into this buffer and delegates to `apply_batch_hashed`.
    hashed_ops: Vec<HashedMapOp>,
    /// Contiguous hash-carrying op buffer handed to one shard.
    run_ops: Vec<HashedMapOp>,
    /// Reply buffer for that shard's sub-batch.
    run_replies: Vec<MapReply>,
}

thread_local! {
    static BATCH_SCRATCH: RefCell<BatchScratch> = RefCell::new(BatchScratch {
        order: Vec::with_capacity(128),
        hashed_ops: Vec::with_capacity(128),
        run_ops: Vec::with_capacity(128),
        run_replies: Vec::with_capacity(128),
    });
}

/// A power-of-two array of independent `T` shards behind one
/// [`ConcurrentSet`] surface.
pub struct Sharded<T> {
    shards: Box<[T]>,
    /// log2(shard count); keys route on this many *top* hash bits.
    shard_bits: u32,
    name: &'static str,
}

impl<T> Sharded<T> {
    /// Build `2^shards_log2` shards with `build(shard_index)`.
    pub fn from_builder(
        shards_log2: u32,
        name: &'static str,
        mut build: impl FnMut(usize) -> T,
    ) -> Self {
        assert!(shards_log2 <= 16, "shard count out of range: 2^{shards_log2}");
        let n = 1usize << shards_log2;
        Sharded {
            shards: (0..n).map(&mut build).collect(),
            shard_bits: shards_log2,
            name,
        }
    }

    /// Shard index for a precomputed hash `h == splitmix64(key)`: the
    /// top `shard_bits`. The inner tables consume the *low* bits
    /// (`h & mask`), so routing and in-shard placement are independent.
    #[inline(always)]
    fn route(&self, h: u64) -> usize {
        if self.shard_bits == 0 {
            0
        } else {
            (h >> (64 - self.shard_bits)) as usize
        }
    }

    /// Which shard owns `key`.
    ///
    /// Every call through the facade hashes each key exactly once: the
    /// hash computed for routing is handed down through the tables'
    /// `*_hashed` entry points (ROADMAP "hashed entry points" item) on
    /// the single-op path, and through
    /// [`ConcurrentMap::apply_batch_hashed`] on the batch path, so the
    /// inner table's home-bucket lookup reuses it instead of
    /// recomputing SplitMix64.
    #[inline(always)]
    pub fn shard_of(&self, key: u64) -> usize {
        self.route(splitmix64(key))
    }

    /// Number of shards (a power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The inner shards, in routing order (read-only; for diagnostics
    /// and tests — all mutation goes through the facade).
    pub fn shards(&self) -> &[T] {
        &self.shards
    }
}

impl Sharded<super::kcas_rh::KCasRobinHood> {
    /// Total capacity `2^size_log2` buckets split evenly across
    /// `2^shards_log2` K-CAS Robin Hood shards (so load-factor
    /// semantics match the unsharded table of the same total size).
    pub fn kcas(size_log2: u32, shards_log2: u32) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-kcas-rh", |_| {
            super::kcas_rh::KCasRobinHood::new(per)
        })
    }
}

impl Sharded<super::resizable::ResizableRobinHood> {
    /// Sharded resizable composition: growth quiesces one shard, not
    /// the whole table.
    pub fn resizable(size_log2: u32, shards_log2: u32) -> Self {
        Self::resizable_with_threshold(size_log2, shards_log2, 0.85)
    }

    /// As [`Sharded::resizable`] with an explicit per-shard grow
    /// threshold (tests use low thresholds to force grow boundaries).
    pub fn resizable_with_threshold(
        size_log2: u32,
        shards_log2: u32,
        grow_at: f64,
    ) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-resizable-rh", |_| {
            super::resizable::ResizableRobinHood::with_threshold(per, grow_at)
        })
    }
}

impl Sharded<super::resizable::IncResizableRobinHood> {
    /// Sharded composition of the non-blocking two-generation engine:
    /// a growing shard keeps serving its slice of the keyspace (no
    /// stop-shard pause at all — ROADMAP "resize under shards" item).
    pub fn inc_resizable(size_log2: u32, shards_log2: u32) -> Self {
        Self::inc_resizable_with_threshold(size_log2, shards_log2, 0.85)
    }

    /// As [`Sharded::inc_resizable`] with an explicit per-shard grow
    /// threshold (tests use low thresholds to force migrations).
    pub fn inc_resizable_with_threshold(
        size_log2: u32,
        shards_log2: u32,
        grow_at: f64,
    ) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-inc-resize-rh", |_| {
            super::resizable::IncResizableRobinHood::with_threshold(
                per, grow_at,
            )
        })
    }
}

impl Sharded<super::resizable::ResizableRobinHoodMap> {
    /// Sharded growable key→value composition (incremental migration
    /// per shard).
    pub fn inc_resizable_map(size_log2: u32, shards_log2: u32) -> Self {
        Self::inc_resizable_map_with_threshold(size_log2, shards_log2, 0.85)
    }

    /// As [`Sharded::inc_resizable_map`] with an explicit per-shard
    /// grow threshold.
    pub fn inc_resizable_map_with_threshold(
        size_log2: u32,
        shards_log2: u32,
        grow_at: f64,
    ) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-inc-resize-rh-map", |_| {
            super::resizable::ResizableRobinHoodMap::with_threshold(
                per, grow_at,
            )
        })
    }
}

impl Sharded<super::kcas_rh_map::KCasRobinHoodMap> {
    /// Sharded key→value composition of the paper's algorithm: total
    /// capacity `2^size_log2` pair-buckets split evenly across
    /// `2^shards_log2` [`super::kcas_rh_map::KCasRobinHoodMap`] shards.
    pub fn kcas_map(size_log2: u32, shards_log2: u32) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-kcas-rh-map", |_| {
            super::kcas_rh_map::KCasRobinHoodMap::new(per)
        })
    }
}

impl Sharded<super::locked_lp::LockedLpMap> {
    /// Sharded blocking baseline map.
    pub fn locked_lp_map(size_log2: u32, shards_log2: u32) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-locked-lp-map", |_| {
            super::locked_lp::LockedLpMap::new(per)
        })
    }
}

impl<T: ConcurrentMap + txn::TxnBackend> ConcurrentMap for Sharded<T> {
    #[inline]
    fn get(&self, key: u64) -> Option<u64> {
        let h = splitmix64(key);
        self.shards[self.route(h)].get_hashed(h, key)
    }

    #[inline]
    fn insert(&self, key: u64, value: u64) -> Option<u64> {
        let h = splitmix64(key);
        self.shards[self.route(h)].insert_hashed(h, key, value)
    }

    #[inline]
    fn remove(&self, key: u64) -> Option<u64> {
        let h = splitmix64(key);
        self.shards[self.route(h)].remove_hashed(h, key)
    }

    #[inline]
    fn compare_exchange(
        &self,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        let h = splitmix64(key);
        self.shards[self.route(h)].compare_exchange_hashed(h, key, expected, new)
    }

    #[inline]
    fn get_or_insert(&self, key: u64, value: u64) -> Option<u64> {
        let h = splitmix64(key);
        self.shards[self.route(h)].get_or_insert_hashed(h, key, value)
    }

    #[inline]
    fn fetch_add(&self, key: u64, delta: u64) -> Option<u64> {
        let h = splitmix64(key);
        self.shards[self.route(h)].fetch_add_hashed(h, key, delta)
    }

    // Pre-hashed entry points (nested facades, and the hashed batch
    // path below): route on the caller's hash, hand the same hash down.

    #[inline]
    fn get_hashed(&self, h: u64, key: u64) -> Option<u64> {
        self.shards[self.route(h)].get_hashed(h, key)
    }

    #[inline]
    fn insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        self.shards[self.route(h)].insert_hashed(h, key, value)
    }

    #[inline]
    fn remove_hashed(&self, h: u64, key: u64) -> Option<u64> {
        self.shards[self.route(h)].remove_hashed(h, key)
    }

    #[inline]
    fn compare_exchange_hashed(
        &self,
        h: u64,
        key: u64,
        expected: Option<u64>,
        new: Option<u64>,
    ) -> Result<(), Option<u64>> {
        self.shards[self.route(h)].compare_exchange_hashed(h, key, expected, new)
    }

    #[inline]
    fn get_or_insert_hashed(&self, h: u64, key: u64, value: u64) -> Option<u64> {
        self.shards[self.route(h)].get_or_insert_hashed(h, key, value)
    }

    #[inline]
    fn fetch_add_hashed(&self, h: u64, key: u64, delta: u64) -> Option<u64> {
        self.shards[self.route(h)].fetch_add_hashed(h, key, delta)
    }

    /// Shard-grouped batching: stable-sort op indices by shard, forward
    /// each shard's ops as one contiguous sub-batch, scatter the replies
    /// back to op order. Equivalent to op-by-op application because the
    /// regrouping only reorders ops on *different* shards (disjoint
    /// keys, which commute) and keeps each shard's ops — in particular
    /// repeated ops on the same key — in their original relative order.
    /// The hash computed here for routing rides along with each sub-op
    /// ([`ConcurrentMap::apply_batch_hashed`]), so batched traffic pays
    /// exactly one SplitMix64 per op, same as the single-op path.
    fn apply_batch(&self, ops: &[MapOp], out: &mut Vec<MapReply>) {
        if self.shard_bits == 0 {
            return self.shards[0].apply_batch(ops, out);
        }
        // Hash each op exactly once, then run the single copy of the
        // group/scatter loop in `apply_batch_hashed`. The pair buffer
        // is taken out of the scratch (not borrowed) so the delegate —
        // which takes the whole scratch — finds the RefCell free.
        let mut hashed = BATCH_SCRATCH
            .with(|s| std::mem::take(&mut s.borrow_mut().hashed_ops));
        hashed.clear();
        hashed.extend(ops.iter().map(|&op| (splitmix64(op.key()), op)));
        self.apply_batch_hashed(&hashed, out);
        BATCH_SCRATCH.with(|s| s.borrow_mut().hashed_ops = hashed);
    }

    /// Hash-carrying batch entry (a nested-facade courtesy): identical
    /// grouping, but routes on the caller's hashes instead of
    /// recomputing them.
    fn apply_batch_hashed(&self, ops: &[HashedMapOp], out: &mut Vec<MapReply>) {
        if self.shard_bits == 0 {
            return self.shards[0].apply_batch_hashed(ops, out);
        }
        // Same take-don't-borrow discipline as `apply_batch`: a nested
        // facade's re-entry must find the thread-local borrowable.
        let mut bs = BATCH_SCRATCH.with(|s| std::mem::take(&mut *s.borrow_mut()));
        {
            let BatchScratch { order, run_ops, run_replies, .. } = &mut bs;
            order.clear();
            for (i, &(h, _)) in ops.iter().enumerate() {
                order.push((self.route(h) as u32, i as u32));
            }
            order.sort_unstable();
            out.clear();
            out.resize(ops.len(), MapReply::Value(None));
            let mut start = 0;
            while start < order.len() {
                let shard = order[start].0;
                let mut end = start;
                while end < order.len() && order[end].0 == shard {
                    end += 1;
                }
                let run = &order[start..end];
                run_ops.clear();
                run_ops.extend(run.iter().map(|&(_, i)| ops[i as usize]));
                self.shards[shard as usize]
                    .apply_batch_hashed(run_ops, run_replies);
                debug_assert_eq!(run_replies.len(), run.len());
                for (&(_, i), &reply) in run.iter().zip(run_replies.iter()) {
                    out[i as usize] = reply;
                }
                start = end;
            }
        }
        BATCH_SCRATCH.with(|s| *s.borrow_mut() = bs);
    }

    /// Cross-shard multi-key transaction: one commit spanning every
    /// shard the op set routes to. The facade contributes only the
    /// routing closure — the inner table family's
    /// [`txn::TxnBackend::apply_txn_routed`] picks the commit protocol
    /// (one K-CAS for the lock-free tables, ordered 2PL for the locked
    /// baseline), so a single shared descriptor (or lock envelope)
    /// spans every touched shard's bucket array.
    fn apply_txn(&self, ops: &[MapOp]) -> Result<Vec<MapReply>, TxnError> {
        let replies =
            T::apply_txn_routed(&self.shards, &|h| self.route(h), ops)?;
        if self.shard_bits > 0 {
            let mut first = None;
            for op in ops {
                let s = self.route(splitmix64(op.key()));
                match first {
                    None => first = Some(s),
                    Some(f) if f != s => {
                        metrics().txn_cross_shard.incr();
                        break;
                    }
                    Some(_) => {}
                }
            }
        }
        Ok(replies)
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.capacity()).sum()
    }

    fn len_quiesced(&self) -> usize {
        self.shards.iter().map(|s| s.len_quiesced()).sum()
    }

    fn check_invariant_quiesced(&self) -> Result<(), String> {
        for (i, s) in self.shards.iter().enumerate() {
            s.check_invariant_quiesced()
                .map_err(|e| format!("shard {i}: {e}"))?;
        }
        Ok(())
    }
}

/// Nested-facade transaction routing (`Sharded<Sharded<T>>` and the
/// facade's own use as a [`txn::TxnBackend`] element). A transaction
/// whose keys all route to one facade in the slice delegates to that
/// facade's inner backend with the composed router; keys spanning
/// *different facades in the slice* have no single inner shard array
/// to span with one descriptor through this trait's shape, so that
/// (test-only nested-of-nested) case reports
/// [`MapError::Unsupported`] rather than silently splitting the
/// commit. The common production shape — one `Sharded<T>` over plain
/// backend shards — never hits that arm: `Sharded::apply_txn` hands
/// the whole shard slice straight to `T::apply_txn_routed`.
impl<T: ConcurrentMap + txn::TxnBackend> txn::TxnBackend for Sharded<T> {
    fn apply_txn_routed(
        shards: &[Self],
        route: &dyn Fn(u64) -> usize,
        ops: &[MapOp],
    ) -> Result<Vec<MapReply>, TxnError> {
        let mut facade = None;
        for op in ops {
            let f = route(splitmix64(op.key()));
            match facade {
                None => facade = Some(f),
                Some(prev) if prev != f => {
                    return Err(MapError::Unsupported);
                }
                Some(_) => {}
            }
        }
        let f = &shards[facade.unwrap_or(0)];
        T::apply_txn_routed(&f.shards, &|h| f.route(h), ops)
    }
}

impl<T: ConcurrentSet> ConcurrentSet for Sharded<T> {
    #[inline]
    fn contains(&self, key: u64) -> bool {
        let h = splitmix64(key);
        self.shards[self.route(h)].contains_hashed(h, key)
    }

    #[inline]
    fn add(&self, key: u64) -> bool {
        let h = splitmix64(key);
        self.shards[self.route(h)].add_hashed(h, key)
    }

    #[inline]
    fn remove(&self, key: u64) -> bool {
        let h = splitmix64(key);
        self.shards[self.route(h)].remove_hashed(h, key)
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.capacity()).sum()
    }

    /// Per-shard snapshots concatenated in shard order: offset `o` of
    /// shard `i`'s segment is the sum of capacities of shards `< i`, and
    /// within a segment the inner table's bucket order (hence its Robin
    /// Hood run structure) is preserved verbatim.
    fn dfb_snapshot(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.capacity());
        for s in self.shards.iter() {
            out.extend(s.dfb_snapshot());
        }
        out
    }

    fn len_quiesced(&self) -> usize {
        self.shards.iter().map(|s| s.len_quiesced()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::kcas_rh::KCasRobinHood;
    use crate::maps::resizable::ResizableRobinHood;

    #[test]
    fn every_key_routes_to_exactly_one_shard() {
        let t = Sharded::<KCasRobinHood>::kcas(10, 2); // 4 shards x 256
        for k in 1..=500u64 {
            assert!(t.add(k));
        }
        for k in 1..=500u64 {
            let holders =
                t.shards().iter().filter(|s| s.contains(k)).count();
            assert_eq!(holders, 1, "key {k} held by {holders} shards");
            assert!(
                t.shards()[t.shard_of(k)].contains(k),
                "key {k} not in its routed shard"
            );
        }
        assert_eq!(t.len_quiesced(), 500);
        assert_eq!(t.capacity(), 1024);
    }

    #[test]
    fn routing_is_stable_and_covers_all_shards() {
        let t = Sharded::<KCasRobinHood>::kcas(12, 4); // 16 shards
        assert_eq!(t.shard_count(), 16);
        let mut counts = vec![0usize; t.shard_count()];
        for k in 1..=8000u64 {
            assert_eq!(t.shard_of(k), t.shard_of(k), "routing not stable");
            counts[t.shard_of(k)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // Uniform expectation 500/shard; any empty shard means the
            // high-bit routing is broken.
            assert!(c > 250, "shard {i} starved: {c} of 8000 keys");
        }
    }

    #[test]
    fn dfb_aggregation_preserves_per_shard_runs() {
        let t = Sharded::<KCasRobinHood>::kcas(10, 2);
        for k in 1..=600u64 {
            t.add(k);
        }
        let agg = t.dfb_snapshot();
        assert_eq!(agg.len(), t.capacity());
        let mut off = 0;
        for s in t.shards() {
            let seg = &agg[off..off + s.capacity()];
            assert_eq!(
                seg,
                &s.dfb_snapshot()[..],
                "aggregation reordered a shard's buckets"
            );
            // Robin Hood ordering within the shard's runs: along
            // consecutive occupied buckets the DFB never jumps by more
            // than +1 (the invariant every inner table maintains).
            for w in seg.windows(2) {
                if w[0] >= 0 && w[1] >= 0 {
                    assert!(
                        w[1] <= w[0] + 1,
                        "DFB ordering broken in shard run: {} -> {}",
                        w[0],
                        w[1]
                    );
                }
            }
            off += s.capacity();
        }
        let occupied = agg.iter().filter(|&&d| d >= 0).count();
        assert_eq!(occupied, t.len_quiesced());
    }

    #[test]
    fn single_shard_degenerates_to_inner_table() {
        let t = Sharded::<KCasRobinHood>::kcas(8, 0);
        assert_eq!(t.shard_count(), 1);
        for k in 1..=100u64 {
            assert!(t.add(k));
        }
        assert_eq!(t.shard_of(12345), 0);
        assert_eq!(t.len_quiesced(), 100);
        assert_eq!(t.capacity(), 256);
    }

    #[test]
    fn resizable_shards_grow_independently() {
        // 4 shards x 64 buckets, grow at 70%: keys routed to shard 0
        // only must grow shard 0 and leave the others untouched.
        let t =
            Sharded::<ResizableRobinHood>::resizable_with_threshold(8, 2, 0.7);
        let before: Vec<usize> =
            t.shards().iter().map(|s| s.capacity()).collect();
        let mut k = 1u64;
        let mut added = 0;
        while added < 60 {
            if t.shard_of(k) == 0 {
                assert!(t.add(k));
                added += 1;
            }
            k += 1;
        }
        let after: Vec<usize> =
            t.shards().iter().map(|s| s.capacity()).collect();
        assert!(
            after[0] > before[0],
            "target shard did not grow: {} -> {}",
            before[0],
            after[0]
        );
        assert_eq!(&after[1..], &before[1..], "untouched shards grew");
        assert_eq!(t.len_quiesced(), 60);
    }

    #[test]
    #[should_panic(expected = "more shards than buckets")]
    fn too_many_shards_panics() {
        let _ = Sharded::<KCasRobinHood>::kcas(2, 3);
    }

    #[test]
    fn map_facade_routes_like_set_facade() {
        use crate::maps::kcas_rh_map::KCasRobinHoodMap;
        let m = Sharded::<KCasRobinHoodMap>::kcas_map(10, 2);
        assert_eq!(m.shard_count(), 4);
        for k in 1..=400u64 {
            assert_eq!(m.insert(k, k + 7), None);
        }
        for k in 1..=400u64 {
            assert_eq!(m.get(k), Some(k + 7));
            // The routed shard holds the pair; the others don't.
            for (i, s) in m.shards().iter().enumerate() {
                let want = if i == m.shard_of(k) { Some(k + 7) } else { None };
                assert_eq!(s.get(k), want, "key {k} shard {i}");
            }
        }
        assert_eq!(m.len_quiesced(), 400);
        assert_eq!(m.capacity(), 1024);
        assert_eq!(ConcurrentMap::name(&m), "sharded-kcas-rh-map");
    }

    #[test]
    fn map_batch_grouping_matches_op_by_op() {
        use crate::maps::kcas_rh_map::KCasRobinHoodMap;
        use crate::util::rng::Rng;
        let batched = Sharded::<KCasRobinHoodMap>::kcas_map(10, 2);
        let serial = Sharded::<KCasRobinHoodMap>::kcas_map(10, 2);
        let mut rng = Rng::new(0xBA7C);
        let mut replies = Vec::new();
        for round in 0..40 {
            let n = 1 + rng.below(64) as usize;
            let ops: Vec<MapOp> = (0..n)
                .map(|_| {
                    let k = 1 + rng.below(200);
                    match rng.below(6) {
                        0 => MapOp::Insert(k, rng.below(1000)),
                        1 => MapOp::Remove(k),
                        2 => MapOp::CmpEx(
                            k,
                            if rng.below(2) == 0 {
                                None
                            } else {
                                Some(rng.below(1000))
                            },
                            if rng.below(2) == 0 {
                                None
                            } else {
                                Some(rng.below(1000))
                            },
                        ),
                        3 => MapOp::GetOrInsert(k, rng.below(1000)),
                        4 => MapOp::FetchAdd(k, rng.below(50)),
                        _ => MapOp::Get(k),
                    }
                })
                .collect();
            batched.apply_batch(&ops, &mut replies);
            let expect: Vec<MapReply> =
                ops.iter().map(|&op| serial.apply_one(op)).collect();
            assert_eq!(replies, expect, "round {round} ops {ops:?}");
        }
        assert_eq!(batched.len_quiesced(), serial.len_quiesced());
    }

    #[test]
    fn nested_facade_batch_does_not_reenter_scratch() {
        use crate::maps::kcas_rh_map::KCasRobinHoodMap;
        // A facade of facades: both levels' batch paths use the same
        // thread-local scratch, so the outer must not hold its borrow
        // across the inner call (regression: BorrowMutError panic).
        let m = Sharded::from_builder(1, "nested-kcas-rh-map", |_| {
            Sharded::<KCasRobinHoodMap>::kcas_map(8, 1)
        });
        let ops: Vec<MapOp> = (1..=40u64)
            .flat_map(|k| [MapOp::Insert(k, k * 3), MapOp::Get(k)])
            .collect();
        let mut replies = Vec::new();
        ConcurrentMap::apply_batch(&m, &ops, &mut replies);
        for (i, k) in (1..=40u64).enumerate() {
            assert_eq!(replies[2 * i], MapReply::Prev(None), "key {k}");
            assert_eq!(replies[2 * i + 1], MapReply::Value(Some(k * 3)));
        }
        assert_eq!(ConcurrentMap::len_quiesced(&m), 40);
    }

    #[test]
    fn map_conditional_ops_route_and_agree() {
        use crate::maps::kcas_rh_map::KCasRobinHoodMap;
        let m = Sharded::<KCasRobinHoodMap>::kcas_map(10, 2);
        for k in 1..=200u64 {
            assert_eq!(m.compare_exchange(k, None, Some(k)), Ok(()));
            assert_eq!(m.compare_exchange(k, None, Some(0)), Err(Some(k)));
            assert_eq!(m.get_or_insert(k, 0), Some(k));
            assert_eq!(m.fetch_add(k, 5), Some(k));
            // The routed shard holds the updated pair.
            assert_eq!(m.shards()[m.shard_of(k)].get(k), Some(k + 5));
        }
        for k in 1..=200u64 {
            assert_eq!(m.compare_exchange(k, Some(k + 5), None), Ok(()));
        }
        assert_eq!(m.len_quiesced(), 0);
        m.check_invariant_quiesced().unwrap();
    }

    #[test]
    fn map_batch_preserves_same_key_order_across_shards() {
        use crate::maps::kcas_rh_map::KCasRobinHoodMap;
        let m = Sharded::<KCasRobinHoodMap>::kcas_map(10, 4);
        // Interleave two keys that live on different shards with
        // same-key dependencies; replies must reflect slice order.
        let (a, b) = (3u64, 4u64);
        let ops = vec![
            MapOp::Insert(a, 1),
            MapOp::Insert(b, 2),
            MapOp::Insert(a, 3),
            MapOp::Get(a),
            MapOp::Remove(b),
            MapOp::Get(b),
            MapOp::Remove(a),
        ];
        let mut replies = Vec::new();
        m.apply_batch(&ops, &mut replies);
        assert_eq!(
            replies,
            vec![
                MapReply::Prev(None),
                MapReply::Prev(None),
                MapReply::Prev(Some(1)),
                MapReply::Value(Some(3)),
                MapReply::Removed(Some(2)),
                MapReply::Value(None),
                MapReply::Removed(Some(3)),
            ]
        );
        assert_eq!(m.len_quiesced(), 0);
    }
}
