//! **Sharded facade** — the first ROADMAP scaling milestone.
//!
//! The paper's K-CAS Robin Hood table wins on probe length and load
//! factor, but a single bucket array is still one contention domain:
//! every displacement chain, timestamp bump, and descriptor install
//! lands in the same memory region. Maier, Sanders & Dementiev
//! ("Concurrent Hash Tables: Fast and General?(!)") show that
//! partitioning work across independent sub-tables is the standard
//! route to multi-socket scaling, and the split-ordered-lists line
//! (Shalev & Shavit) motivates leaving each partition's non-blocking
//! protocol untouched. [`Sharded<T>`] does exactly that: a power-of-two
//! array of inner [`ConcurrentSet`]s, each running the unmodified
//! per-shard protocol, with keys routed by the **high bits** of the
//! same SplitMix64 hash the tables use internally. Home buckets come
//! from the *low* bits (`hash & mask`), so conditioning on the high
//! bits leaves each shard's in-table hash distribution exactly uniform
//! — probe lengths inside a shard are indistinguishable from an
//! unsharded table at the same load factor.
//!
//! Composing with [`super::resizable::ResizableRobinHood`] gives
//! incremental growth for free: each shard carries its own epoch
//! RwLock, so a grow migration quiesces **one shard** (1/N of the
//! keyspace) while the other N-1 shards keep serving at full speed —
//! versus the unsharded resizable table, which stalls the world.
//!
//! `dfb_snapshot` concatenates per-shard snapshots in shard order
//! (aggregation preserves each shard's Robin Hood run structure) and
//! `len_quiesced`/`capacity` sum across shards, so all quiesced
//! analytics and invariant checks keep working through the facade.

use super::ConcurrentSet;
use crate::util::hash::splitmix64;

/// A power-of-two array of independent `T` shards behind one
/// [`ConcurrentSet`] surface.
pub struct Sharded<T> {
    shards: Box<[T]>,
    /// log2(shard count); keys route on this many *top* hash bits.
    shard_bits: u32,
    name: &'static str,
}

impl<T: ConcurrentSet> Sharded<T> {
    /// Build `2^shards_log2` shards with `build(shard_index)`.
    pub fn from_builder(
        shards_log2: u32,
        name: &'static str,
        mut build: impl FnMut(usize) -> T,
    ) -> Self {
        assert!(shards_log2 <= 16, "shard count out of range: 2^{shards_log2}");
        let n = 1usize << shards_log2;
        Sharded {
            shards: (0..n).map(&mut build).collect(),
            shard_bits: shards_log2,
            name,
        }
    }

    /// Which shard owns `key`: the top `shard_bits` of its hash. The
    /// inner tables consume the low bits (`hash & mask`), so routing
    /// and in-shard placement are independent.
    ///
    /// The hash is deliberately recomputed here and again inside the
    /// inner table: SplitMix64 is ~5 ALU ops, noise next to the
    /// cache-missing probe that follows, and threading a precomputed
    /// hash through the inner tables would fork their hot-path APIs.
    /// Revisit if profiling ever shows it (ROADMAP: hashed entry
    /// points).
    #[inline(always)]
    pub fn shard_of(&self, key: u64) -> usize {
        if self.shard_bits == 0 {
            0
        } else {
            (splitmix64(key) >> (64 - self.shard_bits)) as usize
        }
    }

    /// Number of shards (a power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The inner shards, in routing order (read-only; for diagnostics
    /// and tests — all mutation goes through the facade).
    pub fn shards(&self) -> &[T] {
        &self.shards
    }

    #[inline(always)]
    fn shard(&self, key: u64) -> &T {
        &self.shards[self.shard_of(key)]
    }
}

impl Sharded<super::kcas_rh::KCasRobinHood> {
    /// Total capacity `2^size_log2` buckets split evenly across
    /// `2^shards_log2` K-CAS Robin Hood shards (so load-factor
    /// semantics match the unsharded table of the same total size).
    pub fn kcas(size_log2: u32, shards_log2: u32) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-kcas-rh", |_| {
            super::kcas_rh::KCasRobinHood::new(per)
        })
    }
}

impl Sharded<super::resizable::ResizableRobinHood> {
    /// Sharded resizable composition: growth quiesces one shard, not
    /// the whole table.
    pub fn resizable(size_log2: u32, shards_log2: u32) -> Self {
        Self::resizable_with_threshold(size_log2, shards_log2, 0.85)
    }

    /// As [`Sharded::resizable`] with an explicit per-shard grow
    /// threshold (tests use low thresholds to force grow boundaries).
    pub fn resizable_with_threshold(
        size_log2: u32,
        shards_log2: u32,
        grow_at: f64,
    ) -> Self {
        let per = size_log2
            .checked_sub(shards_log2)
            .expect("more shards than buckets");
        Sharded::from_builder(shards_log2, "sharded-resizable-rh", |_| {
            super::resizable::ResizableRobinHood::with_threshold(per, grow_at)
        })
    }
}

impl<T: ConcurrentSet> ConcurrentSet for Sharded<T> {
    #[inline]
    fn contains(&self, key: u64) -> bool {
        self.shard(key).contains(key)
    }

    #[inline]
    fn add(&self, key: u64) -> bool {
        self.shard(key).add(key)
    }

    #[inline]
    fn remove(&self, key: u64) -> bool {
        self.shard(key).remove(key)
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.capacity()).sum()
    }

    /// Per-shard snapshots concatenated in shard order: offset `o` of
    /// shard `i`'s segment is the sum of capacities of shards `< i`, and
    /// within a segment the inner table's bucket order (hence its Robin
    /// Hood run structure) is preserved verbatim.
    fn dfb_snapshot(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.capacity());
        for s in self.shards.iter() {
            out.extend(s.dfb_snapshot());
        }
        out
    }

    fn len_quiesced(&self) -> usize {
        self.shards.iter().map(|s| s.len_quiesced()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::kcas_rh::KCasRobinHood;
    use crate::maps::resizable::ResizableRobinHood;

    #[test]
    fn every_key_routes_to_exactly_one_shard() {
        let t = Sharded::<KCasRobinHood>::kcas(10, 2); // 4 shards x 256
        for k in 1..=500u64 {
            assert!(t.add(k));
        }
        for k in 1..=500u64 {
            let holders =
                t.shards().iter().filter(|s| s.contains(k)).count();
            assert_eq!(holders, 1, "key {k} held by {holders} shards");
            assert!(
                t.shards()[t.shard_of(k)].contains(k),
                "key {k} not in its routed shard"
            );
        }
        assert_eq!(t.len_quiesced(), 500);
        assert_eq!(t.capacity(), 1024);
    }

    #[test]
    fn routing_is_stable_and_covers_all_shards() {
        let t = Sharded::<KCasRobinHood>::kcas(12, 4); // 16 shards
        assert_eq!(t.shard_count(), 16);
        let mut counts = vec![0usize; t.shard_count()];
        for k in 1..=8000u64 {
            assert_eq!(t.shard_of(k), t.shard_of(k), "routing not stable");
            counts[t.shard_of(k)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // Uniform expectation 500/shard; any empty shard means the
            // high-bit routing is broken.
            assert!(c > 250, "shard {i} starved: {c} of 8000 keys");
        }
    }

    #[test]
    fn dfb_aggregation_preserves_per_shard_runs() {
        let t = Sharded::<KCasRobinHood>::kcas(10, 2);
        for k in 1..=600u64 {
            t.add(k);
        }
        let agg = t.dfb_snapshot();
        assert_eq!(agg.len(), t.capacity());
        let mut off = 0;
        for s in t.shards() {
            let seg = &agg[off..off + s.capacity()];
            assert_eq!(
                seg,
                &s.dfb_snapshot()[..],
                "aggregation reordered a shard's buckets"
            );
            // Robin Hood ordering within the shard's runs: along
            // consecutive occupied buckets the DFB never jumps by more
            // than +1 (the invariant every inner table maintains).
            for w in seg.windows(2) {
                if w[0] >= 0 && w[1] >= 0 {
                    assert!(
                        w[1] <= w[0] + 1,
                        "DFB ordering broken in shard run: {} -> {}",
                        w[0],
                        w[1]
                    );
                }
            }
            off += s.capacity();
        }
        let occupied = agg.iter().filter(|&&d| d >= 0).count();
        assert_eq!(occupied, t.len_quiesced());
    }

    #[test]
    fn single_shard_degenerates_to_inner_table() {
        let t = Sharded::<KCasRobinHood>::kcas(8, 0);
        assert_eq!(t.shard_count(), 1);
        for k in 1..=100u64 {
            assert!(t.add(k));
        }
        assert_eq!(t.shard_of(12345), 0);
        assert_eq!(t.len_quiesced(), 100);
        assert_eq!(t.capacity(), 256);
    }

    #[test]
    fn resizable_shards_grow_independently() {
        // 4 shards x 64 buckets, grow at 70%: keys routed to shard 0
        // only must grow shard 0 and leave the others untouched.
        let t =
            Sharded::<ResizableRobinHood>::resizable_with_threshold(8, 2, 0.7);
        let before: Vec<usize> =
            t.shards().iter().map(|s| s.capacity()).collect();
        let mut k = 1u64;
        let mut added = 0;
        while added < 60 {
            if t.shard_of(k) == 0 {
                assert!(t.add(k));
                added += 1;
            }
            k += 1;
        }
        let after: Vec<usize> =
            t.shards().iter().map(|s| s.capacity()).collect();
        assert!(
            after[0] > before[0],
            "target shard did not grow: {} -> {}",
            before[0],
            after[0]
        );
        assert_eq!(&after[1..], &before[1..], "untouched shards grew");
        assert_eq!(t.len_quiesced(), 60);
    }

    #[test]
    #[should_panic(expected = "more shards than buckets")]
    fn too_many_shards_panics() {
        let _ = Sharded::<KCasRobinHood>::kcas(2, 3);
    }
}
