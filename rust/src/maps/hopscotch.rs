//! Hopscotch Hashing (Herlihy, Shavit & Tzafrir [24]) — the paper's
//! strongest blocking competitor.
//!
//! Each bucket `b` owns a *neighborhood* of `H = 64` consecutive buckets
//! described by a hop-info bitmap: bit `j` set means the entry stored at
//! `b + j` hashes home to `b`. Insertions linear-probe for an empty
//! bucket and then *hop* it backwards (displacing entries within their
//! own neighborhoods) until it lies within `H` of home.
//!
//! * `contains` is lock-free: read the home bitmap, probe only the set
//!   bits, and validate a per-segment timestamp on a miss (displacements
//!   bump it) — the same reader/relocation protocol the paper's Robin
//!   Hood adopts (§3.2 credits Hopscotch for the sharding scheme).
//! * `add`/`remove` are blocking, sharded over segment locks (64
//!   buckets/segment). Multi-segment operations acquire the covering
//!   locks in sorted order (deadlock-free two-phase locking over the
//!   probe span).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::util::pad::CachePadded;

use super::{check_key, ConcurrentSet};
use crate::util::hash::{home_bucket, splitmix64};

const EMPTY: u64 = 0;
/// Virtual hop-range (bits in the hop-info word).
pub const H: usize = 64;
/// Buckets per lock segment / timestamp shard.
pub const MIN_SEG_LOG2: u32 = 6;

pub struct Hopscotch {
    keys: Box<[AtomicU64]>,
    hop: Box<[AtomicU64]>,
    locks: Box<[CachePadded<Mutex<()>>]>,
    ts: Box<[CachePadded<AtomicU64>]>,
    mask: u64,
    seg_log2: u32,
}

impl Hopscotch {
    pub fn new(size_log2: u32) -> Self {
        let size = 1usize << size_log2;
        assert!(size >= H, "hopscotch table must have at least H buckets");
        // Bounded, cache-resident lock/timestamp table (the original
        // implementation sizes its lock table by concurrency level, not
        // table size) — see kcas_rh::default_shard_log2.
        let seg_log2 = super::kcas_rh::default_shard_log2(size_log2)
            .max(MIN_SEG_LOG2);
        let nseg = (size >> seg_log2).max(1);
        Self {
            keys: (0..size).map(|_| AtomicU64::new(EMPTY)).collect(),
            hop: (0..size).map(|_| AtomicU64::new(0)).collect(),
            locks: (0..nseg).map(|_| CachePadded::new(Mutex::new(()))).collect(),
            ts: (0..nseg).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            mask: (size - 1) as u64,
            seg_log2,
        }
    }

    #[inline]
    fn size(&self) -> usize {
        self.keys.len()
    }

    #[inline]
    fn seg(&self, i: usize) -> usize {
        (i >> self.seg_log2) & (self.locks.len() - 1)
    }

    #[inline]
    fn wrap(&self, i: usize) -> usize {
        i & self.mask as usize
    }

    /// Lock every segment covering buckets `[start, start+len)`
    /// (wrapped), in sorted order.
    fn lock_span(&self, start: usize, len: usize) -> Vec<MutexGuard<'_, ()>> {
        let mut segs: Vec<usize> = (0..len.div_ceil(1 << self.seg_log2) + 1)
            .map(|s| self.seg(self.wrap(start + (s << self.seg_log2))))
            .collect();
        segs.sort_unstable();
        segs.dedup();
        segs.iter().map(|&s| self.locks[s].lock().unwrap()).collect()
    }

    /// Is `key` in `home`'s neighborhood? (Caller may or may not hold
    /// locks; used locked during add, unlocked+validated in contains.)
    fn present(&self, home: usize, key: u64) -> Option<usize> {
        let mut bits = self.hop[home].load(Ordering::Acquire);
        while bits != 0 {
            let j = bits.trailing_zeros() as usize;
            let slot = self.wrap(home + j);
            if self.keys[slot].load(Ordering::Acquire) == key {
                return Some(slot);
            }
            bits &= bits - 1;
        }
        None
    }
}

impl ConcurrentSet for Hopscotch {
    // The plain trio routes through the hashed twins (Hopscotch derives
    // only the home bucket from the hash, so the sharded facade's
    // routing SplitMix64 is reused as-is).

    fn contains(&self, key: u64) -> bool {
        self.contains_hashed(splitmix64(key), key)
    }

    fn add(&self, key: u64) -> bool {
        self.add_hashed(splitmix64(key), key)
    }

    fn remove(&self, key: u64) -> bool {
        self.remove_hashed(splitmix64(key), key)
    }

    fn contains_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        loop {
            let t0 = self.ts[self.seg(home)].load(Ordering::Acquire);
            if self.present(home, key).is_some() {
                return true;
            }
            // Miss: valid only if no displacement moved entries of this
            // segment's neighborhoods during the scan.
            if self.ts[self.seg(home)].load(Ordering::Acquire) == t0 {
                return false;
            }
        }
    }

    fn add_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        // Estimated span: probe distance to the first empty bucket plus
        // hop room; grown on retry.
        let mut span = 4 * H;
        'attempt: loop {
            assert!(span <= self.size() * 2, "hopscotch: table too full");
            // Cover [home - H, home + span): displacement bases can sit
            // up to H-1 before the free slot (which itself can be before
            // home + span).
            let lock_start = self.wrap(home.wrapping_sub(H - 1)
                & self.mask as usize);
            let guards = self.lock_span(lock_start, span + H);
            if self.present(home, key).is_some() {
                return false;
            }
            // Find the first empty bucket within the locked span.
            let mut free = None;
            for d in 0..span {
                let i = self.wrap(home + d);
                if self.keys[i].load(Ordering::Acquire) == EMPTY {
                    free = Some((i, d));
                    break;
                }
            }
            let (mut free, mut dist) = match free {
                Some(f) => f,
                None => {
                    drop(guards);
                    span *= 2;
                    continue; // no empty bucket in span: widen
                }
            };
            // Hop the free bucket back until it's within H of home.
            'hopping: while dist >= H {
                // Try bases from the farthest candidate (free-H+1) in.
                for back in (1..H).rev() {
                    let b = self.wrap(free.wrapping_sub(back));
                    let bits = self.hop[b].load(Ordering::Acquire)
                        & ((1u64 << back) - 1);
                    if bits == 0 {
                        continue;
                    }
                    let j = bits.trailing_zeros() as usize;
                    let s = self.wrap(b + j);
                    // Move s -> free (both in locked span):
                    // 1. copy key into the free bucket,
                    // 2. flip the bitmap atomically (single store is
                    //    fine: b's segment lock is held),
                    // 3. empty the old bucket,
                    // 4. bump b's segment timestamp so lock-free readers
                    //    that scanned the old layout revalidate.
                    let moved = self.keys[s].load(Ordering::Acquire);
                    debug_assert_ne!(moved, EMPTY);
                    self.keys[free].store(moved, Ordering::Release);
                    let hb = self.hop[b].load(Ordering::Acquire);
                    self.hop[b].store(
                        (hb & !(1u64 << j)) | (1u64 << back),
                        Ordering::Release,
                    );
                    self.keys[s].store(EMPTY, Ordering::Release);
                    self.ts[self.seg(b)].fetch_add(1, Ordering::AcqRel);
                    dist -= free.wrapping_sub(s) & self.mask as usize;
                    free = s;
                    continue 'hopping;
                }
                // No movable entry: extremely rare below ~90% LF with
                // H=64; widen the span and retry from scratch.
                drop(guards);
                span *= 2;
                continue 'attempt;
            }
            // Place the key.
            self.keys[free].store(key, Ordering::Release);
            let hb = self.hop[home].load(Ordering::Acquire);
            self.hop[home].store(hb | (1u64 << dist), Ordering::Release);
            return true;
        }
    }

    fn remove_hashed(&self, h: u64, key: u64) -> bool {
        check_key(key);
        let home = (h & self.mask) as usize;
        let _guard = self.lock_span(home, H);
        match self.present(home, key) {
            None => false,
            Some(slot) => {
                let j = slot.wrapping_sub(home) & self.mask as usize;
                let hb = self.hop[home].load(Ordering::Acquire);
                // Clear the bitmap bit first, then the bucket: a reader
                // with the old bitmap either still sees the key (hit
                // linearizes before us) or sees EMPTY (no match).
                self.hop[home].store(hb & !(1u64 << j), Ordering::Release);
                self.keys[slot].store(EMPTY, Ordering::Release);
                true
            }
        }
    }

    fn name(&self) -> &'static str {
        "hopscotch"
    }

    fn capacity(&self) -> usize {
        self.size()
    }

    fn dfb_snapshot(&self) -> Vec<i32> {
        (0..self.size())
            .map(|i| {
                let k = self.keys[i].load(Ordering::Acquire);
                if k == EMPTY {
                    -1
                } else {
                    crate::util::hash::dfb(home_bucket(k, self.mask), i, self.mask)
                        as i32
                }
            })
            .collect()
    }

    fn len_quiesced(&self) -> usize {
        self.keys
            .iter()
            .filter(|k| k.load(Ordering::Acquire) != EMPTY)
            .count()
    }
}

impl Hopscotch {
    /// Consistency check (quiesced): every key reachable via its home
    /// bitmap, every set bit backed by a key with that home, within H.
    pub fn check_invariant(&self) -> Result<(), String> {
        for b in 0..self.size() {
            let mut bits = self.hop[b].load(Ordering::Acquire);
            while bits != 0 {
                let j = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let slot = self.wrap(b + j);
                let k = self.keys[slot].load(Ordering::Acquire);
                if k == EMPTY {
                    return Err(format!("bit {j} of bucket {b} -> empty slot"));
                }
                if home_bucket(k, self.mask) != b {
                    return Err(format!(
                        "slot {slot}: key {k} in bitmap of {b} but home {}",
                        home_bucket(k, self.mask)
                    ));
                }
            }
        }
        for i in 0..self.size() {
            let k = self.keys[i].load(Ordering::Acquire);
            if k == EMPTY {
                continue;
            }
            let b = home_bucket(k, self.mask);
            let j = i.wrapping_sub(b) & self.mask as usize;
            if j >= H {
                return Err(format!("key {k} at {i} is {j} from home {b}"));
            }
            if self.hop[b].load(Ordering::Acquire) & (1 << j) == 0 {
                return Err(format!("key {k} at {i} not in bitmap of {b}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = Hopscotch::new(8);
        assert!(t.add(5));
        assert!(!t.add(5));
        assert!(t.contains(5));
        assert!(t.remove(5));
        assert!(!t.remove(5));
        assert!(!t.contains(5));
        t.check_invariant().unwrap();
    }

    #[test]
    fn fill_forces_hopping() {
        let t = Hopscotch::new(10);
        let n = (1024.0 * 0.8) as u64;
        for k in 1..=n {
            assert!(t.add(k), "add {k}");
        }
        t.check_invariant().unwrap();
        for k in 1..=n {
            assert!(t.contains(k), "lost {k}");
        }
        assert_eq!(t.len_quiesced(), n as usize);
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "hopscotch matches HashSet",
            25,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = Hopscotch::new(7);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                t.check_invariant()?;
                if t.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn hashed_entry_points_agree_with_plain() {
        let t = Hopscotch::new(8);
        for k in 1..=60u64 {
            let h = splitmix64(k);
            assert!(ConcurrentSet::add_hashed(&t, h, k));
            assert!(!t.add(k));
            assert!(ConcurrentSet::contains_hashed(&t, h, k));
        }
        for k in (1..=60u64).step_by(2) {
            assert!(ConcurrentSet::remove_hashed(&t, splitmix64(k), k));
            assert!(!t.contains(k));
        }
        t.check_invariant().unwrap();
        assert_eq!(t.len_quiesced(), 30);
    }

    #[test]
    fn concurrent_adds_exactly_once() {
        let t = Arc::new(Hopscotch::new(12));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=400u64).filter(|&k| t.add(k)).count()
            }));
        }
        let total: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 400);
        t.check_invariant().unwrap();
    }

    #[test]
    fn concurrent_churn_keeps_structure_valid() {
        let t = Arc::new(Hopscotch::new(9));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(21, tid);
                for _ in 0..3000 {
                    let k = 1 + r.below(300);
                    match r.below(3) {
                        0 => {
                            t.add(k);
                        }
                        1 => {
                            t.remove(k);
                        }
                        _ => {
                            t.contains(k);
                        }
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
    }

    #[test]
    fn readers_never_miss_stable_keys_during_hops() {
        // Stable keys stay put; churn forces displacements around them.
        let t = Arc::new(Hopscotch::new(8));
        for k in 1000..1030u64 {
            t.add(k);
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut hs = Vec::new();
        for tid in 0..2u64 {
            let (t, stop) = (t.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(31, tid);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let k = 1 + r.below(120);
                    t.add(k);
                    t.remove(k);
                }
            }));
        }
        for tid in 0..4u64 {
            let (t, stop) = (t.clone(), stop.clone());
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(33, tid);
                for _ in 0..20_000 {
                    let k = 1000 + r.below(30);
                    assert!(t.contains(k), "stable key {k} missed");
                }
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        t.check_invariant().unwrap();
    }
}
