//! Michael's lock-free hash table [27] — separate chaining with one
//! lock-free *ordered* linked list per bucket (Michael's refinement of
//! Harris's list [19], SPAA 2002).
//!
//! Deleted nodes are *leaked*: the paper runs all benchmarks without a
//! memory-reclamation system ("no memory reclamation system was used in
//! algorithms that traditionally require one", §4.1) and we reproduce
//! that setup. Do not use this table in a long-running service without
//! adding hazard pointers / epochs.
//!
//! The mark bit (logical deletion) lives in bit 0 of the `next` pointer;
//! nodes are 16-byte aligned so the bit is always free.

use std::sync::atomic::{AtomicPtr, Ordering};

use super::{check_key, ConcurrentSet};
use crate::util::hash::home_bucket;

#[repr(align(16))]
struct Node {
    key: u64,
    next: AtomicPtr<Node>,
}

const MARK: usize = 1;

#[inline]
fn marked(p: *mut Node) -> bool {
    (p as usize) & MARK != 0
}

#[inline]
fn with_mark(p: *mut Node) -> *mut Node {
    ((p as usize) | MARK) as *mut Node
}

#[inline]
fn unmarked(p: *mut Node) -> *mut Node {
    ((p as usize) & !MARK) as *mut Node
}

pub struct MichaelSet {
    heads: Box<[AtomicPtr<Node>]>,
    mask: u64,
}

// SAFETY: the raw node pointers are confined to the internal lock-free
// protocol — every node is heap-allocated, published by CAS, and never
// freed while the set lives (deliberately leaked, see module docs).
unsafe impl Send for MichaelSet {}
// SAFETY: as for Send — all shared mutation goes through the per-node
// atomics.
unsafe impl Sync for MichaelSet {}

struct FindResult<'a> {
    /// Location holding the (unmarked) pointer to `cur`.
    prev: &'a AtomicPtr<Node>,
    cur: *mut Node,
    found: bool,
}

impl MichaelSet {
    pub fn new(size_log2: u32) -> Self {
        let size = 1usize << size_log2;
        Self {
            heads: (0..size)
                .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                .collect(),
            mask: (size - 1) as u64,
        }
    }

    /// Michael's `find`: position at the first node with `node.key >=
    /// key`, physically unlinking marked nodes along the way. Restarts
    /// from the head when an unlink CAS loses a race.
    fn find<'a>(&'a self, head: &'a AtomicPtr<Node>, key: u64) -> FindResult<'a> {
        'retry: loop {
            let mut prev: &AtomicPtr<Node> = head;
            let mut cur = prev.load(Ordering::Acquire);
            loop {
                let curp = unmarked(cur);
                if curp.is_null() {
                    return FindResult { prev, cur: curp, found: false };
                }
                // SAFETY: a non-null unmarked pointer read from the
                // list targets a published, never-freed node.
                let cur_node = unsafe { &*curp };
                let next = cur_node.next.load(Ordering::Acquire);
                if marked(next) {
                    // Logically deleted: try to physically unlink.
                    if prev
                        .compare_exchange(
                            curp,
                            unmarked(next),
                            Ordering::AcqRel,
                            Ordering::Acquire,
                        )
                        .is_err()
                    {
                        continue 'retry;
                    }
                    // Node leaked deliberately (paper runs reclaimer-free).
                    cur = unmarked(next) as *mut Node;
                    continue;
                }
                if cur_node.key >= key {
                    return FindResult {
                        prev,
                        cur: curp,
                        found: cur_node.key == key,
                    };
                }
                prev = &cur_node.next;
                cur = next;
            }
        }
    }
}

impl ConcurrentSet for MichaelSet {
    fn contains(&self, key: u64) -> bool {
        check_key(key);
        let head = &self.heads[home_bucket(key, self.mask)];
        // Wait-free-ish traversal (no unlinking on the read path).
        let mut cur = unmarked(head.load(Ordering::Acquire));
        while !cur.is_null() {
            // SAFETY: non-null list pointers target published,
            // never-freed nodes (reclaimer-free by design).
            let node = unsafe { &*cur };
            let next = node.next.load(Ordering::Acquire);
            if node.key >= key {
                return node.key == key && !marked(next);
            }
            cur = unmarked(next);
        }
        false
    }

    fn add(&self, key: u64) -> bool {
        check_key(key);
        let head = &self.heads[home_bucket(key, self.mask)];
        let node = Box::into_raw(Box::new(Node {
            key,
            next: AtomicPtr::new(std::ptr::null_mut()),
        }));
        loop {
            let f = self.find(head, key);
            if f.found {
                // Already present; release our unpublished node.
                // SAFETY: `node` came from Box::into_raw above and was
                // never published (the insert CAS did not run).
                unsafe { drop(Box::from_raw(node)) };
                return false;
            }
            // SAFETY: `node` is our own not-yet-published allocation.
            // ORDERING: Relaxed is enough for the next-pointer staging
            // store — the publishing CAS below is AcqRel, which is what
            // makes the node (and this field) visible to other threads.
            unsafe { &*node }.next.store(f.cur, Ordering::Relaxed);
            if f.prev
                .compare_exchange(f.cur, node, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
        }
    }

    fn remove(&self, key: u64) -> bool {
        check_key(key);
        let head = &self.heads[home_bucket(key, self.mask)];
        loop {
            let f = self.find(head, key);
            if !f.found {
                return false;
            }
            // SAFETY: find() returned a non-null match; nodes are
            // never freed while the set lives.
            let cur_node = unsafe { &*f.cur };
            let next = cur_node.next.load(Ordering::Acquire);
            if marked(next) {
                continue; // someone else is deleting it; re-find
            }
            // Logical delete: mark the next pointer.
            if cur_node
                .next
                .compare_exchange(
                    next,
                    with_mark(next),
                    Ordering::AcqRel,
                    Ordering::Acquire,
                )
                .is_err()
            {
                continue;
            }
            // Physical unlink (best effort; find() will finish it).
            let _ = f.prev.compare_exchange(
                f.cur,
                unmarked(next),
                Ordering::AcqRel,
                Ordering::Acquire,
            );
            return true;
        }
    }

    fn name(&self) -> &'static str {
        "michael"
    }

    fn capacity(&self) -> usize {
        self.heads.len()
    }

    fn len_quiesced(&self) -> usize {
        let mut n = 0;
        for head in self.heads.iter() {
            let mut cur = unmarked(head.load(Ordering::Acquire));
            while !cur.is_null() {
                // SAFETY: non-null list pointers target published,
                // never-freed nodes.
                let node = unsafe { &*cur };
                let next = node.next.load(Ordering::Acquire);
                if !marked(next) {
                    n += 1;
                }
                cur = unmarked(next);
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::collections::HashSet;
    use std::sync::Arc;

    #[test]
    fn basic_semantics() {
        let t = MichaelSet::new(4);
        assert!(t.add(10));
        assert!(!t.add(10));
        assert!(t.contains(10));
        assert!(t.remove(10));
        assert!(!t.remove(10));
        assert!(!t.contains(10));
    }

    #[test]
    fn chains_stay_sorted_and_complete() {
        // Tiny bucket array -> long chains exercise list ordering.
        let t = MichaelSet::new(2);
        for k in (1..=200u64).rev() {
            assert!(t.add(k));
        }
        for k in 1..=200u64 {
            assert!(t.contains(k));
        }
        assert_eq!(t.len_quiesced(), 200);
        for head in t.heads.iter() {
            let mut cur = unmarked(head.load(Ordering::Acquire));
            let mut last = 0u64;
            while !cur.is_null() {
                // SAFETY: quiesced test walk over never-freed nodes.
                let node = unsafe { &*cur };
                assert!(node.key > last, "chain out of order");
                last = node.key;
                cur = unmarked(node.next.load(Ordering::Acquire));
            }
        }
    }

    #[test]
    fn oracle_property_random_ops() {
        prop::check(
            "michael matches HashSet",
            30,
            |r: &mut Rng| {
                (0..300)
                    .map(|_| (r.below(3) as u8, 1 + r.below(48)))
                    .collect::<Vec<(u8, u64)>>()
            },
            |ops| {
                let t = MichaelSet::new(4);
                let mut oracle = HashSet::new();
                for &(op, key) in ops {
                    let (got, want) = match op {
                        0 => (t.add(key), oracle.insert(key)),
                        1 => (t.remove(key), oracle.remove(&key)),
                        _ => (t.contains(key), oracle.contains(&key)),
                    };
                    if got != want {
                        return Err(format!(
                            "op {op} key {key}: got {got} want {want}"
                        ));
                    }
                }
                if t.len_quiesced() != oracle.len() {
                    return Err("length mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_adds_exactly_once() {
        let t = Arc::new(MichaelSet::new(6));
        let mut hs = Vec::new();
        for _ in 0..8 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                (1..=400u64).filter(|&k| t.add(k)).count()
            }));
        }
        let total: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 400);
        assert_eq!(t.len_quiesced(), 400);
    }

    #[test]
    fn concurrent_add_remove_churn() {
        let t = Arc::new(MichaelSet::new(4));
        let mut hs = Vec::new();
        for tid in 0..8u64 {
            let t = t.clone();
            hs.push(std::thread::spawn(move || {
                let mut r = Rng::for_thread(13, tid);
                for _ in 0..5000 {
                    let k = 1 + r.below(64);
                    if r.below(2) == 0 {
                        t.add(k);
                    } else {
                        t.remove(k);
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // Consistency: every key the table reports present is found, and
        // chains are still sorted.
        let n = t.len_quiesced();
        assert!(n <= 64);
        let mut found = 0;
        for k in 1..=64u64 {
            if t.contains(k) {
                found += 1;
            }
        }
        assert_eq!(found, n);
    }
}
