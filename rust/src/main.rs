//! `crh` — CLI for the Concurrent Robin Hood Hashing reproduction.
//!
//! ```text
//! crh fig10  [--size-log2 N] [--ms N] [--reps N] [--no-pin]
//! crh fig11  [--size-log2 N] [--ms N] [--threads 1,2,4,...] [--no-pin]
//! crh fig12  (same options)
//! crh fig13_sharding [--shards 1,4,16] (same options)
//! crh fig14_batching [--map sharded-kcas-rh-map:4] [--batches 1,8,64]
//!            (same options; batched KV pipeline vs unbatched baseline)
//! crh fig15_resize [--grow-ats 0.7,0.85] [--size-log2 N] [--ms N]
//!            [--threads 1,2,4] (op latency during an in-flight grow:
//!            incremental two-generation migration vs quiescing rebuild)
//! crh fig16_rmw [--maps sharded-kcas-rh-map:4,sharded-locked-lp-map:4]
//!            [--hot-keys 1,16,256,4096] (conditional RMW counter
//!            workload across contention skew: native K-CAS
//!            compare_exchange/fetch_add vs the locked baseline)
//! crh fig17_frontend [--conns 16,64,256] [--workers 1,2,4]
//!            [--frames N] [--batch N] [--backends threads,reactor,uring]
//!            (KV front-end comparison across the three-backend
//!            matrix — thread-per-connection, epoll event loop,
//!            io_uring completion rings — after asserting all answer
//!            a fixed trace identically; includes a connection-churn
//!            cell and a syscalls-per-op series)
//! crh fig18_txn [--shards 1,4,16] [--txn-sizes 2,4,8]
//!            [--hot-keys 8,64,1024] (SmallBank-style multi-key
//!            transfers committed all-or-nothing: native one-K-CAS
//!            commit vs OCC vs 2PL across transaction size and
//!            contention skew; native cells assert conservation of
//!            the account total)
//! crh serve  [--map sharded-kcas-rh-map:4] [--size-log2 N]
//!            [--addr 127.0.0.1:7878] [--backend threads|reactor|uring]
//!            [--workers N] (run the KV server until killed;
//!            --reactor is kept as an alias for --backend reactor;
//!            uring falls back to the reactor on kernels without
//!            io_uring)
//! crh stats  [--addr 127.0.0.1:7878]
//!            (query a running server's STATS verb and pretty-print
//!            the telemetry snapshot)
//! crh table1 [--size-log2 N] [--ops N]
//! crh bench  --table kcas-rh|inc-resize-rh|sharded-kcas-rh:16|...
//!            [--lf 0.6] [--updates 10] [--threads N] [--ms N] [--zipf]
//! crh bench-compare <old.json> <new.json>
//!            (classify every cell of two BENCH_*.json snapshots as
//!            regressed / improved / ok; exit 1 if any cell regressed
//!            by more than 15%)
//! crh lint [path ...]
//!            (in-tree concurrency lint: rules L001-L005 — SAFETY: and
//!            ORDERING: comment coverage, #[allow] justifications,
//!            metric-name registry hygiene, three-backend wire-verb
//!            dispatch parity. Defaults to src/tests/benches/examples;
//!            exits 1 on any diagnostic. See `crh::analysis`.)
//! crh analyze [--size-log2 N] [--lf 0.8]       (probe statistics)
//! crh validate                                  (artifact golden check)
//! crh smoke
//! ```
//!
//! Every `fig*`/`table1` command measures into a
//! [`crh::bench::report::BenchReport`]; pass `--json` (or set
//! `CRH_BENCH_JSON=1`, optionally `CRH_BENCH_JSON_DIR=<dir>`) to also
//! write the run as a machine-fingerprinted `BENCH_<fig>.json`
//! perf-trajectory snapshot for later `bench-compare` runs.

use crh::bench::report;
use crh::coordinator::{self, ExpOpts};
use crh::maps::{MapKind, TableKind};
use crh::util::error::Result;

/// Figure epilogue: write the `BENCH_<fig>.json` snapshot when
/// `--json` / `CRH_BENCH_JSON=1` asks for one.
fn finish(r: report::BenchReport) {
    let _ = report::write_if_enabled(&r);
}

fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str) -> Option<T> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
}

/// Parse a comma-separated flag value. Any malformed entry rejects the
/// whole list (with a warning) so a typo falls back to the default
/// instead of silently shrinking the sweep.
fn parse_list<T: std::str::FromStr>(args: &[String], name: &str) -> Option<Vec<T>> {
    let s: String = parse_flag(args, name)?;
    match s.split(',').map(|x| x.parse().ok()).collect::<Option<Vec<T>>>() {
        Some(v) if !v.is_empty() => Some(v),
        _ => {
            eprintln!("warning: malformed {name} value {s:?}; using default");
            None
        }
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: crh <fig10|fig11|fig12|fig13_sharding|fig14_batching|\
         fig15_resize|fig16_rmw|fig17_frontend|fig18_txn|serve|stats|\
         table1|bench|\
         bench-compare|lint|ablate-ts|analyze|validate|smoke> [options]\n\
         (figures accept --json / CRH_BENCH_JSON=1 to write a \
         BENCH_<fig>.json snapshot; see `main.rs` docs or README)"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("");
    let mut opts = ExpOpts::default();
    if let Some(s) = parse_flag(&args, "--size-log2") {
        opts.size_log2 = s;
    }
    if let Some(ms) = parse_flag(&args, "--ms") {
        opts.duration_ms = ms;
    }
    if let Some(r) = parse_flag(&args, "--reps") {
        opts.reps = r;
    }
    if let Some(t) = parse_list(&args, "--threads") {
        opts.threads = t;
    }
    if args.iter().any(|a| a == "--no-pin") {
        opts.pin = false;
    }

    match cmd {
        "fig10" => finish(coordinator::fig10(&opts)),
        "fig11" => finish(coordinator::fig11(&opts)),
        "fig12" => finish(coordinator::fig12(&opts)),
        "fig13_sharding" | "fig13" => {
            let shards = parse_list(&args, "--shards")
                .unwrap_or_else(|| TableKind::SHARD_SWEEP.to_vec());
            finish(coordinator::fig13_sharding(&opts, &shards));
        }
        "fig14_batching" | "fig14" => {
            let map: String = parse_flag(&args, "--map")
                .unwrap_or_else(|| "sharded-kcas-rh-map:4".into());
            let kind = MapKind::parse(&map)
                .unwrap_or_else(|| panic!("unknown map {map}"));
            let batches =
                parse_list(&args, "--batches").unwrap_or_else(|| vec![1, 8, 64]);
            finish(coordinator::fig14_batching(&opts, kind, &batches));
        }
        "fig15_resize" | "fig15" => {
            // The latency cells rebuild + prefill per rep, so default to
            // a migration-friendly size instead of the paper's 2^23.
            if parse_flag::<u32>(&args, "--size-log2").is_none() {
                opts.size_log2 = 20;
            }
            let grow_ats = parse_list(&args, "--grow-ats")
                .unwrap_or_else(|| vec![0.7, 0.85]);
            finish(coordinator::fig15_resize(&opts, &grow_ats));
        }
        "fig16_rmw" | "fig16" => {
            let maps: Vec<MapKind> = parse_list::<String>(&args, "--maps")
                .map(|specs| {
                    specs
                        .iter()
                        .map(|s| {
                            MapKind::parse(s)
                                .unwrap_or_else(|| panic!("unknown map {s}"))
                        })
                        .collect()
                })
                .unwrap_or_else(|| {
                    vec![
                        MapKind::ShardedKCasRhMap { shards: 4 },
                        MapKind::ShardedLockedLpMap { shards: 4 },
                    ]
                });
            let hot_keys = parse_list(&args, "--hot-keys")
                .unwrap_or_else(|| vec![1, 16, 256, 4096]);
            finish(coordinator::fig16_rmw(&opts, &maps, &hot_keys));
        }
        "fig17_frontend" | "fig17" => {
            // Network round trips, not table capacity, dominate here;
            // default to a service-sized map instead of the paper's 2^23.
            if parse_flag::<u32>(&args, "--size-log2").is_none() {
                opts.size_log2 = 16;
            }
            let conns = parse_list(&args, "--conns")
                .unwrap_or_else(|| vec![16, 64, 256]);
            let workers = parse_list(&args, "--workers")
                .unwrap_or_else(|| vec![1, 2, 4]);
            let frames = parse_flag(&args, "--frames").unwrap_or(500usize);
            let batch = parse_flag(&args, "--batch")
                .unwrap_or(8usize)
                .clamp(1, crh::service::frame::MAX_BATCH);
            let backends: Vec<crh::service::Backend> =
                parse_list::<String>(&args, "--backends")
                    .map(|specs| {
                        specs
                            .iter()
                            .map(|s| {
                                crh::service::Backend::parse(s).unwrap_or_else(
                                    || panic!("unknown backend {s}"),
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_else(|| crh::service::Backend::ALL.to_vec());
            finish(coordinator::fig17_frontend(
                opts.size_log2,
                &conns,
                &workers,
                frames,
                batch,
                opts.reps,
                &backends,
            ));
        }
        "fig18_txn" | "fig18" => {
            // 1024 hot accounts dominate the workload, not table
            // capacity; default to a service-sized map.
            if parse_flag::<u32>(&args, "--size-log2").is_none() {
                opts.size_log2 = 16;
            }
            let shards = parse_list(&args, "--shards")
                .unwrap_or_else(|| TableKind::SHARD_SWEEP.to_vec());
            let txn_sizes = parse_list(&args, "--txn-sizes")
                .unwrap_or_else(|| vec![2, 4, 8]);
            let hot_keys = parse_list(&args, "--hot-keys")
                .unwrap_or_else(|| vec![8, 64, 1024]);
            finish(coordinator::fig18_txn(&opts, &shards, &txn_sizes, &hot_keys));
        }
        "serve" => {
            let spec: String = parse_flag(&args, "--map")
                .unwrap_or_else(|| "sharded-kcas-rh-map:4".into());
            let kind = MapKind::parse(&spec)
                .unwrap_or_else(|| panic!("unknown map {spec}"));
            let size = parse_flag(&args, "--size-log2").unwrap_or(20u32);
            let bind: String = parse_flag(&args, "--addr")
                .unwrap_or_else(|| "127.0.0.1:7878".into());
            let listener = std::net::TcpListener::bind(&bind)?;
            let map: std::sync::Arc<dyn crh::maps::ConcurrentMap> =
                std::sync::Arc::from(kind.build(size));
            let backend = if args.iter().any(|a| a == "--reactor") {
                // Pre-matrix alias, kept for scripts.
                crh::service::Backend::Reactor
            } else {
                parse_flag::<String>(&args, "--backend")
                    .map(|s| {
                        crh::service::Backend::parse(&s)
                            .unwrap_or_else(|| panic!("unknown backend {s}"))
                    })
                    .unwrap_or(crh::service::Backend::Threads)
            };
            let workers = parse_flag(&args, "--workers").unwrap_or(0);
            let h = backend.serve(listener, map, workers)?;
            let mode = match backend {
                crh::service::Backend::Threads => "thread-per-connection",
                crh::service::Backend::Reactor => "epoll event loop",
                crh::service::Backend::Uring => {
                    if crh::service::uring::uring_frontend_available() {
                        "io_uring completion rings"
                    } else {
                        "io_uring → epoll fallback (kernel lacks io_uring)"
                    }
                }
            };
            println!("serving {} ({mode}) on {}", kind.display(), h.addr());
            loop {
                std::thread::park();
            }
        }
        "stats" => {
            let addr: String = parse_flag(&args, "--addr")
                .unwrap_or_else(|| "127.0.0.1:7878".into());
            let sock: std::net::SocketAddr = addr.parse().map_err(|_| {
                crh::util::error::Error::msg(format!("bad --addr {addr:?}"))
            })?;
            let mut c = crh::service::server::Client::connect(sock)?;
            let line = c.stats()?;
            match crh::util::json::Json::parse(&line) {
                Ok(j) => println!("{}", j.render()),
                // A non-JSON line (old server?) still gets shown.
                Err(_) => println!("{line}"),
            }
        }
        "table1" => {
            let ops = parse_flag(&args, "--ops").unwrap_or(6_000_000u64);
            let size = parse_flag(&args, "--size-log2").unwrap_or(22u32);
            finish(coordinator::table1(size, ops));
        }
        "bench-compare" => {
            let (old_p, new_p) = match (args.get(1), args.get(2)) {
                (Some(o), Some(n)) => (o.as_str(), n.as_str()),
                _ => {
                    eprintln!("usage: crh bench-compare <old.json> <new.json>");
                    std::process::exit(2);
                }
            };
            let load = |p: &str| {
                report::read_snapshot(std::path::Path::new(p))
                    .unwrap_or_else(|e| {
                        eprintln!("bench-compare: {p}: {e}");
                        std::process::exit(2);
                    })
            };
            let cmp = report::compare(&load(old_p), &load(new_p));
            print!("{}", cmp.render());
            if cmp.has_regressions() {
                std::process::exit(1);
            }
        }
        "lint" => {
            let paths: Vec<std::path::PathBuf> = args[1..]
                .iter()
                .filter(|a| !a.starts_with("--"))
                .map(std::path::PathBuf::from)
                .collect();
            let paths = if paths.is_empty() {
                let d = crh::analysis::default_paths();
                if d.is_empty() {
                    eprintln!(
                        "lint: no default paths found (run from rust/ or \
                         pass paths explicitly)"
                    );
                    std::process::exit(2);
                }
                d
            } else {
                paths
            };
            let files = crh::analysis::collect_rs_files(&paths)
                .unwrap_or_else(|e| {
                    eprintln!("lint: {e}");
                    std::process::exit(2);
                });
            let diags = crh::analysis::lint_paths(&paths).unwrap_or_else(|e| {
                eprintln!("lint: {e}");
                std::process::exit(2);
            });
            for d in &diags {
                println!("{d}");
            }
            println!(
                "crh lint: {} file(s), {} diagnostic(s)",
                files.len(),
                diags.len()
            );
            if !diags.is_empty() {
                std::process::exit(1);
            }
        }
        "bench" => {
            let table: String =
                parse_flag(&args, "--table").unwrap_or_else(|| "kcas-rh".into());
            let kind = TableKind::parse(&table)
                .unwrap_or_else(|| panic!("unknown table {table}"));
            let dist = if args.iter().any(|a| a == "--zipf") {
                crh::bench::workload::KeyDist::Zipf
            } else {
                crh::bench::workload::KeyDist::Uniform
            };
            coordinator::bench_cell(
                kind,
                opts.size_log2,
                parse_flag(&args, "--lf").unwrap_or(0.6),
                parse_flag(&args, "--updates").unwrap_or(10),
                parse_flag(&args, "--threads").unwrap_or(1),
                opts.duration_ms,
                opts.pin,
                dist,
            );
        }
        "ablate-ts" => coordinator::ablate_ts(
            parse_flag(&args, "--size-log2").unwrap_or(22),
            parse_flag(&args, "--ms").unwrap_or(1000),
        ),
        "analyze" => coordinator::analyze(
            parse_flag(&args, "--size-log2").unwrap_or(20),
            parse_flag(&args, "--lf").unwrap_or(0.8),
        )?,
        "validate" => coordinator::validate()?,
        "smoke" => coordinator::smoke(),
        _ => usage(),
    }
    Ok(())
}
