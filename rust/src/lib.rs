//! # crh — Concurrent Robin Hood Hashing
//!
//! A reproduction of *"Concurrent Robin Hood Hashing"* (Kelly,
//! Pearlmutter, Maguire — OPODIS/CS.DC 2018): a non-blocking,
//! obstruction-free Robin Hood hash table built on a portable K-CAS
//! (multi-word compare-and-swap) constructed from single-word CAS, plus
//! a transactional (lock-elision) variant, the paper's full set of
//! competitor tables and benchmarks — and the scaling milestones beyond
//! the paper: a generic **sharded facade** that partitions the keyspace
//! across independent sub-tables, and a **key→value service layer**
//! ([`maps::ConcurrentMap`] + [`service`]) with a batched K-CAS request
//! pipeline.
//!
//! ## Layout
//!
//! * [`kcas`] — Harris-style K-CAS with Arbel-Raviv & Brown descriptor
//!   reuse (no allocation, no reclamation) — the paper's §2.3 substrate.
//! * [`maps`] — the hash tables: the paper's K-CAS Robin Hood
//!   ([`maps::kcas_rh`]), transactional Robin Hood ([`maps::tx_rh`]),
//!   baselines (Hopscotch, lock-free/locked linear probing, Michael's
//!   separate chaining, serial Robin Hood), and the scaling
//!   compositions: [`maps::resizable`] (growth two ways: non-blocking
//!   two-generation cooperative migration — `inc-resize-rh[:N]`,
//!   `inc-resize-rh-map[:N]` — plus the quiescing epoch-RwLock
//!   baseline `resizable-rh`) and [`maps::sharded`] (generic
//!   `Sharded<T>` facade routing keys by high hash bits; growable
//!   compositions resize one shard at a time, and the incremental
//!   engine doesn't pause even that one). The key→value
//!   side ([`maps::ConcurrentMap`], spec'd by [`maps::MapKind`] with the
//!   same `:N` shard CLI syntax, e.g. `sharded-kcas-rh-map:16`) lifts
//!   [`maps::kcas_rh_map::KCasRobinHoodMap`] and a locked-LP baseline
//!   through the same facade.
//! * [`service`] — the KV service layer: [`service::batch`] (batched
//!   `apply_batch` API amortising K-CAS descriptor setup, plus the
//!   `fig14_batching` driver), [`service::frame`] (the wire-protocol
//!   codec with an incremental decoder every front-end shares), and
//!   three TCP front-ends serving the identical protocol —
//!   [`service::server`] (thread-per-connection pipeline),
//!   [`service::reactor`] (epoll event loop: ops from every ready
//!   socket applied as one hashed batch per wake-up, EPOLLOUT
//!   backpressure, eventfd shutdown), and [`service::uring`]
//!   (io_uring completion loop, one ring + SO_REUSEPORT listener per
//!   worker, epoll fallback on old kernels) — selectable via
//!   [`service::Backend`].
//! * [`bench`] — §4.1 methodology: workload generation, pinned threads,
//!   barrier-synced timed runs with per-worker measurement windows,
//!   ops/µs reporting, and the perf-trajectory layer
//!   ([`bench::report`]): every figure returns typed per-cell results
//!   that `CRH_BENCH_JSON=1` / `--json` writes as machine-fingerprinted
//!   `BENCH_<fig>.json` snapshots, diffable with `crh bench-compare`.
//! * [`cachesim`] — set-associative cache simulator + per-table memory
//!   trace models (PAPI substitute for Table 1).
//! * [`runtime`] — the AOT artifact runtime behind one `Engine`
//!   surface: a pure-Rust interpreter backend by default (offline
//!   builds, bit-identical hash pipeline), the original PJRT/XLA
//!   loader behind the `xla` cargo feature.
//! * [`coordinator`] — experiment registry and CLI entry points that
//!   regenerate each of the paper's figures and tables, plus the
//!   extension sweeps: `fig13_sharding` (shard count x threads),
//!   `fig14_batching` (batch size x threads), `fig15_resize` (op tail
//!   latency during an in-flight grow migration, incremental vs
//!   quiescing engine), `fig16_rmw` (conditional RMW under contention
//!   skew), and `fig17_frontend` (thread-per-connection vs epoll vs
//!   io_uring front-ends across connection counts, with a
//!   connection-churn cell and syscalls-per-op columns).
//! * [`analysis`] — the in-tree concurrency lint (`crh lint`): a
//!   lightweight Rust lexer plus rules L001–L005 enforcing the
//!   crate's `SAFETY:` / `ORDERING:` comment conventions, `#[allow]`
//!   justifications, metric-name registry hygiene, and three-backend
//!   wire-verb dispatch parity; a blocking CI lane.
//! * [`util`] — hashing (bit-identical to the L1 Pallas kernel), RNG,
//!   thread pinning, a mini property-testing driver, the Linux
//!   readiness + io_uring syscalls behind the event front-ends
//!   (`util::sys`), the
//!   always-on telemetry plane ([`util::metrics`]: sharded relaxed
//!   counters + log-histograms behind a `CRH_METRICS` gate, exported
//!   through the `STATS` wire verb, `crh stats`, and the snapshots'
//!   `metrics` sections), and the offline-build shims ([`util::pad`]
//!   cache padding, [`util::error`] error plumbing) that keep the
//!   crate free of external dependencies.

pub mod analysis;
pub mod bench;
pub mod cachesim;
pub mod coordinator;
pub mod kcas;
pub mod maps;
pub mod runtime;
pub mod service;
pub mod util;

pub use maps::{ConcurrentMap, ConcurrentSet};
