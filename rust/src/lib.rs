//! # crh — Concurrent Robin Hood Hashing
//!
//! A reproduction of *"Concurrent Robin Hood Hashing"* (Kelly,
//! Pearlmutter, Maguire — OPODIS/CS.DC 2018): a non-blocking,
//! obstruction-free Robin Hood hash table built on a portable K-CAS
//! (multi-word compare-and-swap) constructed from single-word CAS, plus
//! a transactional (lock-elision) variant and the paper's full set of
//! competitor tables and benchmarks.
//!
//! ## Layout
//!
//! * [`kcas`] — Harris-style K-CAS with Arbel-Raviv & Brown descriptor
//!   reuse (no allocation, no reclamation) — the paper's §2.3 substrate.
//! * [`maps`] — the hash tables: the paper's K-CAS Robin Hood
//!   ([`maps::kcas_rh`]), transactional Robin Hood ([`maps::tx_rh`]),
//!   and baselines (Hopscotch, lock-free/locked linear probing,
//!   Michael's separate chaining, serial Robin Hood).
//! * [`bench`] — §4.1 methodology: workload generation, pinned threads,
//!   barrier-synced timed runs, ops/µs reporting.
//! * [`cachesim`] — set-associative cache simulator + per-table memory
//!   trace models (PAPI substitute for Table 1).
//! * [`runtime`] — PJRT/XLA runtime loading the AOT-compiled hash
//!   pipeline and probe-statistics artifacts (`artifacts/*.hlo.txt`).
//! * [`coordinator`] — experiment registry and CLI entry points that
//!   regenerate each of the paper's figures and tables.
//! * [`util`] — hashing (bit-identical to the L1 Pallas kernel), RNG,
//!   thread pinning, and a mini property-testing driver.

pub mod bench;
pub mod cachesim;
pub mod coordinator;
pub mod kcas;
pub mod maps;
pub mod runtime;
pub mod util;

pub use maps::ConcurrentSet;
