//! Set-associative LRU cache simulator (PAPI substitute for Table 1).
//!
//! A small two-level hierarchy (L1-D + LLC) driven by byte addresses.
//! We report LLC misses as "cache misses" — at the paper's table sizes
//! (2^23 buckets, deliberately larger than cache) that is what PAPI's
//! total-cache-miss counters are dominated by.

/// One set-associative LRU cache level.
pub struct Cache {
    /// sets[s] = lines (tags), most-recently-used last.
    sets: Vec<Vec<u64>>,
    assoc: usize,
    line_log2: u32,
    set_mask: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    /// `size_bytes` total capacity, `assoc`-way, `line_bytes` lines.
    pub fn new(size_bytes: usize, assoc: usize, line_bytes: usize) -> Self {
        assert!(line_bytes.is_power_of_two());
        let nsets = size_bytes / (assoc * line_bytes);
        assert!(nsets.is_power_of_two() && nsets > 0);
        Self {
            sets: vec![Vec::with_capacity(assoc); nsets],
            assoc,
            line_log2: line_bytes.trailing_zeros(),
            set_mask: (nsets - 1) as u64,
            hits: 0,
            misses: 0,
        }
    }

    /// Standard x86-style L1-D: 32 KiB, 8-way, 64-byte lines.
    pub fn l1d() -> Self {
        Cache::new(32 << 10, 8, 64)
    }

    /// Shared LLC model: 8 MiB, 16-way, 64-byte lines.
    pub fn llc() -> Self {
        Cache::new(8 << 20, 16, 64)
    }

    /// Access a byte address; true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr >> self.line_log2;
        let set = (line & self.set_mask) as usize;
        let ways = &mut self.sets[set];
        if let Some(pos) = ways.iter().position(|&t| t == line) {
            let t = ways.remove(pos);
            ways.push(t); // MRU
            self.hits += 1;
            true
        } else {
            if ways.len() == self.assoc {
                ways.remove(0); // evict LRU
            }
            ways.push(line);
            self.misses += 1;
            false
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// L1-D + LLC hierarchy.
pub struct Hierarchy {
    pub l1: Cache,
    pub llc: Cache,
}

impl Hierarchy {
    pub fn new() -> Self {
        Self { l1: Cache::l1d(), llc: Cache::llc() }
    }

    /// Access an address through the hierarchy.
    #[inline]
    pub fn access(&mut self, addr: u64) {
        if !self.l1.access(addr) {
            self.llc.access(addr);
        }
    }

    pub fn reset_counters(&mut self) {
        self.l1.reset_counters();
        self.llc.reset_counters();
    }

    /// The Table 1 metric: misses that left the cache hierarchy.
    pub fn llc_misses(&self) -> u64 {
        self.llc.misses
    }

    pub fn l1_misses(&self) -> u64 {
        self.l1.misses
    }
}

impl Default for Hierarchy {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 2, 64);
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.misses, 2);
        assert_eq!(c.hits, 2);
    }

    #[test]
    fn lru_eviction_order() {
        // 2-way, line 64, 2 sets => set stride 128.
        let mut c = Cache::new(256, 2, 64);
        c.access(0); // set 0
        c.access(128); // set 0
        c.access(256); // set 0 -> evicts line(0)
        assert!(!c.access(0), "LRU line should have been evicted");
        assert!(c.access(256));
    }

    #[test]
    fn streaming_misses_every_line() {
        let mut c = Cache::l1d();
        for i in 0..10_000u64 {
            c.access(i * 64 + 1 << 20);
        }
        assert!(c.misses >= 10_000 - (32 << 10) / 64);
    }

    #[test]
    fn hierarchy_l1_filters_llc() {
        let mut h = Hierarchy::new();
        for _ in 0..100 {
            h.access(4096);
        }
        assert_eq!(h.llc.misses, 1);
        assert_eq!(h.l1.misses, 1);
        assert_eq!(h.l1.hits, 99);
    }

    #[test]
    fn working_set_larger_than_l1_smaller_than_llc() {
        let mut h = Hierarchy::new();
        // 1 MiB working set, scanned twice.
        for _ in 0..2 {
            for i in 0..(1 << 20) / 64u64 {
                h.access(i * 64);
            }
        }
        // Second scan should hit in LLC (fits) but mostly miss L1.
        assert!(h.llc.misses <= (1 << 20) / 64 + 16);
        assert!(h.l1.misses > (1 << 20) / 64);
    }
}
