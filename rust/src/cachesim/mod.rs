//! Cache-behaviour study (paper Table 1) — PAPI substitute.
//!
//! [`cache`] is a set-associative LRU hierarchy; [`trace`] holds the
//! per-algorithm memory-trace models. [`table1_row`] replays the §4.1
//! workload through a model and reports LLC misses; the coordinator
//! normalises rows against K-CAS Robin Hood exactly as the paper does.

pub mod cache;
pub mod trace;

pub use cache::{Cache, Hierarchy};
pub use trace::TraceTable;

use crate::bench::workload::{KeyDist, Mix, WorkloadCfg};
use crate::maps::TableKind;
use crate::util::rng::Rng;

/// Replay `ops` workload operations for `kind` at the configured load
/// factor and return (LLC misses, L1 misses) — prefill excluded from
/// the counts, like measuring with PAPI around the timed section.
pub fn table1_cell(kind: TableKind, cfg: &WorkloadCfg, ops: u64) -> (u64, u64) {
    let mut t = TraceTable::new(kind, cfg.size_log2);
    let mut h = Hierarchy::new();
    // Prefill with the same deterministic keys the real harness uses.
    let mut rng = Rng::new(cfg.seed ^ 0xDEAD_BEEF);
    let mut added = std::collections::HashSet::new();
    while added.len() < cfg.prefill_count() {
        let key = 1 + rng.below(cfg.key_space());
        if added.insert(key) {
            t.op(crate::bench::workload::Op::Add(key), &mut h);
        }
    }
    h.reset_counters();
    let mut rng = Rng::for_thread(cfg.seed, 0);
    for _ in 0..ops {
        t.op(cfg.draw_op(&mut rng), &mut h);
    }
    (h.llc_misses(), h.l1_misses())
}

/// One Table 1 row: misses for `kind` relative to K-CAS Robin Hood (in
/// percent) for each of the paper's 8 configurations.
pub fn table1_row(
    kind: TableKind,
    size_log2: u32,
    ops: u64,
    baseline: &[u64],
) -> Vec<f64> {
    WorkloadCfg::paper_grid(size_log2, 0)
        .iter()
        .zip(baseline)
        .map(|(cfg, &base)| {
            let (llc, _) = table1_cell(kind, cfg, ops);
            100.0 * llc as f64 / base.max(1) as f64
        })
        .collect()
}

/// Baseline (K-CAS RH) absolute LLC misses for the 8 configurations.
pub fn table1_baseline(size_log2: u32, ops: u64) -> Vec<u64> {
    WorkloadCfg::paper_grid(size_log2, 0)
        .iter()
        .map(|cfg| table1_cell(TableKind::KCasRobinHood, cfg, ops).0)
        .collect()
}

/// Convenience: the paper's workload grid labels.
pub fn grid_labels(size_log2: u32) -> Vec<String> {
    WorkloadCfg::paper_grid(size_log2, 0)
        .iter()
        .map(|c| c.label())
        .collect()
}

/// Default mix used in standalone cells.
pub fn default_mix() -> Mix {
    Mix::LIGHT
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_are_deterministic() {
        let cfg = WorkloadCfg {
            size_log2: 12,
            load_factor: 0.6,
            mix: Mix::LIGHT,
            duration_ms: 0,
            seed: 1,
            dist: KeyDist::Uniform,
        };
        let a = table1_cell(TableKind::KCasRobinHood, &cfg, 20_000);
        let b = table1_cell(TableKind::KCasRobinHood, &cfg, 20_000);
        assert_eq!(a, b);
    }

    #[test]
    fn lockfree_lp_worst_at_high_lf() {
        // The paper's headline Table 1 shape: lock-free LP's misses
        // dwarf everyone's at 80% LF.
        let cfg = WorkloadCfg {
            size_log2: 14,
            load_factor: 0.8,
            mix: Mix::HEAVY,
            duration_ms: 0,
            seed: 1,
            dist: KeyDist::Uniform,
        };
        let (rh, _) = table1_cell(TableKind::KCasRobinHood, &cfg, 50_000);
        let (lp, _) = table1_cell(TableKind::LockFreeLp, &cfg, 50_000);
        assert!(
            lp as f64 > 1.5 * rh as f64,
            "lock-free LP {lp} not >> K-CAS RH {rh}"
        );
    }

    #[test]
    fn hopscotch_beats_kcas_rh_on_misses() {
        // Must use a table much larger than the LLC (as the paper does:
        // 2^23 buckets) or cache-residency effects dominate.
        let cfg = WorkloadCfg {
            size_log2: 22,
            load_factor: 0.6,
            mix: Mix::LIGHT,
            duration_ms: 0,
            seed: 1,
            dist: KeyDist::Uniform,
        };
        let (rh, _) = table1_cell(TableKind::KCasRobinHood, &cfg, 100_000);
        let (hs, _) = table1_cell(TableKind::Hopscotch, &cfg, 100_000);
        assert!(hs < rh, "hopscotch {hs} >= kcas-rh {rh}");
    }
}
