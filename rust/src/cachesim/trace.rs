//! Per-table memory-trace models for the Table 1 cache study.
//!
//! Each model replays the §4.1 workload single-threaded against a
//! faithful *memory layout* of the corresponding algorithm, emitting
//! every bucket/timestamp/lock/node access into the cache hierarchy.
//! Probe lengths, tombstone contamination, displacement chains and
//! pointer chasing all emerge from real algorithm state — only the
//! synchronisation (atomics/locks) is elided, since a single-core trace
//! has no contention (matching the paper's single-core Table 1 setup).
//!
//! Layout assumptions (one address region per array):
//!
//! | table            | per-bucket layout                                |
//! |------------------|--------------------------------------------------|
//! | K-CAS RH         | 8 B key words + 128 B-padded timestamp shards    |
//! | Transactional RH | 8 B key words (HTM: no timestamp reads at all)   |
//! | Hopscotch        | 32 B bucket record (hop-info, key, stored hash)  |
//! | Locked LP        | 8 B key words + 128 B-padded lock shards         |
//! | Lock-free LP     | 8 B bucket *pointer* + 32 B heap node ([29])     |
//! | Michael          | 8 B head pointer + 32 B heap nodes (chained)     |

use super::cache::Hierarchy;
use crate::bench::workload::Op;
use crate::util::hash::{dfb, home_bucket, splitmix64};

const TABLE_BASE: u64 = 1 << 32;
const TS_BASE: u64 = 2 << 32;
const HOP_BASE: u64 = 3 << 32;
const LOCK_BASE: u64 = 4 << 32;
const HEAP_BASE: u64 = 5 << 32;
const PTR_BASE: u64 = 6 << 32;
const DESC_BASE: u64 = 7 << 32;

/// Mirror of `maps::kcas_rh::default_shard_log2`: bounded, cache-
/// resident timestamp/lock shard tables (this crate's optimized
/// default).
fn shard_log2(size_log2: u32) -> u32 {
    6u32.max(size_log2.saturating_sub(13))
}

/// The paper's layout: one timestamp per 64 buckets regardless of table
/// size (16 MiB of timestamps at 2^23 — NOT cache resident). Table 1's
/// relative numbers (Tx-RH < 100%, Hopscotch 66-89%) only arise under
/// this layout; see EXPERIMENTS.md §Table-1 and the ts-sharding
/// ablation.
pub const PAPER_TS_SHARD_LOG2: u32 = 6;
/// Heap span for pseudo-random allocation placement (jemalloc spread).
const HEAP_SPAN: u64 = 1 << 30;

#[inline]
fn heap_addr(alloc_id: u64) -> u64 {
    HEAP_BASE + (splitmix64(alloc_id) & (HEAP_SPAN - 1) & !31)
}

/// Which layout/algorithm a Robin Hood trace models.
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum RhFlavor {
    /// K-CAS: timestamp array on the read path, descriptor on updates.
    KCas,
    /// HTM lock-elision: bare table accesses only.
    Tx,
}

/// Robin Hood trace (serial RH core + flavor-specific extra traffic).
pub struct RhTrace {
    table: Vec<u64>,
    mask: u64,
    flavor: RhFlavor,
    ts_shard_log2: u32,
}

impl RhTrace {
    pub fn new(size_log2: u32, flavor: RhFlavor) -> Self {
        Self::with_ts_sharding(size_log2, flavor, shard_log2(size_log2))
    }

    pub fn with_ts_sharding(
        size_log2: u32,
        flavor: RhFlavor,
        ts_shard_log2: u32,
    ) -> Self {
        Self {
            table: vec![0; 1 << size_log2],
            mask: (1u64 << size_log2) - 1,
            flavor,
            ts_shard_log2,
        }
    }

    #[inline]
    fn bucket(&self, i: usize, h: &mut Hierarchy) {
        h.access(TABLE_BASE + i as u64 * 8);
    }

    #[inline]
    fn ts(&self, i: usize, h: &mut Hierarchy) {
        if self.flavor == RhFlavor::KCas {
            h.access(TS_BASE + ((i >> self.ts_shard_log2) as u64) * 128);
        }
    }

    fn dist(&self, key: u64, i: usize) -> u64 {
        dfb(home_bucket(key, self.mask), i, self.mask)
    }

    pub fn op(&mut self, op: Op, h: &mut Hierarchy) {
        match op {
            Op::Contains(key) => {
                let mut i = home_bucket(key, self.mask);
                let mut d = 0u64;
                loop {
                    self.ts(i, h);
                    self.bucket(i, h);
                    let cur = self.table[i];
                    if cur == 0 || cur == key || self.dist(cur, i) < d {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    d += 1;
                }
            }
            Op::Add(key) => {
                let mut active = key;
                let mut ad = 0u64;
                let mut i = home_bucket(active, self.mask);
                let mut desc_entries = 0u64;
                loop {
                    self.ts(i, h);
                    self.bucket(i, h);
                    let cur = self.table[i];
                    if cur == key {
                        return;
                    }
                    if cur == 0 {
                        self.table[i] = active;
                        self.bucket(i, h); // the committing write
                        if self.flavor == RhFlavor::KCas {
                            // Descriptor writes (thread-local, hot).
                            for e in 0..=desc_entries {
                                h.access(DESC_BASE + e * 24);
                            }
                        }
                        return;
                    }
                    let cd = self.dist(cur, i);
                    if cd < ad {
                        self.table[i] = active;
                        self.bucket(i, h); // swap write
                        self.ts(i, h); // timestamp bump
                        active = cur;
                        ad = cd;
                        desc_entries += 1;
                    }
                    i = (i + 1) & self.mask as usize;
                    ad += 1;
                }
            }
            Op::Remove(key) => {
                let mut i = home_bucket(key, self.mask);
                let mut d = 0u64;
                loop {
                    self.ts(i, h);
                    self.bucket(i, h);
                    let cur = self.table[i];
                    if cur == 0 || self.dist(cur, i) < d {
                        return; // miss
                    }
                    if cur == key {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                    d += 1;
                }
                // Backward shift.
                loop {
                    let next = (i + 1) & self.mask as usize;
                    self.bucket(next, h);
                    let nk = self.table[next];
                    if nk == 0 || self.dist(nk, next) == 0 {
                        self.table[i] = 0;
                        self.bucket(i, h);
                        return;
                    }
                    self.table[i] = nk;
                    self.bucket(i, h);
                    self.ts(i, h);
                    i = next;
                }
            }
        }
    }
}

/// Hopscotch trace: 32-byte bucket records (hop-info + key + stored
/// hash, as in the reference implementation) + segment timestamps.
pub struct HopTrace {
    keys: Vec<u64>,
    hop: Vec<u64>,
    mask: u64,
    seg_log2: u32,
}

const H: usize = 64;

impl HopTrace {
    pub fn new(size_log2: u32) -> Self {
        Self {
            keys: vec![0; 1 << size_log2],
            hop: vec![0; 1 << size_log2],
            mask: (1u64 << size_log2) - 1,
            seg_log2: shard_log2(size_log2),
        }
    }

    #[inline]
    fn bucket(&self, i: usize, h: &mut Hierarchy) {
        h.access(HOP_BASE + i as u64 * 32);
    }

    #[inline]
    fn wrap(&self, i: usize) -> usize {
        i & self.mask as usize
    }

    pub fn op(&mut self, op: Op, h: &mut Hierarchy) {
        let home = home_bucket(
            match op {
                Op::Contains(k) | Op::Add(k) | Op::Remove(k) => k,
            },
            self.mask,
        );
        match op {
            Op::Contains(key) => {
                self.bucket(home, h); // hop-info read
                let mut bits = self.hop[home];
                while bits != 0 {
                    let j = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let s = self.wrap(home + j);
                    self.bucket(s, h);
                    if self.keys[s] == key {
                        return;
                    }
                }
            }
            Op::Add(key) => {
                self.bucket(home, h);
                let mut bits = self.hop[home];
                while bits != 0 {
                    let j = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let s = self.wrap(home + j);
                    self.bucket(s, h);
                    if self.keys[s] == key {
                        return; // already present
                    }
                }
                // Probe for an empty bucket.
                let mut free = None;
                for d in 0..self.keys.len() {
                    let i = self.wrap(home + d);
                    self.bucket(i, h);
                    if self.keys[i] == 0 {
                        free = Some((i, d));
                        break;
                    }
                }
                let (mut free, mut dist) = free.expect("hop trace full");
                'hopping: while dist >= H {
                    for back in (1..H).rev() {
                        let b = self.wrap(free.wrapping_sub(back));
                        self.bucket(b, h);
                        let cand = self.hop[b] & ((1u64 << back) - 1);
                        if cand == 0 {
                            continue;
                        }
                        let j = cand.trailing_zeros() as usize;
                        let s = self.wrap(b + j);
                        self.bucket(s, h);
                        self.bucket(free, h);
                        self.keys[free] = self.keys[s];
                        self.keys[s] = 0;
                        self.hop[b] = (self.hop[b] & !(1u64 << j)) | (1u64 << back);
                        // Segment timestamp bump.
                        h.access(TS_BASE + ((b >> self.seg_log2) as u64) * 128);
                        dist -= (free.wrapping_sub(s)) & self.mask as usize;
                        free = s;
                        continue 'hopping;
                    }
                    return; // displacement failed (full); drop op
                }
                self.keys[free] = key;
                self.hop[home] |= 1u64 << dist;
                self.bucket(free, h);
                self.bucket(home, h);
            }
            Op::Remove(key) => {
                self.bucket(home, h);
                let mut bits = self.hop[home];
                while bits != 0 {
                    let j = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let s = self.wrap(home + j);
                    self.bucket(s, h);
                    if self.keys[s] == key {
                        self.keys[s] = 0;
                        self.hop[home] &= !(1u64 << j);
                        self.bucket(s, h);
                        self.bucket(home, h);
                        return;
                    }
                }
            }
        }
        // Lock-word traffic for mutating ops (sharded; cache-padded).
        if !matches!(op, Op::Contains(_)) {
            h.access(LOCK_BASE + ((home >> self.seg_log2) as u64) * 128);
        }
    }
}

/// Linear-probing trace. `node_based` models [29]'s
/// pointer-per-bucket layout (a heap dereference on every occupied
/// probe); otherwise keys are stored inline (locked LP).
pub struct LpTrace {
    table: Vec<u64>,
    /// Heap allocation id per bucket (node-based flavor).
    node: Vec<u64>,
    mask: u64,
    node_based: bool,
    locked: bool,
    /// Recycle tombstones on insert. The paper's locked LP does NOT
    /// (its Table 1 row is pure contamination: "the table fills up over
    /// time with tombstones"); its lock-free LP (Nielsen & Karlsson)
    /// does.
    reuse_tombstones: bool,
    next_alloc: u64,
    seg_log2: u32,
}

const TOMB: u64 = u64::MAX;

impl LpTrace {
    pub fn new(size_log2: u32, node_based: bool, locked: bool) -> Self {
        Self {
            table: vec![0; 1 << size_log2],
            node: vec![0; 1 << size_log2],
            mask: (1u64 << size_log2) - 1,
            node_based,
            locked,
            reuse_tombstones: node_based, // locked LP: paper never reuses
            next_alloc: 1,
            seg_log2: shard_log2(size_log2),
        }
    }

    #[inline]
    fn bucket(&self, i: usize, h: &mut Hierarchy) {
        if self.node_based {
            h.access(PTR_BASE + i as u64 * 8);
            let id = self.node[i];
            if id != 0 {
                h.access(heap_addr(id));
            }
        } else {
            h.access(TABLE_BASE + i as u64 * 8);
        }
    }

    pub fn op(&mut self, op: Op, h: &mut Hierarchy) {
        if self.locked && !matches!(op, Op::Contains(_)) {
            let home = home_bucket(
                match op {
                    Op::Contains(k) | Op::Add(k) | Op::Remove(k) => k,
                },
                self.mask,
            );
            h.access(LOCK_BASE + ((home >> self.seg_log2) as u64) * 128);
        }
        match op {
            Op::Contains(key) => {
                let mut i = home_bucket(key, self.mask);
                for _ in 0..self.table.len() {
                    self.bucket(i, h);
                    let cur = self.table[i];
                    if cur == 0 || cur == key {
                        return;
                    }
                    i = (i + 1) & self.mask as usize;
                }
            }
            Op::Add(key) => {
                // Scan to EMPTY (checking for the key), then claim the
                // first tombstone if any — the recycling both real LP
                // variants perform.
                let mut i = home_bucket(key, self.mask);
                let mut reusable = None;
                for _ in 0..self.table.len() {
                    self.bucket(i, h);
                    let cur = self.table[i];
                    if cur == key {
                        return;
                    }
                    if cur == TOMB && reusable.is_none() {
                        reusable = Some(i);
                    }
                    if cur == 0 {
                        break;
                    }
                    i = (i + 1) & self.mask as usize;
                }
                let slot = if self.reuse_tombstones {
                    reusable.unwrap_or(i)
                } else {
                    i
                };
                if self.table[slot] != 0 && self.table[slot] != TOMB {
                    return; // table saturated; drop op
                }
                self.table[slot] = key;
                if self.node_based {
                    self.node[slot] = self.next_alloc;
                    self.next_alloc += 1;
                    h.access(heap_addr(self.node[slot]));
                }
                self.bucket(slot, h);
            }
            Op::Remove(key) => {
                let mut i = home_bucket(key, self.mask);
                for _ in 0..self.table.len() {
                    self.bucket(i, h);
                    let cur = self.table[i];
                    if cur == 0 {
                        return;
                    }
                    if cur == key {
                        self.table[i] = TOMB;
                        self.bucket(i, h);
                        return;
                    }
                    i = (i + 1) & self.mask as usize;
                }
            }
        }
    }
}

/// Michael separate-chaining trace: head-pointer array + sorted chains
/// of 32-byte heap nodes.
pub struct MichaelTrace {
    /// Per bucket: sorted vec of (key, alloc_id).
    chains: Vec<Vec<(u64, u64)>>,
    mask: u64,
    next_alloc: u64,
}

impl MichaelTrace {
    pub fn new(size_log2: u32) -> Self {
        Self {
            chains: vec![Vec::new(); 1 << size_log2],
            mask: (1u64 << size_log2) - 1,
            next_alloc: 1,
        }
    }

    pub fn op(&mut self, op: Op, h: &mut Hierarchy) {
        let key = match op {
            Op::Contains(k) | Op::Add(k) | Op::Remove(k) => k,
        };
        let b = home_bucket(key, self.mask);
        h.access(PTR_BASE + b as u64 * 8); // head pointer
        let chain = &mut self.chains[b];
        let mut pos = 0;
        while pos < chain.len() {
            h.access(heap_addr(chain[pos].1)); // node dereference
            if chain[pos].0 >= key {
                break;
            }
            pos += 1;
        }
        let found = pos < chain.len() && chain[pos].0 == key;
        match op {
            Op::Contains(_) => {}
            Op::Add(_) => {
                if !found {
                    let id = self.next_alloc;
                    self.next_alloc += 1;
                    h.access(heap_addr(id)); // initialise the new node
                    chain.insert(pos, (key, id));
                }
            }
            Op::Remove(_) => {
                if found {
                    h.access(heap_addr(chain[pos].1)); // mark
                    chain.remove(pos);
                }
            }
        }
    }
}

/// A boxed trace model for any [`crate::maps::TableKind`].
pub enum TraceTable {
    Rh(RhTrace),
    Hop(HopTrace),
    Lp(LpTrace),
    Michael(MichaelTrace),
}

impl TraceTable {
    /// `paper_ts` selects the paper's fine-grained timestamp layout for
    /// the K-CAS Robin Hood trace (Table 1 reproduction) instead of
    /// this crate's optimized bounded sharding.
    pub fn new_with(
        kind: crate::maps::TableKind,
        size_log2: u32,
        paper_ts: bool,
    ) -> Self {
        use crate::maps::TableKind::*;
        match kind {
            // The resizable wrapper and the sharded facade run the same
            // K-CAS Robin Hood protocol per (sub-)table, so the single-
            // core memory trace is the K-CAS model (sharding only
            // partitions the address space; a serial trace touches one
            // partition per op either way).
            KCasRobinHood
            | ResizableRobinHood
            | IncResizableRh
            | ShardedKCasRh { .. }
            | ShardedResizableRh { .. }
            | ShardedIncResizableRh { .. } => {
                let ts = if paper_ts {
                    PAPER_TS_SHARD_LOG2
                } else {
                    shard_log2(size_log2)
                };
                TraceTable::Rh(RhTrace::with_ts_sharding(
                    size_log2,
                    RhFlavor::KCas,
                    ts,
                ))
            }
            TxRobinHood | SerialRobinHood => {
                TraceTable::Rh(RhTrace::new(size_log2, RhFlavor::Tx))
            }
            Hopscotch => TraceTable::Hop(HopTrace::new(size_log2)),
            LockFreeLp => TraceTable::Lp(LpTrace::new(size_log2, true, false)),
            LockedLp => TraceTable::Lp(LpTrace::new(size_log2, false, true)),
            Michael => TraceTable::Michael(MichaelTrace::new(size_log2)),
        }
    }

    pub fn new(kind: crate::maps::TableKind, size_log2: u32) -> Self {
        Self::new_with(kind, size_log2, true)
    }

    pub fn op(&mut self, op: Op, h: &mut Hierarchy) {
        match self {
            TraceTable::Rh(t) => t.op(op, h),
            TraceTable::Hop(t) => t.op(op, h),
            TraceTable::Lp(t) => t.op(op, h),
            TraceTable::Michael(t) => t.op(op, h),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::TableKind;

    fn run_trace(kind: TableKind, ops: &[Op]) -> (TraceTable, Hierarchy) {
        let mut t = TraceTable::new(kind, 12);
        let mut h = Hierarchy::new();
        for &op in ops {
            t.op(op, &mut h);
        }
        (t, h)
    }

    #[test]
    fn all_kinds_replay_without_panic() {
        let ops: Vec<Op> = (1..=800u64)
            .map(Op::Add)
            .chain((1..=400).map(Op::Remove))
            .chain((1..=800).map(Op::Contains))
            .collect();
        for kind in TableKind::ALL_CONCURRENT {
            let (_, h) = run_trace(kind, &ops);
            assert!(h.l1.hits + h.l1.misses > 0, "{}", kind.name());
        }
    }

    #[test]
    fn node_based_lp_touches_more_memory_than_inline() {
        let ops: Vec<Op> = (1..=2000u64)
            .map(Op::Add)
            .chain((1..=2000).map(Op::Contains))
            .collect();
        let (_, node) = run_trace(TableKind::LockFreeLp, &ops);
        let (_, inline) = run_trace(TableKind::LockedLp, &ops);
        assert!(
            node.llc_misses() > inline.llc_misses(),
            "node {} <= inline {}",
            node.llc_misses(),
            inline.llc_misses()
        );
    }

    #[test]
    fn tx_rh_touches_less_than_kcas_rh() {
        let ops: Vec<Op> = (1..=2000u64)
            .map(Op::Add)
            .chain((1..=2000).map(Op::Contains))
            .collect();
        let (_, tx) = run_trace(TableKind::TxRobinHood, &ops);
        let (_, kcas) = run_trace(TableKind::KCasRobinHood, &ops);
        let (t, k) = (
            tx.l1.hits + tx.l1.misses,
            kcas.l1.hits + kcas.l1.misses,
        );
        assert!(t < k, "tx accesses {t} >= kcas accesses {k}");
    }

    #[test]
    fn rh_trace_semantics_match_serial() {
        // The trace's internal state must be a real Robin Hood table.
        let mut t = RhTrace::new(8, RhFlavor::KCas);
        let mut h = Hierarchy::new();
        for k in 1..=150u64 {
            t.op(Op::Add(k), &mut h);
        }
        for k in (1..=150u64).step_by(2) {
            t.op(Op::Remove(k), &mut h);
        }
        let live = t.table.iter().filter(|&&k| k != 0).count();
        assert_eq!(live, 75);
    }

    #[test]
    fn contamination_grows_probe_traffic() {
        // Churned LP probes should touch more lines than fresh LP.
        let mut fresh = LpTrace::new(10, false, false);
        let mut churned = LpTrace::new(10, false, false);
        let mut hf = Hierarchy::new();
        let mut hc = Hierarchy::new();
        for k in 1..=600u64 {
            fresh.op(Op::Add(k), &mut hf);
            churned.op(Op::Add(k), &mut hc);
        }
        // Contaminate: delete and re-add disjoint keys many times.
        for round in 0..10u64 {
            for k in 1..=300u64 {
                churned.op(Op::Remove(601 + (round * 300 + k) % 300), &mut hc);
            }
            for k in 1..=300u64 {
                churned.op(Op::Add(1000 + round * 1000 + k), &mut hc);
                churned.op(Op::Remove(1000 + round * 1000 + k), &mut hc);
            }
        }
        hf.reset_counters();
        hc.reset_counters();
        // Unsuccessful searches: LP can only cull at EMPTY, so
        // contamination lengthens exactly these probes.
        for k in 1..=600u64 {
            fresh.op(Op::Contains(50_000 + k), &mut hf);
            churned.op(Op::Contains(50_000 + k), &mut hc);
        }
        let (f, c) = (hf.l1.hits + hf.l1.misses, hc.l1.hits + hc.l1.misses);
        assert!(c > f, "contamination had no effect: {c} <= {f}");
    }
}
