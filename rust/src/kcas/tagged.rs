//! Tagged-word and descriptor-reference encodings.
//!
//! Words: `value << 2 | tag`. Descriptor references:
//! `(tid << 48) | (seq << 2) | tag` — 16 bits of thread id, 46 bits of
//! sequence number (wrapping; a helper would need to stall across 2^46
//! operations of one thread to alias, far beyond any run length here).

pub const TAG_MASK: u64 = 0b11;
pub const TAG_VALUE: u64 = 0b00;
pub const TAG_RDCSS: u64 = 0b01;
pub const TAG_KCAS: u64 = 0b10;

/// Largest storable plain value (62 bits).
pub const MAX_VALUE: u64 = (1 << 62) - 1;

const SEQ_BITS: u32 = 46;
pub const SEQ_MASK: u64 = (1 << SEQ_BITS) - 1;
const TID_SHIFT: u32 = 48;

#[inline(always)]
pub fn tag_of(w: u64) -> u64 {
    w & TAG_MASK
}

#[allow(dead_code)] // used by tests and diagnostics
#[inline(always)]
pub fn is_value(w: u64) -> bool {
    tag_of(w) == TAG_VALUE
}

#[inline(always)]
pub fn make_ref(tid: usize, seq: u64, tag: u64) -> u64 {
    debug_assert!(tag == TAG_RDCSS || tag == TAG_KCAS);
    ((tid as u64) << TID_SHIFT) | ((seq & SEQ_MASK) << 2) | tag
}

#[inline(always)]
pub fn ref_tid(w: u64) -> usize {
    (w >> TID_SHIFT) as usize
}

#[inline(always)]
pub fn ref_seq(w: u64) -> u64 {
    (w >> 2) & SEQ_MASK
}

/// K-CAS status packing: `(seq << 2) | state`.
pub const UNDECIDED: u64 = 0;
pub const SUCCEEDED: u64 = 1;
pub const FAILED: u64 = 2;

#[inline(always)]
pub fn pack_status(seq: u64, state: u64) -> u64 {
    ((seq & SEQ_MASK) << 2) | state
}

#[inline(always)]
pub fn status_seq(st: u64) -> u64 {
    (st >> 2) & SEQ_MASK
}

#[inline(always)]
pub fn status_state(st: u64) -> u64 {
    st & TAG_MASK
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ref_roundtrip() {
        for &(tid, seq) in &[(0usize, 0u64), (255, 1), (17, SEQ_MASK), (65535, 12345)] {
            let r = make_ref(tid, seq, TAG_KCAS);
            assert_eq!(ref_tid(r), tid);
            assert_eq!(ref_seq(r), seq & SEQ_MASK);
            assert_eq!(tag_of(r), TAG_KCAS);
            assert!(!is_value(r));
        }
    }

    #[test]
    fn status_roundtrip() {
        let st = pack_status(0xABCDEF, SUCCEEDED);
        assert_eq!(status_seq(st), 0xABCDEF);
        assert_eq!(status_state(st), SUCCEEDED);
    }

    #[test]
    fn values_are_tag_00() {
        assert!(is_value(42 << 2));
        assert!(is_value(0));
        assert!(!is_value(make_ref(1, 1, TAG_RDCSS)));
    }

    #[test]
    fn seq_wraps_harmlessly() {
        let r = make_ref(3, SEQ_MASK + 5, TAG_RDCSS);
        assert_eq!(ref_seq(r), 4);
    }
}
