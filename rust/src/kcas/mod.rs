//! K-CAS: multi-word compare-and-swap from single-word CAS.
//!
//! Implements the paper's §2.3 substrate: Harris, Fraser & Pratt's
//! K-CAS (RDCSS-based) with the Arbel-Raviv & Brown *descriptor reuse*
//! scheme ("Reuse, don't recycle", DISC 2017) — no allocation per
//! operation and no memory reclaimer, which is precisely what made
//! K-CAS fast enough for the paper's Robin Hood table.
//!
//! ## Word encoding
//!
//! Every K-CAS-managed word ([`Word`]) is an `AtomicU64` holding
//! `value << 2 | tag` (the paper's "0-2 reserved bits"):
//!
//! | tag  | meaning                        |
//! |------|--------------------------------|
//! | `00` | plain value (62 usable bits)   |
//! | `01` | RDCSS descriptor reference     |
//! | `10` | K-CAS descriptor reference     |
//!
//! Descriptor *references* carry no pointer: they encode
//! `(thread_id << 48) | (seq << 2) | tag`, resolved through a global
//! per-thread registry. Stale references are rendered harmless by
//! sequence-number validation (see [`registry`]).
//!
//! ## API
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the xla rpath.
//! use crh::kcas::{Word, OpBuilder};
//! let a = Word::new(1);
//! let b = Word::new(2);
//! let mut op = OpBuilder::new();
//! op.push(&a, 1, 10);
//! op.push(&b, 2, 20);
//! assert!(op.execute());
//! assert_eq!((a.read(), b.read()), (10, 20));
//! ```

mod core;
mod registry;
mod tagged;

pub use registry::{thread_id, MAX_ENTRIES, MAX_THREADS};
pub use tagged::MAX_VALUE;

use std::sync::atomic::{AtomicU64, Ordering};

/// A single K-CAS-managed 62-bit word.
///
/// All access must go through [`Word::read`] / [`Word::write`] /
/// [`OpBuilder`]: raw loads can observe descriptor references.
#[repr(transparent)]
pub struct Word(pub(crate) AtomicU64);

impl Word {
    /// Create a word holding `v` (`v < 2^62`).
    pub const fn new(v: u64) -> Self {
        assert!(v <= tagged::MAX_VALUE);
        Word(AtomicU64::new(v << 2))
    }

    /// Linearizable read; helps any in-flight K-CAS/RDCSS it encounters
    /// (the paper's `K_CAS_load`, required by the §3.4 proof).
    #[inline]
    pub fn read(&self) -> u64 {
        core::read(&self.0)
    }

    /// Linearizable unconditional write (the paper's `K_CAS_WRITE`).
    pub fn write(&self, v: u64) {
        debug_assert!(v <= tagged::MAX_VALUE);
        loop {
            let cur = self.read();
            if core::cas_value(&self.0, cur, v) {
                return;
            }
        }
    }

    /// Single-word CAS through the K-CAS protocol (helps descriptors).
    pub fn cas(&self, old: u64, new: u64) -> bool {
        debug_assert!(old <= tagged::MAX_VALUE && new <= tagged::MAX_VALUE);
        loop {
            match core::try_cas_value(&self.0, old, new) {
                Ok(_) => return true,
                Err(cur) if cur != old => return false,
                Err(_) => continue, // descriptor was helped; retry
            }
        }
    }

    pub(crate) fn addr(&self) -> usize {
        &self.0 as *const AtomicU64 as usize
    }

    /// Raw tagged load, for tests and diagnostics only.
    pub fn raw(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

impl std::fmt::Debug for Word {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Word({})", self.read())
    }
}

/// Builds and executes one K-CAS operation.
///
/// Reusable: `clear` + `push`es + `execute`. The entry buffer is a plain
/// `Vec` owned by the caller (keep one per thread to avoid allocation on
/// the hot path — see `maps::kcas_rh`).
#[derive(Default)]
pub struct OpBuilder {
    entries: Vec<(usize, u64, u64)>,
}

impl OpBuilder {
    pub fn new() -> Self {
        Self { entries: Vec::with_capacity(16) }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Add `*word: old -> new` to the operation.
    #[inline]
    pub fn push(&mut self, word: &Word, old: u64, new: u64) {
        debug_assert!(old <= tagged::MAX_VALUE && new <= tagged::MAX_VALUE);
        self.entries.push((word.addr(), old << 2, new << 2));
    }

    /// Add `old -> new` at a raw word address previously captured with
    /// [`Word::addr`]. The transaction planner stages per-key plans in
    /// its own buffer (so same-word entries can be merged before the
    /// duplicate-address check) and replays the merged set through here.
    #[inline]
    pub(crate) fn push_addr(&mut self, addr: usize, old: u64, new: u64) {
        debug_assert!(old <= tagged::MAX_VALUE && new <= tagged::MAX_VALUE);
        self.entries.push((addr, old << 2, new << 2));
    }

    /// Attempt the multi-word CAS; true iff *all* entries were swapped
    /// atomically. The entry list is preserved (so a failed attempt can
    /// be inspected), but callers normally `clear` and rebuild.
    pub fn execute(&mut self) -> bool {
        if self.entries.is_empty() {
            return true;
        }
        if self.entries.len() == 1 {
            // Degenerate K=1: plain CAS through the protocol.
            let (addr, old, new) = self.entries[0];
            // SAFETY: `addr` was captured from a live `&Word` in push;
            // table words outlive the operations that target them.
            let w = unsafe { &*(addr as *const AtomicU64) };
            loop {
                match core::try_cas_value_enc(w, old, new) {
                    Ok(_) => return true,
                    Err(cur) if cur != old => return false,
                    Err(_) => continue,
                }
            }
        }
        // Global address order prevents circular helping livelock.
        self.entries.sort_unstable_by_key(|e| e.0);
        for w in self.entries.windows(2) {
            assert_ne!(w[0].0, w[1].0, "duplicate address in K-CAS op");
        }
        core::kcas(&self.entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as RawA;
    use std::sync::Arc;

    #[test]
    fn single_word_read_write() {
        let w = Word::new(5);
        assert_eq!(w.read(), 5);
        w.write(9);
        assert_eq!(w.read(), 9);
    }

    #[test]
    fn word_cas_semantics() {
        let w = Word::new(1);
        assert!(w.cas(1, 2));
        assert!(!w.cas(1, 3));
        assert_eq!(w.read(), 2);
    }

    #[test]
    fn kcas_success_and_failure() {
        let a = Word::new(1);
        let b = Word::new(2);
        let c = Word::new(3);
        let mut op = OpBuilder::new();
        op.push(&a, 1, 10);
        op.push(&b, 2, 20);
        op.push(&c, 3, 30);
        assert!(op.execute());
        assert_eq!((a.read(), b.read(), c.read()), (10, 20, 30));

        op.clear();
        op.push(&a, 10, 100);
        op.push(&b, 999, 200); // wrong expected -> whole op fails
        assert!(!op.execute());
        assert_eq!((a.read(), b.read()), (10, 20));
    }

    #[test]
    fn empty_and_singleton_ops() {
        let mut op = OpBuilder::new();
        assert!(op.execute());
        let a = Word::new(7);
        op.push(&a, 7, 8);
        assert!(op.execute());
        assert_eq!(a.read(), 8);
        op.clear();
        op.push(&a, 7, 9);
        assert!(!op.execute());
    }

    #[test]
    #[should_panic(expected = "duplicate address")]
    fn duplicate_address_panics() {
        let a = Word::new(1);
        let mut op = OpBuilder::new();
        op.push(&a, 1, 2);
        op.push(&a, 1, 3);
        op.execute();
    }

    #[test]
    fn max_value_roundtrip() {
        let w = Word::new(MAX_VALUE);
        assert_eq!(w.read(), MAX_VALUE);
        assert!(w.cas(MAX_VALUE, 0));
        assert_eq!(w.read(), 0);
    }

    #[test]
    fn descriptor_reuse_many_sequential_ops() {
        // Thousands of ops through the same thread slot: seq numbers
        // advance, nothing corrupts.
        let a = Word::new(0);
        let b = Word::new(0);
        let mut op = OpBuilder::new();
        for i in 0..5000u64 {
            op.clear();
            op.push(&a, i, i + 1);
            op.push(&b, i, i + 1);
            assert!(op.execute(), "iteration {i}");
        }
        assert_eq!((a.read(), b.read()), (5000, 5000));
    }

    #[test]
    fn concurrent_multiword_counters_stay_in_lockstep() {
        const THREADS: usize = 8;
        const OPS: u64 = 2_000;
        const K: usize = 4;
        let words: Arc<Vec<Word>> =
            Arc::new((0..K).map(|_| Word::new(0)).collect());
        let done = Arc::new(RawA::new(0));

        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let words = words.clone();
            handles.push(std::thread::spawn(move || {
                let mut op = OpBuilder::new();
                let mut succ = 0u64;
                while succ < OPS {
                    let v = words[0].read();
                    op.clear();
                    for w in words.iter() {
                        op.push(w, v, v + 1);
                    }
                    if op.execute() {
                        succ += 1;
                    }
                }
            }));
        }
        // Reader thread: atomicity invariant — reading w[0] then w[i]
        // must never observe w[i] < w[0] (reads help in-flight ops).
        {
            let words = words.clone();
            let done = done.clone();
            handles.push(std::thread::spawn(move || {
                while done.load(Ordering::Relaxed) == 0 {
                    let x = words[0].read();
                    for w in words.iter().skip(1) {
                        let y = w.read();
                        assert!(y >= x, "torn K-CAS visible: {y} < {x}");
                    }
                }
            }));
        }
        for h in handles.drain(..THREADS) {
            h.join().unwrap();
        }
        done.store(1, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        for w in words.iter() {
            assert_eq!(w.read(), (THREADS as u64) * OPS);
        }
    }

    #[test]
    fn contended_disjoint_then_overlapping() {
        // Two threads repeatedly K-CAS overlapping word pairs (a,b) and
        // (b,c): b's value must stay consistent with exactly one history.
        let a = Arc::new(Word::new(0));
        let b = Arc::new(Word::new(0));
        let c = Arc::new(Word::new(0));
        let t1 = {
            let (a, b) = (a.clone(), b.clone());
            std::thread::spawn(move || {
                let mut op = OpBuilder::new();
                let mut n = 0;
                while n < 3000 {
                    let (va, vb) = (a.read(), b.read());
                    op.clear();
                    op.push(&a, va, va + 1);
                    op.push(&b, vb, vb + 1);
                    if op.execute() {
                        n += 1;
                    }
                }
            })
        };
        let t2 = {
            let (b, c) = (b.clone(), c.clone());
            std::thread::spawn(move || {
                let mut op = OpBuilder::new();
                let mut n = 0;
                while n < 3000 {
                    let (vb, vc) = (b.read(), c.read());
                    op.clear();
                    op.push(&b, vb, vb + 1);
                    op.push(&c, vc, vc + 1);
                    if op.execute() {
                        n += 1;
                    }
                }
            })
        };
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(a.read(), 3000);
        assert_eq!(c.read(), 3000);
        assert_eq!(b.read(), 6000);
    }
}
