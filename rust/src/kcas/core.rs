//! The K-CAS algorithm: RDCSS install phase + decide + detach phase,
//! with helping and seq-validated descriptor reuse.
//!
//! All descriptor-field traffic uses SeqCst on the validation-critical
//! words (`status`, `seq`) and Acquire/Release elsewhere; the validation
//! protocol (fields are read, then the seq/status is re-checked) is what
//! makes stale helpers harmless — see registry.rs.

use std::sync::atomic::{AtomicU64, Ordering::*};

use super::registry::{registry, thread_id};
use super::tagged::*;
use crate::util::metrics::metrics;

/// Linearizable read of a K-CAS-managed word (helps descriptors).
#[inline]
pub fn read(word: &AtomicU64) -> u64 {
    loop {
        let v = word.load(SeqCst);
        match tag_of(v) {
            TAG_VALUE => return v >> 2,
            TAG_RDCSS => rdcss_complete(ref_tid(v), ref_seq(v)),
            _ => {
                help_kcas(v);
            }
        }
    }
}

/// CAS `old -> new` (plain values) through the protocol.
/// Ok on success; Err(current-decoded-value) when the word holds a
/// different value; Err(old) — i.e. retryable — after helping.
#[inline]
pub fn try_cas_value(word: &AtomicU64, old: u64, new: u64) -> Result<(), u64> {
    try_cas_value_enc(word, old << 2, new << 2).map_err(|e| e >> 2)
}

/// Like [`try_cas_value`] but on already-encoded words. The Err payload
/// is encoded; descriptors are helped and reported as Err(old) so the
/// caller retries.
#[inline]
pub fn try_cas_value_enc(word: &AtomicU64, old: u64, new: u64) -> Result<(), u64> {
    match word.compare_exchange(old, new, SeqCst, SeqCst) {
        Ok(_) => Ok(()),
        Err(cur) => match tag_of(cur) {
            TAG_VALUE => Err(cur),
            TAG_RDCSS => {
                rdcss_complete(ref_tid(cur), ref_seq(cur));
                Err(old) // retry
            }
            _ => {
                help_kcas(cur);
                Err(old) // retry
            }
        },
    }
}

/// Unconditional-write helper used by `Word::write`.
#[inline]
pub fn cas_value(word: &AtomicU64, old: u64, new: u64) -> bool {
    matches!(try_cas_value(word, old, new), Ok(()))
}

/// Execute a K-CAS over `entries` (sorted by address, encoded old/new)
/// using this thread's descriptor. Returns true iff it succeeded.
pub fn kcas(entries: &[(usize, u64, u64)]) -> bool {
    let tid = thread_id();
    let slot = &registry()[tid];
    let desc = &slot.kcas;
    assert!(
        entries.len() <= super::registry::MAX_ENTRIES,
        "K-CAS too wide: {} entries (Robin Hood displacement chain \
         exceeded MAX_ENTRIES; grow kcas::MAX_ENTRIES)",
        entries.len()
    );

    // New incarnation: bump seq FIRST (invalidates stale references),
    // then publish fields, then run.
    // ORDERING: Relaxed read of our own descriptor's status — only the
    // owner thread bumps it, so this just re-reads the thread's last
    // store; the SeqCst store below is what publishes the new seq.
    let seq = status_seq(desc.status.load(Relaxed)).wrapping_add(1) & SEQ_MASK;
    desc.status.store(pack_status(seq, UNDECIDED), SeqCst);
    desc.n.store(entries.len(), Release);
    for (i, &(addr, old, new)) in entries.iter().enumerate() {
        desc.entries[i].addr.store(addr, Release);
        desc.entries[i].old.store(old, Release);
        desc.entries[i].new.store(new, Release);
    }
    metrics().kcas_attempts.incr();
    let ok = execute(tid, seq);
    if !ok {
        // The owner's verdict is authoritative (its descriptor can't be
        // reused concurrently), so this counts exactly the failed
        // executions the caller will re-probe and retry.
        metrics().kcas_retries.incr();
    }
    ok
}

/// Help a K-CAS referenced by `kref` (called when a reader/installer
/// encounters the reference in a word).
pub fn help_kcas(kref: u64) {
    debug_assert_eq!(tag_of(kref), TAG_KCAS);
    metrics().kcas_helps.incr();
    execute(ref_tid(kref), ref_seq(kref));
}

/// Run (or help) K-CAS incarnation `seq` of thread `tid` to completion.
/// Returns the success flag — accurate for the owner (whose descriptor
/// cannot be concurrently reused); helpers may get a stale `false` after
/// the op finished, which they ignore.
fn execute(tid: usize, seq: u64) -> bool {
    let desc = &registry()[tid].kcas;
    let myref = make_ref(tid, seq, TAG_KCAS);
    let undecided = pack_status(seq, UNDECIDED);

    let st = desc.status.load(SeqCst);
    if status_seq(st) != seq {
        return false; // stale helper; op already finished
    }
    if status_state(st) == UNDECIDED {
        let n = desc.n.load(Acquire);
        if status_seq(desc.status.load(SeqCst)) != seq {
            return false;
        }
        let mut newstate = SUCCEEDED;
        'install: for i in 0..n {
            let addr = desc.entries[i].addr.load(Acquire);
            let old = desc.entries[i].old.load(Acquire);
            if status_seq(desc.status.load(SeqCst)) != seq {
                return false;
            }
            // SAFETY: entry addresses are bucket words of tables the
            // crate never frees while operations can reference them
            // (retired generations are held until the wrapper drops);
            // the seq re-validation above confirmed the entries belong
            // to a live incarnation when they were read.
            let word = unsafe { &*(addr as *const AtomicU64) };
            loop {
                let r = rdcss(&desc.status, undecided, word, old, myref);
                if r == old || r == myref {
                    break; // installed (or someone installed for us)
                }
                if tag_of(r) == TAG_KCAS {
                    help_kcas(r); // resolve the other op, then retry
                    continue;
                }
                // A different plain value: the whole K-CAS fails.
                newstate = FAILED;
                break 'install;
            }
            // If the status was decided while we installed, stop early.
            let st = desc.status.load(SeqCst);
            if st != undecided {
                if status_seq(st) != seq {
                    return false;
                }
                newstate = status_state(st);
                break;
            }
        }
        let _ = desc.status.compare_exchange(
            undecided,
            pack_status(seq, newstate),
            SeqCst,
            SeqCst,
        );
    }

    // Phase 2: detach — replace our reference with the decided value.
    let st = desc.status.load(SeqCst);
    if status_seq(st) != seq {
        return false;
    }
    let success = status_state(st) == SUCCEEDED;
    let n = desc.n.load(Acquire);
    if status_seq(desc.status.load(SeqCst)) != seq {
        return success;
    }
    for i in 0..n {
        let addr = desc.entries[i].addr.load(Acquire);
        let old = desc.entries[i].old.load(Acquire);
        let new = desc.entries[i].new.load(Acquire);
        if status_seq(desc.status.load(SeqCst)) != seq {
            return success;
        }
        // SAFETY: as in the install phase — seq-validated entry
        // addresses point at bucket words that outlive the operation.
        let word = unsafe { &*(addr as *const AtomicU64) };
        let target = if success { new } else { old };
        let _ = word.compare_exchange(myref, target, SeqCst, SeqCst);
    }
    success
}

/// RDCSS (restricted double-compare single-swap): atomically
/// `if *status == expected_status { *word: old2 -> new2 }`, returning
/// the prior (encoded/tagged) content of `word`. `old2` is an encoded
/// value, `new2` a K-CAS descriptor reference.
///
/// Returns `old2` when the conditional swap was performed (or was
/// performed-and-reverted because the status had been decided — the
/// caller re-checks status either way); any other return is the
/// interfering content (a value or a K-CAS reference; alien RDCSS
/// descriptors are resolved internally).
fn rdcss(
    status: &AtomicU64,
    expected_status: u64,
    word: &AtomicU64,
    old2: u64,
    new2: u64,
) -> u64 {
    let tid = thread_id();
    let d = &registry()[tid].rdcss;

    // New incarnation of this thread's RDCSS descriptor.
    // ORDERING: Relaxed read of our own descriptor's seq — the owner
    // thread is its only writer; the SeqCst store below publishes.
    let seq = d.seq.load(Relaxed).wrapping_add(1) & SEQ_MASK;
    d.seq.store(seq, SeqCst);
    d.status_addr
        .store(status as *const AtomicU64 as usize, Release);
    d.expected_status.store(expected_status, Release);
    d.word_addr.store(word as *const AtomicU64 as usize, Release);
    d.old2.store(old2, Release);
    d.new2.store(new2, Release);
    let rref = make_ref(tid, seq, TAG_RDCSS);

    loop {
        match word.compare_exchange(old2, rref, SeqCst, SeqCst) {
            Ok(_) => {
                rdcss_complete(tid, seq);
                return old2;
            }
            Err(r) => {
                if tag_of(r) == TAG_RDCSS {
                    rdcss_complete(ref_tid(r), ref_seq(r));
                    continue;
                }
                return r;
            }
        }
    }
}

/// Complete (help) RDCSS incarnation `seq` of thread `tid`: decide the
/// condition and swing the word to `new2` or back to `old2`.
fn rdcss_complete(tid: usize, seq: u64) {
    let d = &registry()[tid].rdcss;
    let status_addr = d.status_addr.load(Acquire);
    let expected_status = d.expected_status.load(Acquire);
    let word_addr = d.word_addr.load(Acquire);
    let old2 = d.old2.load(Acquire);
    let new2 = d.new2.load(Acquire);
    if d.seq.load(SeqCst) != seq {
        return; // stale: the RDCSS already completed
    }
    let rref = make_ref(tid, seq, TAG_RDCSS);
    // SAFETY: `status_addr` names a K-CAS descriptor status word in the
    // 'static registry, so the pointer is always valid.
    let status = unsafe { &*(status_addr as *const AtomicU64) };
    // SAFETY: `word_addr` names a table bucket word; tables (including
    // retired generations) are never freed while ops can reference
    // them, and the seq check above validated the field snapshot.
    let word = unsafe { &*(word_addr as *const AtomicU64) };
    let cond = status.load(SeqCst) == expected_status;
    let target = if cond { new2 } else { old2 };
    let _ = word.compare_exchange(rref, target, SeqCst, SeqCst);
}
