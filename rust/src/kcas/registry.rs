//! Per-thread descriptor registry + thread-id assignment.
//!
//! One K-CAS descriptor and one RDCSS descriptor per thread slot,
//! allocated once, *reused forever* (Arbel-Raviv & Brown). A descriptor
//! reference embeds `(tid, seq)`; helpers validate `seq` after reading
//! fields, which makes references to reused descriptors harmless: if the
//! seq moved on, the referenced operation already completed and every
//! word it owned has been detached, so the helper's CAS (expecting the
//! stale reference) fails benignly.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::util::pad::CachePadded;

use super::tagged::{pack_status, UNDECIDED};

/// Maximum number of *concurrently live* registered threads.
pub const MAX_THREADS: usize = 256;

/// Maximum entries per K-CAS (a Robin Hood displacement/shift chain plus
/// its timestamp increments; far beyond anything observed at LF <= 0.9).
pub const MAX_ENTRIES: usize = 4096;

/// One K-CAS entry as seen by helpers. Old/new are stored *encoded*
/// (`value << 2`).
pub struct KEntry {
    pub addr: AtomicUsize,
    pub old: AtomicU64,
    pub new: AtomicU64,
}

/// Reusable K-CAS descriptor. `status` packs `(seq << 2) | state`; the
/// seq is bumped when the owner starts a new operation, which atomically
/// invalidates all outstanding references to the previous incarnation.
pub struct KCasDesc {
    pub status: AtomicU64,
    pub n: AtomicUsize,
    pub entries: Box<[KEntry]>,
}

/// Reusable RDCSS descriptor (one in-flight RDCSS per thread at a time —
/// RDCSS invocations never overlap within a thread).
pub struct RdcssDesc {
    pub seq: AtomicU64,
    /// Address of the controlling K-CAS status word (`addr1`).
    pub status_addr: AtomicUsize,
    /// Expected status (`old1`): `pack_status(kseq, UNDECIDED)`.
    pub expected_status: AtomicU64,
    /// Target data word (`addr2`).
    pub word_addr: AtomicUsize,
    /// Expected encoded value (`old2`).
    pub old2: AtomicU64,
    /// K-CAS descriptor reference to install (`new2`).
    pub new2: AtomicU64,
}

pub struct Slot {
    pub kcas: KCasDesc,
    pub rdcss: RdcssDesc,
}

fn new_slot() -> CachePadded<Slot> {
    CachePadded::new(Slot {
        kcas: KCasDesc {
            status: AtomicU64::new(pack_status(0, UNDECIDED)),
            n: AtomicUsize::new(0),
            entries: (0..MAX_ENTRIES)
                .map(|_| KEntry {
                    addr: AtomicUsize::new(0),
                    old: AtomicU64::new(0),
                    new: AtomicU64::new(0),
                })
                .collect(),
        },
        rdcss: RdcssDesc {
            seq: AtomicU64::new(0),
            status_addr: AtomicUsize::new(0),
            expected_status: AtomicU64::new(0),
            word_addr: AtomicUsize::new(0),
            old2: AtomicU64::new(0),
            new2: AtomicU64::new(0),
        },
    })
}

static REGISTRY: OnceLock<Vec<CachePadded<Slot>>> = OnceLock::new();

pub fn registry() -> &'static [CachePadded<Slot>] {
    REGISTRY.get_or_init(|| (0..MAX_THREADS).map(|_| new_slot()).collect())
}

// ---- thread-id assignment (free-listed so short-lived test threads
// don't exhaust the slot space) ----

static NEXT_TID: AtomicUsize = AtomicUsize::new(0);
static FREE_TIDS: Mutex<Vec<usize>> = Mutex::new(Vec::new());

struct TidGuard(usize);

impl Drop for TidGuard {
    fn drop(&mut self) {
        FREE_TIDS.lock().unwrap().push(self.0);
    }
}

thread_local! {
    static TID: TidGuard = TidGuard(alloc_tid());
}

fn alloc_tid() -> usize {
    crate::util::metrics::metrics().kcas_descriptors.incr();
    if let Some(t) = FREE_TIDS.lock().unwrap().pop() {
        return t;
    }
    // ORDERING: a fresh-id ticket — uniqueness comes from the atomic
    // RMW itself; no other memory is published through the counter.
    let t = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    assert!(
        t < MAX_THREADS,
        "more than {MAX_THREADS} concurrently live K-CAS threads"
    );
    t
}

/// This thread's registry slot index (assigned on first use, released on
/// thread exit).
#[inline]
pub fn thread_id() -> usize {
    TID.with(|g| g.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_ids_stable_within_thread() {
        assert_eq!(thread_id(), thread_id());
    }

    #[test]
    fn thread_ids_unique_across_live_threads() {
        let mine = thread_id();
        let theirs = std::thread::spawn(thread_id).join().unwrap();
        assert_ne!(mine, theirs);
    }

    #[test]
    fn tids_are_recycled_after_thread_exit() {
        let _ = thread_id();
        let a = std::thread::spawn(thread_id).join().unwrap();
        // The exited thread's tid goes back on the free list; a new
        // thread should be able to draw it again (not guaranteed to be
        // the same one if other tests run in parallel, so just check the
        // pool doesn't grow monotonically).
        let before = NEXT_TID.load(Ordering::Relaxed);
        for _ in 0..64 {
            let b = std::thread::spawn(thread_id).join().unwrap();
            assert!(b < MAX_THREADS);
            let _ = a;
        }
        let after = NEXT_TID.load(Ordering::Relaxed);
        assert!(after - before <= 64, "tids not recycled: {before} -> {after}");
    }

    #[test]
    fn registry_has_max_threads_slots() {
        assert_eq!(registry().len(), MAX_THREADS);
        assert_eq!(registry()[0].kcas.entries.len(), MAX_ENTRIES);
    }
}
