//! Pure-Rust interpreter backend for the artifact runtime (default).
//!
//! Evaluates the hash pipeline and probe-statistics computations
//! directly instead of through PJRT. This is semantically exact, not an
//! approximation: the L1 Pallas kernel *is* SplitMix64 (the golden
//! vectors in `artifacts/golden_hash.txt` pin all three layers to the
//! same bits), and the probe-statistics graph is a histogram/moment
//! fold with a closed-form Rust equivalent. The batch-shape checks and
//! chunking behaviour of the PJRT backend are preserved so the two
//! backends are drop-in interchangeable.

use std::path::Path;

use super::{artifacts_dir, Manifest, ProbeStats};
use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::hash::splitmix64;

/// Interpreter engine: same surface as the PJRT backend.
pub struct Engine {
    pub manifest: Manifest,
    platform: &'static str,
}

impl Engine {
    /// Load from `dir`. A missing `MANIFEST.txt` falls back to the
    /// synthetic manifest (the interpreter needs no compiled HLO), so
    /// `crh analyze` works from a clean checkout.
    pub fn load(dir: &Path) -> Result<Engine> {
        let mpath = dir.join("MANIFEST.txt");
        if mpath.exists() {
            let text = std::fs::read_to_string(&mpath)
                .with_context(|| format!("reading {}", mpath.display()))?;
            Ok(Engine { manifest: Manifest::parse(&text)?, platform: "rust-interp" })
        } else {
            Ok(Engine {
                manifest: Manifest::synthetic(),
                platform: "rust-interp (synthetic manifest)",
            })
        }
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(&artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.platform.to_string()
    }

    /// Run one fixed-size batch through the hash pipeline:
    /// `(hashes, home buckets)`. `keys.len()` must equal the manifest's
    /// `hash_batch` (same contract as the compiled executable).
    pub fn hash_batch(&self, keys: &[i64]) -> Result<(Vec<i64>, Vec<i64>)> {
        if keys.len() != self.manifest.hash_batch {
            bail!(
                "hash_batch expects {} keys, got {}",
                self.manifest.hash_batch,
                keys.len()
            );
        }
        let mask = (1u64 << self.manifest.size_log2) - 1;
        let hashes: Vec<i64> =
            keys.iter().map(|&k| splitmix64(k as u64) as i64).collect();
        let buckets: Vec<i64> =
            hashes.iter().map(|&h| (h as u64 & mask) as i64).collect();
        Ok((hashes, buckets))
    }

    /// Hash an arbitrary-length key stream by chunking through the
    /// fixed batch (the tail is padded with zeros and trimmed).
    pub fn hash_stream(&self, keys: &[i64]) -> Result<Vec<i64>> {
        let b = self.manifest.hash_batch;
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            if chunk.len() == b {
                out.extend(self.hash_batch(chunk)?.0);
            } else {
                let mut padded = chunk.to_vec();
                padded.resize(b, 0);
                out.extend(self.hash_batch(&padded)?.0[..chunk.len()].iter());
            }
        }
        Ok(out)
    }

    /// Probe-distance analytics over a DFB snapshot. -1 marks empty
    /// buckets; DFBs beyond `max_dfb` accumulate in the last histogram
    /// bin, exactly like the compiled graph.
    pub fn probe_stats(&self, dfb: &[i32]) -> Result<ProbeStats> {
        let bins = self.manifest.max_dfb + 1;
        let mut hist = vec![0i64; bins];
        let (mut count, mut sum, mut sq, mut max) = (0i64, 0f64, 0f64, -1i32);
        for &d in dfb {
            if d < 0 {
                continue;
            }
            hist[(d as usize).min(bins - 1)] += 1;
            count += 1;
            sum += d as f64;
            sq += d as f64 * d as f64;
            max = max.max(d);
        }
        let mean = if count > 0 { sum / count as f64 } else { 0.0 };
        let var =
            if count > 0 { sq / count as f64 - mean * mean } else { 0.0 };
        Ok(ProbeStats { hist, count, mean, var, max })
    }

    /// Verify the Rust hot-path hash agrees bit-for-bit with the
    /// pipeline on the golden vectors emitted by `aot.py`.
    pub fn verify_golden(&self, dir: &Path) -> Result<usize> {
        let path = dir.join("golden_hash.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut keys = Vec::new();
        let mut hashes = Vec::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            if let (Some(k), Some(h)) = (it.next(), it.next()) {
                keys.push(k.parse::<i64>()?);
                hashes.push(h.parse::<i64>()?);
            }
        }
        let got = self.hash_stream(&keys)?;
        for (i, (&want, &g)) in hashes.iter().zip(&got).enumerate() {
            if want != g {
                bail!(
                    "golden mismatch at {i}: key {} want {want} got {g}",
                    keys[i]
                );
            }
            let rust = splitmix64(keys[i] as u64) as i64;
            if rust != want {
                bail!("rust splitmix64 mismatch at {i}: {rust} vs {want}");
            }
        }
        Ok(keys.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine {
            manifest: Manifest {
                hash_batch: 64,
                stats_batch: 64,
                max_dfb: 8,
                size_log2: 10,
            },
            platform: "rust-interp",
        }
    }

    #[test]
    fn hash_batch_shape_checked() {
        let e = engine();
        assert!(e.hash_batch(&[1, 2, 3]).is_err());
        let keys: Vec<i64> = (0..64).collect();
        let (h, b) = e.hash_batch(&keys).unwrap();
        for i in 0..64 {
            assert_eq!(h[i] as u64, splitmix64(keys[i] as u64));
            assert_eq!(b[i] as u64, h[i] as u64 & 1023);
        }
    }

    #[test]
    fn hash_stream_ragged_tail() {
        let e = engine();
        let keys: Vec<i64> = (0..100).map(|i| i * 31 + 7).collect();
        let out = e.hash_stream(&keys).unwrap();
        assert_eq!(out.len(), 100);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(out[i] as u64, splitmix64(k as u64));
        }
    }

    #[test]
    fn probe_stats_moments_and_overflow() {
        let e = engine();
        // DFBs: two 0s, one 3, one 100 (overflow bin), plus empties.
        let stats = e.probe_stats(&[-1, 0, 0, 3, -1, 100]).unwrap();
        assert_eq!(stats.count, 4);
        assert_eq!(stats.hist[0], 2);
        assert_eq!(stats.hist[3], 1);
        assert_eq!(*stats.hist.last().unwrap(), 1); // overflow
        assert_eq!(stats.hist.iter().sum::<i64>(), stats.count);
        assert_eq!(stats.max, 100);
        let mean = (0.0 + 0.0 + 3.0 + 100.0) / 4.0;
        assert!((stats.mean - mean).abs() < 1e-12);
    }

    #[test]
    fn load_without_artifacts_synthesizes_manifest() {
        let e = Engine::load(Path::new("/definitely/not/a/dir")).unwrap();
        assert_eq!(e.manifest, Manifest::synthetic());
        assert!(e.platform().contains("rust-interp"));
    }
}
