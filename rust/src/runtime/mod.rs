//! Artifact runtime: execute the AOT-compiled hash pipeline and
//! probe-statistics graphs.
//!
//! Python (JAX + Pallas) runs **once** at build time (`make artifacts`),
//! lowering the L2 hash pipeline and probe-statistics graphs to HLO
//! text. Two interchangeable backends consume them:
//!
//! * [`interp`] (default) — a pure-Rust interpreter that evaluates the
//!   same computations directly (`splitmix64` is bit-identical to the
//!   L1 Pallas kernel by construction; probe statistics are a plain
//!   fold). It needs no external crates, works without artifacts (a
//!   synthetic manifest is substituted), and keeps the offline build
//!   green.
//! * `pjrt` (enable the `xla` cargo feature) — the original PJRT/XLA
//!   path: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `compile` → `execute`. Requires a vendored `xla` crate and the
//!   artifacts on disk. Interchange is HLO *text* (not serialized
//!   protos): jax ≥ 0.5 emits 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Both backends expose the same [`Engine`] surface, and
//! `rust/tests/runtime_integration.rs` asserts backend/Rust agreement
//! on the golden vectors emitted by `aot.py` whenever artifacts exist.

use std::path::PathBuf;

use crate::util::error::{Context, Result};

/// Parsed `artifacts/MANIFEST.txt` — shapes the executables were
/// specialised to; the runtime asserts on these before executing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub hash_batch: usize,
    pub stats_batch: usize,
    pub max_dfb: usize,
    pub size_log2: u32,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            if let (Some(k), Some(v)) = (it.next(), it.next()) {
                kv.insert(k.to_string(), v.parse::<u64>()?);
            }
        }
        let get = |k: &str| -> Result<u64> {
            kv.get(k).copied().with_context(|| format!("manifest missing {k}"))
        };
        Ok(Manifest {
            hash_batch: get("hash_batch")? as usize,
            stats_batch: get("stats_batch")? as usize,
            max_dfb: get("max_dfb")? as usize,
            size_log2: get("size_log2")? as u32,
        })
    }

    /// Default shapes used by the interpreter backend when no artifacts
    /// have been built (mirrors `aot.py` defaults).
    pub fn synthetic() -> Manifest {
        Manifest {
            hash_batch: 65536,
            stats_batch: 65536,
            max_dfb: 64,
            size_log2: 23,
        }
    }
}

/// Probe-length statistics computed by the `probe_stats` graph.
#[derive(Clone, Debug)]
pub struct ProbeStats {
    /// hist[d] = buckets at DFB d; the last bin accumulates overflow.
    pub hist: Vec<i64>,
    pub count: i64,
    pub mean: f64,
    pub var: f64,
    pub max: i32,
}

/// Default artifacts directory (overridable via `CRH_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("CRH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

// The PJRT backend needs a vendored `xla` crate that this offline tree
// does not carry; fail the feature with an actionable message instead
// of an unresolved-crate error. Once the crate is vendored (add it to
// rust/Cargo.toml), build with `RUSTFLAGS="--cfg xla_available"`.
#[cfg(all(feature = "xla", not(xla_available)))]
compile_error!(
    "the `xla` feature requires a vendored `xla` crate: add it to \
     rust/Cargo.toml [dependencies], then build with \
     RUSTFLAGS=\"--cfg xla_available\" (see runtime module docs)"
);

#[cfg(all(feature = "xla", xla_available))]
mod pjrt;
#[cfg(all(feature = "xla", xla_available))]
pub use pjrt::Engine;

#[cfg(not(all(feature = "xla", xla_available)))]
mod interp;
#[cfg(not(all(feature = "xla", xla_available)))]
pub use interp::Engine;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let m = Manifest::parse(
            "hash_batch 65536\nstats_batch 65536\nmax_dfb 64\nsize_log2 23\n",
        )
        .unwrap();
        assert_eq!(
            m,
            Manifest {
                hash_batch: 65536,
                stats_batch: 65536,
                max_dfb: 64,
                size_log2: 23
            }
        );
        assert_eq!(m, Manifest::synthetic());
    }

    #[test]
    fn manifest_missing_key_errors() {
        assert!(Manifest::parse("hash_batch 10\n").is_err());
    }

    // Engine tests that need artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).
}
