//! PJRT/XLA backend for the artifact runtime (`--features xla`).
//!
//! Loads the HLO-text artifacts through the `xla` crate
//! (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile`
//! → `execute`). This is the original backend; it is feature-gated
//! because the `xla` crate must be vendored (it is not available in the
//! offline build). See `runtime` module docs and
//! /opt/xla-example/README.md.

use std::path::Path;

use super::{artifacts_dir, Manifest, ProbeStats};
use crate::bail;
use crate::util::error::{Context, Result};

/// The PJRT engine: compiled executables for the hash pipeline and the
/// probe-statistics analytics.
pub struct Engine {
    client: xla::PjRtClient,
    hash_exe: xla::PjRtLoadedExecutable,
    stats_exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

impl Engine {
    /// Load and compile all artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::parse(
            &std::fs::read_to_string(dir.join("MANIFEST.txt"))
                .with_context(|| {
                    format!(
                        "reading {}/MANIFEST.txt — run `make artifacts` first",
                        dir.display()
                    )
                })?,
        )?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        Ok(Engine {
            hash_exe: compile("hash_pipeline.hlo.txt")?,
            stats_exe: compile("probe_stats.hlo.txt")?,
            manifest,
            client,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Self::load(&artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run one fixed-size batch through the hash pipeline:
    /// `(hashes, home buckets)`. `keys.len()` must equal the manifest's
    /// `hash_batch`.
    pub fn hash_batch(&self, keys: &[i64]) -> Result<(Vec<i64>, Vec<i64>)> {
        if keys.len() != self.manifest.hash_batch {
            bail!(
                "hash_batch expects {} keys, got {}",
                self.manifest.hash_batch,
                keys.len()
            );
        }
        let lit = xla::Literal::vec1(keys);
        let out = self.hash_exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        if parts.len() != 2 {
            bail!("hash pipeline returned {} outputs, want 2", parts.len());
        }
        Ok((parts[0].to_vec::<i64>()?, parts[1].to_vec::<i64>()?))
    }

    /// Hash an arbitrary-length key stream by chunking through the
    /// fixed batch (the tail is padded with zeros and trimmed).
    pub fn hash_stream(&self, keys: &[i64]) -> Result<Vec<i64>> {
        let b = self.manifest.hash_batch;
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(b) {
            if chunk.len() == b {
                out.extend(self.hash_batch(chunk)?.0);
            } else {
                let mut padded = chunk.to_vec();
                padded.resize(b, 0);
                out.extend(self.hash_batch(&padded)?.0[..chunk.len()].iter());
            }
        }
        Ok(out)
    }

    /// Probe-distance analytics over a DFB snapshot (padded with -1 to
    /// the artifact's batch size; -1 marks empty buckets, so padding is
    /// neutral).
    pub fn probe_stats(&self, dfb: &[i32]) -> Result<ProbeStats> {
        let b = self.manifest.stats_batch;
        let mut hist = vec![0i64; self.manifest.max_dfb + 1];
        let (mut count, mut sum, mut sq, mut max) = (0i64, 0f64, 0f64, -1i32);
        for chunk in dfb.chunks(b) {
            let mut padded = chunk.to_vec();
            padded.resize(b, -1);
            let lit = xla::Literal::vec1(&padded);
            let out = self.stats_exe.execute::<xla::Literal>(&[lit])?[0][0]
                .to_literal_sync()?;
            let parts = out.to_tuple()?;
            if parts.len() != 5 {
                bail!("probe_stats returned {} outputs, want 5", parts.len());
            }
            let h = parts[0].to_vec::<i64>()?;
            let c = parts[1].to_vec::<i64>()?[0];
            let mean = parts[2].to_vec::<f64>()?[0];
            let var = parts[3].to_vec::<f64>()?[0];
            let mx = parts[4].to_vec::<i32>()?[0];
            for (a, b) in hist.iter_mut().zip(h) {
                *a += b;
            }
            // Merge chunk moments.
            let cf = c as f64;
            sum += mean * cf;
            sq += (var + mean * mean) * cf;
            count += c;
            max = max.max(mx);
        }
        let mean = if count > 0 { sum / count as f64 } else { 0.0 };
        let var =
            if count > 0 { sq / count as f64 - mean * mean } else { 0.0 };
        Ok(ProbeStats { hist, count, mean, var, max })
    }

    /// Verify the Rust hot-path hash agrees bit-for-bit with the AOT
    /// pipeline on the golden vectors emitted by `aot.py`.
    pub fn verify_golden(&self, dir: &Path) -> Result<usize> {
        let text = std::fs::read_to_string(dir.join("golden_hash.txt"))?;
        let mut keys = Vec::new();
        let mut hashes = Vec::new();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            if let (Some(k), Some(h)) = (it.next(), it.next()) {
                keys.push(k.parse::<i64>()?);
                hashes.push(h.parse::<i64>()?);
            }
        }
        let got = self.hash_stream(&keys)?;
        for (i, (&want, &g)) in hashes.iter().zip(&got).enumerate() {
            if want != g {
                bail!("golden mismatch at {i}: key {} want {want} got {g}", keys[i]);
            }
            // And against the Rust implementation.
            let rust = crate::util::hash::splitmix64(keys[i] as u64) as i64;
            if rust != want {
                bail!("rust splitmix64 mismatch at {i}: {rust} vs {want}");
            }
        }
        Ok(keys.len())
    }
}
