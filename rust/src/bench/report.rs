//! Perf-trajectory snapshots — record what "faster" means.
//!
//! Every experiment cell (`coordinator::fig10..fig17`, `table1`) is
//! captured as a typed [`CellResult`]; an experiment run bundles its
//! cells with the machine fingerprint and sweep spec into a
//! [`BenchReport`], which both the human-readable `println!` tables
//! and the snapshot writer consume. When `CRH_BENCH_JSON=1` (or the
//! process was invoked with `--json`) the report is also written to
//! `BENCH_<fig>.json` — a dependency-free JSON document
//! ([`crate::util::json`]) that later runs compare against with
//! [`compare`] / `crh bench-compare`, flagging any cell whose median
//! throughput regressed by more than [`REGRESSION_THRESHOLD`].
//!
//! Snapshot schema (version 1):
//!
//! ```json
//! {
//!   "version": 1,
//!   "fig": "fig15",
//!   "unix_time": 1754550000,
//!   "fingerprint": {
//!     "cpu_model": "...", "cpus": 8, "kernel": "6.8.0",
//!     "os": "linux/x86_64", "env": {"CRH_BENCH_MS": "100"}
//!   },
//!   "spec": {"size_log2": "20", "duration_ms": "500", "reps": "3"},
//!   "cells": [{
//!     "labels": {"engine": "incremental", "threads": "2"},
//!     "ops_per_us": {"min": 9.1, "median": 9.4, "max": 9.6, "reps": 3},
//!     "latency_ns": {"p50": 724, "p99": 11585, "p999": 46341,
//!                    "max": 812345},
//!     "extra": {"grows": 2},
//!     "metrics": {"probe_p99": 6.0, "kcas_retry_rate": 0.002}
//!   }]
//! }
//! ```
//!
//! The `metrics` section is the telemetry delta the cell's measurement
//! window observed ([`crate::util::metrics::cell_metrics`]) — probe
//! p50/p99, K-CAS retry rate, stripes drained — so a regression report
//! can say *why* a median moved, not just that it did; [`compare`]
//! surfaces metric shifts beyond the threshold as warn-level notes.

use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::driver::LatencyHist;

/// Snapshot schema version written (and the only one read).
pub const SNAPSHOT_VERSION: u64 = 1;

/// A cell whose median throughput drops by more than this fraction
/// (or whose p99 latency rises by more, for latency-only cells) is
/// classified as regressed.
pub const REGRESSION_THRESHOLD: f64 = 0.15;

/// Min/median/max over an experiment cell's repetitions — the snapshot
/// records the spread, the tables print the median (one scheduler
/// hiccup must not become the recorded number).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Stat {
    pub min: f64,
    pub median: f64,
    pub max: f64,
    pub reps: u32,
}

impl Stat {
    /// Aggregate repetition samples. Panics on an empty slice — a cell
    /// with zero reps is a harness bug, not a measurement.
    pub fn from_samples(samples: &[f64]) -> Stat {
        assert!(!samples.is_empty(), "Stat::from_samples on empty slice");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        let median = if s.len() % 2 == 1 {
            s[s.len() / 2]
        } else {
            (s[s.len() / 2 - 1] + s[s.len() / 2]) / 2.0
        };
        Stat { min: s[0], median, max: s[s.len() - 1], reps: s.len() as u32 }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("min", Json::Num(self.min)),
            ("median", Json::Num(self.median)),
            ("max", Json::Num(self.max)),
            ("reps", Json::Num(self.reps as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<Stat, String> {
        let num = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("stat missing numeric {k:?}"))
        };
        Ok(Stat {
            min: num("min")?,
            median: num("median")?,
            max: num("max")?,
            reps: num("reps")? as u32,
        })
    }
}

/// Latency quantiles of one cell (merged across reps), in nanoseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencySummary {
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
}

impl LatencySummary {
    pub fn from_hist(h: &LatencyHist) -> LatencySummary {
        LatencySummary {
            p50_ns: h.quantile_ns(0.5),
            p99_ns: h.quantile_ns(0.99),
            p999_ns: h.quantile_ns(0.999),
            max_ns: h.max_ns(),
        }
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("p50", Json::Num(self.p50_ns as f64)),
            ("p99", Json::Num(self.p99_ns as f64)),
            ("p999", Json::Num(self.p999_ns as f64)),
            ("max", Json::Num(self.max_ns as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<LatencySummary, String> {
        let num = |k: &str| {
            v.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("latency_ns missing numeric {k:?}"))
        };
        Ok(LatencySummary {
            p50_ns: num("p50")?,
            p99_ns: num("p99")?,
            p999_ns: num("p999")?,
            max_ns: num("max")?,
        })
    }
}

/// One measured experiment cell: identifying labels plus whatever
/// metrics the experiment produced. The `println!` tables and the
/// snapshot writer both read from this — results are never formatted
/// inline and lost.
#[derive(Clone, Debug, PartialEq)]
pub struct CellResult {
    /// Ordered identifying coordinates, e.g.
    /// `[("engine", "incremental"), ("threads", "2")]`. Their joined
    /// form ([`CellResult::id`]) matches cells across snapshots.
    pub labels: Vec<(String, String)>,
    /// Throughput in the paper's headline unit (experiments measuring
    /// ops/s convert, so compare ratios stay unit-free).
    pub ops_per_us: Option<Stat>,
    /// Per-op latency quantiles, when the experiment records them.
    pub latency: Option<LatencySummary>,
    /// Auxiliary numbers (grow count, CAS failure rate, ...).
    pub extra: Vec<(String, f64)>,
    /// Telemetry delta over the cell's measurement window (probe
    /// quantiles, K-CAS retry rate, migration work) — empty when
    /// `CRH_METRICS=0`. See [`crate::util::metrics::cell_metrics`].
    pub metrics: Vec<(String, f64)>,
}

impl CellResult {
    pub fn new<I, K, V>(labels: I) -> CellResult
    where
        I: IntoIterator<Item = (K, V)>,
        K: Into<String>,
        V: ToString,
    {
        CellResult {
            labels: labels
                .into_iter()
                .map(|(k, v)| (k.into(), v.to_string()))
                .collect(),
            ops_per_us: None,
            latency: None,
            extra: Vec::new(),
            metrics: Vec::new(),
        }
    }

    pub fn with_ops(mut self, stat: Stat) -> CellResult {
        self.ops_per_us = Some(stat);
        self
    }

    pub fn with_latency(mut self, lat: LatencySummary) -> CellResult {
        self.latency = Some(lat);
        self
    }

    pub fn with_extra(mut self, key: &str, value: f64) -> CellResult {
        self.extra.push((key.to_string(), value));
        self
    }

    /// Attach the telemetry delta observed over this cell's
    /// measurement window. A no-op for an empty delta (metrics gated
    /// off), so disabled runs don't carry misleading zeros.
    pub fn with_metrics(mut self, metrics: Vec<(String, f64)>) -> CellResult {
        self.metrics = metrics;
        self
    }

    /// Stable identity used to match cells across snapshots:
    /// `k1=v1/k2=v2/...` in label order.
    pub fn id(&self) -> String {
        self.labels
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join("/")
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![(
            "labels",
            Json::Obj(
                self.labels
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            ),
        )];
        if let Some(s) = self.ops_per_us {
            pairs.push(("ops_per_us", s.to_json()));
        }
        if let Some(l) = self.latency {
            pairs.push(("latency_ns", l.to_json()));
        }
        if !self.extra.is_empty() {
            pairs.push((
                "extra",
                Json::Obj(
                    self.extra
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ));
        }
        if !self.metrics.is_empty() {
            pairs.push((
                "metrics",
                Json::Obj(
                    self.metrics
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<CellResult, String> {
        let labels = v
            .get("labels")
            .and_then(Json::as_obj)
            .ok_or("cell missing \"labels\" object")?
            .iter()
            .map(|(k, val)| {
                val.as_str()
                    .map(|s| (k.clone(), s.to_string()))
                    .ok_or_else(|| format!("label {k:?} is not a string"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        let ops_per_us = match v.get("ops_per_us") {
            Some(s) => Some(Stat::from_json(s)?),
            None => None,
        };
        let latency = match v.get("latency_ns") {
            Some(l) => Some(LatencySummary::from_json(l)?),
            None => None,
        };
        let numeric_map = |key: &str| -> Result<Vec<(String, f64)>, String> {
            match v.get(key).and_then(Json::as_obj) {
                Some(pairs) => pairs
                    .iter()
                    .map(|(k, val)| {
                        val.as_f64().map(|f| (k.clone(), f)).ok_or_else(|| {
                            format!("{key} {k:?} is not numeric")
                        })
                    })
                    .collect(),
                None => Ok(Vec::new()),
            }
        };
        let extra = numeric_map("extra")?;
        let metrics = numeric_map("metrics")?;
        Ok(CellResult { labels, ops_per_us, latency, extra, metrics })
    }
}

/// Where a snapshot was measured. Cross-machine comparisons are
/// legitimate but must be flagged — [`compare`] warns on any mismatch.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub cpu_model: String,
    pub cpus: u64,
    pub kernel: String,
    pub os: String,
    /// Every `CRH_*` environment variable at capture time, sorted —
    /// the bench tunables ride in the environment, so two snapshots
    /// with different `CRH_BENCH_*` knobs must not gate each other.
    pub env: Vec<(String, String)>,
}

impl Fingerprint {
    pub fn capture() -> Fingerprint {
        let mut env: Vec<(String, String)> = std::env::vars()
            .filter(|(k, _)| k.starts_with("CRH_"))
            .collect();
        // The telemetry gate changes what the snapshot's `metrics`
        // sections contain (and costs a branch per counter hit), so
        // record its *effective* value even when the variable is
        // unset — two runs with different gates must warn on compare.
        if !env.iter().any(|(k, _)| k == "CRH_METRICS") {
            let on = crate::util::metrics::enabled();
            let effective = if on { "1" } else { "0" };
            env.push(("CRH_METRICS".to_string(), effective.to_string()));
        }
        env.sort();
        Fingerprint {
            cpu_model: cpu_model().unwrap_or_else(|| "unknown".to_string()),
            cpus: crate::util::affinity::available_cpus() as u64,
            kernel: read_trimmed("/proc/sys/kernel/osrelease")
                .unwrap_or_else(|| "unknown".to_string()),
            os: format!("{}/{}", std::env::consts::OS, std::env::consts::ARCH),
            env,
        }
    }

    /// Human-readable description of every field where `self` (the
    /// baseline) and `other` (the fresh run) disagree.
    pub fn diff(&self, other: &Fingerprint) -> Vec<String> {
        let mut out = Vec::new();
        let mut field = |name: &str, a: &str, b: &str| {
            if a != b {
                out.push(format!("{name}: {a:?} vs {b:?}"));
            }
        };
        field("cpu_model", &self.cpu_model, &other.cpu_model);
        field("cpus", &self.cpus.to_string(), &other.cpus.to_string());
        field("kernel", &self.kernel, &other.kernel);
        field("os", &self.os, &other.os);
        let keys: std::collections::BTreeSet<&str> = self
            .env
            .iter()
            .chain(other.env.iter())
            .map(|(k, _)| k.as_str())
            .collect();
        for k in keys {
            let find = |fp: &Fingerprint| {
                fp.env
                    .iter()
                    .find(|(key, _)| key == k)
                    .map(|(_, v)| v.clone())
            };
            let (a, b) = (find(self), find(other));
            if a != b {
                let show = |v: Option<String>| {
                    v.map_or("<unset>".to_string(), |s| format!("{s:?}"))
                };
                out.push(format!("env {k}: {} vs {}", show(a), show(b)));
            }
        }
        out
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cpu_model", Json::Str(self.cpu_model.clone())),
            ("cpus", Json::Num(self.cpus as f64)),
            ("kernel", Json::Str(self.kernel.clone())),
            ("os", Json::Str(self.os.clone())),
            (
                "env",
                Json::Obj(
                    self.env
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<Fingerprint, String> {
        let s = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("fingerprint missing string {k:?}"))
        };
        let env = match v.get("env").and_then(Json::as_obj) {
            Some(pairs) => pairs
                .iter()
                .map(|(k, val)| {
                    val.as_str()
                        .map(|x| (k.clone(), x.to_string()))
                        .ok_or_else(|| format!("env {k:?} is not a string"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        Ok(Fingerprint {
            cpu_model: s("cpu_model")?,
            cpus: v
                .get("cpus")
                .and_then(Json::as_u64)
                .ok_or("fingerprint missing numeric \"cpus\"")?,
            kernel: s("kernel")?,
            os: s("os")?,
            env,
        })
    }
}

fn read_trimmed(path: &str) -> Option<String> {
    std::fs::read_to_string(path)
        .ok()
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
}

fn cpu_model() -> Option<String> {
    let info = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    info.lines()
        .find(|l| l.starts_with("model name"))
        .and_then(|l| l.split(':').nth(1))
        .map(|s| s.trim().to_string())
}

/// One experiment run's full snapshot: fingerprint + sweep spec +
/// every measured cell.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    /// Experiment id, e.g. `"fig15"` — names the snapshot file.
    pub fig: String,
    /// Seconds since the Unix epoch at capture.
    pub unix_time: u64,
    pub fingerprint: Fingerprint,
    /// The sweep configuration (table spec, workload, durations, ...),
    /// recorded as ordered string pairs so foreign snapshots stay
    /// readable even when the spec grows new keys.
    pub spec: Vec<(String, String)>,
    pub cells: Vec<CellResult>,
}

impl BenchReport {
    /// New report for experiment `fig`, capturing the machine
    /// fingerprint and wall-clock time now.
    pub fn new<I, K, V>(fig: &str, spec: I) -> BenchReport
    where
        I: IntoIterator<Item = (K, V)>,
        K: Into<String>,
        V: ToString,
    {
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        BenchReport {
            fig: fig.to_string(),
            unix_time,
            fingerprint: Fingerprint::capture(),
            spec: spec
                .into_iter()
                .map(|(k, v)| (k.into(), v.to_string()))
                .collect(),
            cells: Vec::new(),
        }
    }

    pub fn push(&mut self, cell: CellResult) {
        self.cells.push(cell);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("fig", Json::Str(self.fig.clone())),
            ("unix_time", Json::Num(self.unix_time as f64)),
            ("fingerprint", self.fingerprint.to_json()),
            (
                "spec",
                Json::Obj(
                    self.spec
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "cells",
                Json::Arr(self.cells.iter().map(CellResult::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<BenchReport, String> {
        let version = v
            .get("version")
            .and_then(Json::as_u64)
            .ok_or("snapshot missing numeric \"version\"")?;
        if version != SNAPSHOT_VERSION {
            return Err(format!(
                "unsupported snapshot version {version} (expected \
                 {SNAPSHOT_VERSION})"
            ));
        }
        let fig = v
            .get("fig")
            .and_then(Json::as_str)
            .ok_or("snapshot missing string \"fig\"")?
            .to_string();
        let unix_time = v
            .get("unix_time")
            .and_then(Json::as_u64)
            .ok_or("snapshot missing numeric \"unix_time\"")?;
        let fingerprint = Fingerprint::from_json(
            v.get("fingerprint").ok_or("snapshot missing \"fingerprint\"")?,
        )?;
        let spec = match v.get("spec").and_then(Json::as_obj) {
            Some(pairs) => pairs
                .iter()
                .map(|(k, val)| {
                    val.as_str()
                        .map(|s| (k.clone(), s.to_string()))
                        .ok_or_else(|| format!("spec {k:?} is not a string"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        let cells = v
            .get("cells")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing \"cells\" array")?
            .iter()
            .map(CellResult::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(BenchReport { fig, unix_time, fingerprint, spec, cells })
    }

    /// Render the snapshot document (pretty JSON + trailing newline).
    pub fn render(&self) -> String {
        self.to_json().render()
    }

    /// Parse a snapshot document.
    pub fn parse(text: &str) -> Result<BenchReport, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        BenchReport::from_json(&v)
    }

    /// The file name this report snapshots to: `BENCH_<fig>.json`.
    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.fig)
    }

    /// Write the snapshot into `dir`, returning the path written.
    pub fn write_to(&self, dir: &Path) -> io::Result<PathBuf> {
        let path = dir.join(self.file_name());
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

/// True when the process was asked to write snapshots: either
/// `CRH_BENCH_JSON=1` (any of `1`/`true`/`yes`) or a literal `--json`
/// argument (works for both the `crh` CLI and the
/// `cargo bench ... -- --json` harness mains).
pub fn snapshot_enabled() -> bool {
    let env_on = std::env::var("CRH_BENCH_JSON")
        .map(|v| matches!(v.as_str(), "1" | "true" | "yes"))
        .unwrap_or(false);
    env_on || std::env::args().any(|a| a == "--json")
}

/// Directory snapshots are written into: `CRH_BENCH_JSON_DIR` if set,
/// else the current directory.
pub fn snapshot_dir() -> PathBuf {
    std::env::var("CRH_BENCH_JSON_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."))
}

/// Write `report` to `BENCH_<fig>.json` when snapshots are enabled
/// (see [`snapshot_enabled`]); prints the path written. A write
/// failure is reported but never takes the benchmark down with it.
pub fn write_if_enabled(report: &BenchReport) -> Option<PathBuf> {
    if !snapshot_enabled() {
        return None;
    }
    match report.write_to(&snapshot_dir()) {
        Ok(path) => {
            println!("# wrote snapshot {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!(
                "warning: failed to write {}: {e}",
                report.file_name()
            );
            None
        }
    }
}

/// Read and parse a snapshot file.
pub fn read_snapshot(path: &Path) -> Result<BenchReport, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    BenchReport::parse(&text)
        .map_err(|e| format!("{}: {e}", path.display()))
}

/// How one cell moved between two snapshots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellClass {
    /// Within the threshold band (or no comparable metric).
    Ok,
    /// Primary metric worsened by more than the threshold.
    Regressed,
    /// Primary metric improved by more than the threshold.
    Improved,
    /// Present in the baseline, absent from the new snapshot.
    Missing,
    /// Present only in the new snapshot.
    New,
}

/// One row of a [`Comparison`].
#[derive(Clone, Debug)]
pub struct CellDelta {
    pub id: String,
    pub class: CellClass,
    /// Primary metric values (baseline, new) and their new/old ratio —
    /// `None` where the side or the metric is absent.
    pub old: Option<f64>,
    pub new: Option<f64>,
    pub ratio: Option<f64>,
    /// Secondary observations (e.g. a p99 tail-latency move on a cell
    /// whose primary metric is throughput). Never fatal on their own.
    pub notes: Vec<String>,
}

/// Result of comparing two snapshots of the same experiment.
#[derive(Clone, Debug)]
pub struct Comparison {
    pub fig: String,
    /// Fingerprint fields that differ (warn: the machines or `CRH_*`
    /// knobs were not identical, so deltas may not be meaningful).
    pub fingerprint_diffs: Vec<String>,
    /// Label-key sets present in only one snapshot (warn: cells went
    /// missing/new because a sweep *dimension* changed, not because a
    /// configuration vanished — names the differing keys).
    pub label_key_diffs: Vec<String>,
    pub deltas: Vec<CellDelta>,
}

impl Comparison {
    pub fn count(&self, class: CellClass) -> usize {
        self.deltas.iter().filter(|d| d.class == class).count()
    }

    /// True when any cell regressed — the condition `crh
    /// bench-compare` exits non-zero on.
    pub fn has_regressions(&self) -> bool {
        self.count(CellClass::Regressed) > 0
    }

    /// Human-readable report (one line per non-Ok cell plus a summary).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# bench-compare {}: {} baseline cell(s) vs {} new cell(s)",
            self.fig,
            self.deltas
                .iter()
                .filter(|d| d.class != CellClass::New)
                .count(),
            self.deltas
                .iter()
                .filter(|d| d.class != CellClass::Missing)
                .count(),
        );
        for diff in &self.fingerprint_diffs {
            let _ = writeln!(out, "warning: fingerprint mismatch: {diff}");
        }
        for diff in &self.label_key_diffs {
            let _ = writeln!(out, "warning: label keys differ: {diff}");
        }
        for d in &self.deltas {
            let tag = match d.class {
                CellClass::Ok => continue,
                CellClass::Regressed => "REGRESSED",
                CellClass::Improved => "improved",
                CellClass::Missing => "missing",
                CellClass::New => "new",
            };
            let _ = write!(out, "{tag:<9} {}", d.id);
            if let (Some(o), Some(n), Some(r)) = (d.old, d.new, d.ratio) {
                let _ = write!(out, "  {o:.3} -> {n:.3} ({r:.2}x)");
            }
            let _ = writeln!(out);
        }
        for d in &self.deltas {
            for note in &d.notes {
                let _ = writeln!(out, "note: {}: {note}", d.id);
            }
        }
        let _ = writeln!(
            out,
            "summary: {} ok, {} regressed, {} improved, {} missing, {} new",
            self.count(CellClass::Ok),
            self.count(CellClass::Regressed),
            self.count(CellClass::Improved),
            self.count(CellClass::Missing),
            self.count(CellClass::New),
        );
        out
    }
}

/// The primary comparable metric of a cell: median throughput when
/// present (higher is better), else p99 latency (lower is better).
fn primary_metric(cell: &CellResult) -> Option<(f64, bool)> {
    if let Some(s) = cell.ops_per_us {
        Some((s.median, true))
    } else {
        cell.latency.map(|l| (l.p99_ns as f64, false))
    }
}

/// Compare `new` against the `old` baseline with the default
/// [`REGRESSION_THRESHOLD`].
pub fn compare(old: &BenchReport, new: &BenchReport) -> Comparison {
    compare_with(old, new, REGRESSION_THRESHOLD)
}

/// Compare with an explicit threshold (fraction, e.g. `0.15`).
pub fn compare_with(
    old: &BenchReport,
    new: &BenchReport,
    threshold: f64,
) -> Comparison {
    let mut deltas = Vec::new();
    let mut matched: Vec<&CellResult> = Vec::new();
    for old_cell in &old.cells {
        let id = old_cell.id();
        let Some(new_cell) = new.cells.iter().find(|c| c.id() == id) else {
            deltas.push(CellDelta {
                id,
                class: CellClass::Missing,
                old: primary_metric(old_cell).map(|(v, _)| v),
                new: None,
                ratio: None,
                notes: Vec::new(),
            });
            continue;
        };
        matched.push(new_cell);
        deltas.push(classify(old_cell, new_cell, threshold));
    }
    for new_cell in &new.cells {
        if !matched.iter().any(|c| std::ptr::eq(*c, new_cell)) {
            deltas.push(CellDelta {
                id: new_cell.id(),
                class: CellClass::New,
                old: None,
                new: primary_metric(new_cell).map(|(v, _)| v),
                ratio: None,
                notes: Vec::new(),
            });
        }
    }
    // When cells fail to match because a sweep *dimension* changed
    // (a label key added or dropped), name the differing key sets —
    // a wall of missing/new ids without this is unreadable.
    let keysets = |r: &BenchReport| -> std::collections::BTreeSet<String> {
        r.cells
            .iter()
            .map(|c| {
                c.labels
                    .iter()
                    .map(|(k, _)| k.as_str())
                    .collect::<Vec<_>>()
                    .join(",")
            })
            .collect()
    };
    let (old_keys, new_keys) = (keysets(old), keysets(new));
    let mut label_key_diffs = Vec::new();
    for ks in old_keys.difference(&new_keys) {
        label_key_diffs.push(format!("[{ks}] only in baseline"));
    }
    for ks in new_keys.difference(&old_keys) {
        label_key_diffs.push(format!("[{ks}] only in new snapshot"));
    }
    Comparison {
        fig: new.fig.clone(),
        fingerprint_diffs: old.fingerprint.diff(&new.fingerprint),
        label_key_diffs,
        deltas,
    }
}

fn classify(
    old: &CellResult,
    new: &CellResult,
    threshold: f64,
) -> CellDelta {
    let id = old.id();
    let mut notes = Vec::new();
    // A p99 move on a throughput cell is worth surfacing even though
    // the gate runs on throughput (tail noise is high; warn, don't fail).
    if let (Some(a), Some(b)) = (old.latency, new.latency) {
        if old.ops_per_us.is_some()
            && a.p99_ns > 0
            && b.p99_ns as f64 > (1.0 + threshold) * a.p99_ns as f64
        {
            notes.push(format!(
                "p99 latency rose {} -> {} ns",
                a.p99_ns, b.p99_ns
            ));
        }
    }
    // Telemetry attribution: when a cell's metrics delta moved beyond
    // the threshold, say which mechanism shifted (probe lengths, K-CAS
    // retries, migration work). Warn-level — the gate stays on the
    // primary metric; this tells the reader *why* it may have moved.
    for (k, o) in &old.metrics {
        let Some(n) = new
            .metrics
            .iter()
            .find(|(nk, _)| nk == k)
            .map(|&(_, v)| v)
        else {
            continue;
        };
        if *o > 0.0 && ((n / o) > 1.0 + threshold || (n / o) < 1.0 - threshold)
        {
            notes.push(format!(
                "metric {k} shifted {o:.3} -> {n:.3} ({:.2}x)",
                n / o
            ));
        }
    }
    let (class, old_v, new_v, ratio) = match (
        primary_metric(old),
        primary_metric(new),
    ) {
        (Some((o, higher_better)), Some((n, _))) if o > 0.0 => {
            let ratio = n / o;
            let (lo, hi) = (1.0 - threshold, 1.0 + threshold);
            let class = if higher_better {
                if ratio < lo {
                    CellClass::Regressed
                } else if ratio > hi {
                    CellClass::Improved
                } else {
                    CellClass::Ok
                }
            } else if ratio > hi {
                CellClass::Regressed
            } else if ratio < lo {
                CellClass::Improved
            } else {
                CellClass::Ok
            };
            (class, Some(o), Some(n), Some(ratio))
        }
        (o, n) => {
            (CellClass::Ok, o.map(|(v, _)| v), n.map(|(v, _)| v), None)
        }
    };
    CellDelta { id, class, old: old_v, new: new_v, ratio, notes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(labels: &[(&str, &str)], ops: f64) -> CellResult {
        CellResult::new(labels.iter().copied())
            .with_ops(Stat::from_samples(&[ops * 0.97, ops, ops * 1.02]))
    }

    fn report(fig: &str, cells: Vec<CellResult>) -> BenchReport {
        let mut r = BenchReport::new(fig, [("size_log2", "14")]);
        r.cells = cells;
        r
    }

    #[test]
    fn stat_aggregates_samples() {
        let s = Stat::from_samples(&[3.0, 1.0, 2.0]);
        assert_eq!(
            s,
            Stat { min: 1.0, median: 2.0, max: 3.0, reps: 3 }
        );
        let even = Stat::from_samples(&[4.0, 1.0]);
        assert_eq!(even.median, 2.5);
        assert_eq!(even.reps, 2);
        assert_eq!(Stat::from_samples(&[7.0]).median, 7.0);
    }

    #[test]
    fn cell_ids_join_labels_in_order() {
        let c = cell(&[("engine", "incremental"), ("threads", "2")], 1.0);
        assert_eq!(c.id(), "engine=incremental/threads=2");
    }

    #[test]
    fn report_round_trips_through_json() {
        let mut r = report(
            "fig15",
            vec![
                cell(&[("engine", "inc\"remental"), ("threads", "2")], 9.5)
                    .with_latency(LatencySummary {
                        p50_ns: 724,
                        p99_ns: 11585,
                        p999_ns: 46341,
                        max_ns: 812345,
                    })
                    .with_extra("grows", 2.0)
                    .with_metrics(vec![
                        ("probe_p99".into(), 6.0),
                        ("kcas_retry_rate".into(), 0.002),
                    ]),
                cell(&[("engine", "quiescing"), ("threads", "2")], 8.25),
            ],
        );
        r.spec.push(("note".into(), "uni\u{00e9}code".into()));
        let parsed = BenchReport::parse(&r.render()).expect("parse");
        assert_eq!(parsed, r);
    }

    #[test]
    fn parse_rejects_foreign_versions_and_garbage() {
        assert!(BenchReport::parse("{}").is_err());
        assert!(BenchReport::parse("not json").is_err());
        let mut r = report("fig15", vec![]);
        let bumped = r
            .render()
            .replace("\"version\": 1", "\"version\": 999");
        assert!(BenchReport::parse(&bumped).is_err());
        // And a well-formed empty report parses.
        r.cells.clear();
        assert!(BenchReport::parse(&r.render()).is_ok());
    }

    #[test]
    fn identical_snapshots_compare_clean() {
        let r = report(
            "fig15",
            vec![cell(&[("t", "1")], 10.0), cell(&[("t", "2")], 17.5)],
        );
        let cmp = compare(&r, &r.clone());
        assert!(!cmp.has_regressions());
        assert_eq!(cmp.count(CellClass::Ok), 2);
        assert!(cmp.fingerprint_diffs.is_empty());
    }

    #[test]
    fn compare_classifies_every_case() {
        let old = report(
            "fig13",
            vec![
                cell(&[("t", "reg")], 10.0),
                cell(&[("t", "imp")], 10.0),
                cell(&[("t", "flat")], 10.0),
                cell(&[("t", "gone")], 10.0),
            ],
        );
        let new = report(
            "fig13",
            vec![
                cell(&[("t", "reg")], 8.0),   // 0.80x < 0.85 -> regressed
                cell(&[("t", "imp")], 12.0),  // 1.20x > 1.15 -> improved
                cell(&[("t", "flat")], 10.5), // within band
                cell(&[("t", "fresh")], 5.0), // only in new
            ],
        );
        let cmp = compare(&old, &new);
        let class_of = |id: &str| {
            cmp.deltas
                .iter()
                .find(|d| d.id == format!("t={id}"))
                .unwrap()
                .class
        };
        assert_eq!(class_of("reg"), CellClass::Regressed);
        assert_eq!(class_of("imp"), CellClass::Improved);
        assert_eq!(class_of("flat"), CellClass::Ok);
        assert_eq!(class_of("gone"), CellClass::Missing);
        assert_eq!(class_of("fresh"), CellClass::New);
        assert!(cmp.has_regressions());
        let text = cmp.render();
        assert!(text.contains("REGRESSED t=reg"), "{text}");
        assert!(text.contains("1 regressed"), "{text}");
        assert!(text.contains("1 missing"), "{text}");
    }

    #[test]
    fn threshold_band_is_exclusive() {
        let old = report("fig13", vec![cell(&[("t", "x")], 100.0)]);
        let edge = report("fig13", vec![cell(&[("t", "x")], 85.5)]);
        assert!(!compare(&old, &edge).has_regressions(), "0.855x is in band");
        let over = report("fig13", vec![cell(&[("t", "x")], 84.0)]);
        assert!(compare(&old, &over).has_regressions(), "0.84x regressed");
    }

    #[test]
    fn latency_only_cells_gate_on_p99_inverted() {
        let lat = |p99: u64| {
            CellResult::new([("t", "l")]).with_latency(LatencySummary {
                p50_ns: 100,
                p99_ns: p99,
                p999_ns: 2 * p99,
                max_ns: 4 * p99,
            })
        };
        let old = report("fig15", vec![lat(1000)]);
        let slower = report("fig15", vec![lat(1300)]);
        let cmp = compare(&old, &slower);
        assert!(cmp.has_regressions(), "p99 +30% must regress");
        let faster = report("fig15", vec![lat(700)]);
        assert_eq!(
            compare(&old, &faster).count(CellClass::Improved),
            1,
            "p99 -30% must improve"
        );
    }

    #[test]
    fn tail_move_on_throughput_cell_is_a_note_not_a_failure() {
        let mk = |p99: u64| {
            report(
                "fig15",
                vec![cell(&[("t", "x")], 10.0).with_latency(
                    LatencySummary {
                        p50_ns: 10,
                        p99_ns: p99,
                        p999_ns: p99 * 2,
                        max_ns: p99 * 4,
                    },
                )],
            )
        };
        let cmp = compare(&mk(1000), &mk(2000));
        assert!(!cmp.has_regressions());
        assert!(cmp.render().contains("p99 latency rose"), "{}", cmp.render());
    }

    #[test]
    fn metric_shift_is_a_note_not_a_failure() {
        let mk = |probe_p99: f64| {
            report(
                "fig15",
                vec![cell(&[("t", "x")], 10.0).with_metrics(vec![(
                    "probe_p99".into(),
                    probe_p99,
                )])],
            )
        };
        // Throughput flat, probe tail doubled: warn, don't fail.
        let cmp = compare(&mk(6.0), &mk(12.0));
        assert!(!cmp.has_regressions());
        let text = cmp.render();
        assert!(
            text.contains("metric probe_p99 shifted 6.000 -> 12.000 (2.00x)"),
            "{text}"
        );
        // Inside the band: silence.
        let quiet = compare(&mk(6.0), &mk(6.5));
        assert!(!quiet.render().contains("metric probe_p99"), "{}",
            quiet.render());
    }

    #[test]
    fn changed_label_keys_are_named() {
        let old = report("fig15", vec![cell(&[("threads", "2")], 10.0)]);
        let new = report(
            "fig15",
            vec![cell(&[("threads", "2"), ("grow_at", "0.7")], 10.0)],
        );
        let cmp = compare(&old, &new);
        let text = cmp.render();
        assert!(
            text.contains("label keys differ: [threads] only in baseline"),
            "{text}"
        );
        assert!(
            text.contains("[threads,grow_at] only in new snapshot"),
            "{text}"
        );
        // Same keys, different values: no key warning.
        let moved = report("fig15", vec![cell(&[("threads", "4")], 10.0)]);
        assert!(compare(&old, &moved).label_key_diffs.is_empty());
    }

    #[test]
    fn fingerprint_records_effective_metrics_gate() {
        let fp = Fingerprint::capture();
        assert!(
            fp.env.iter().any(|(k, _)| k == "CRH_METRICS"),
            "CRH_METRICS missing from {:?}",
            fp.env
        );
    }

    #[test]
    fn fingerprint_mismatch_is_warned() {
        let a = report("fig15", vec![]);
        let mut b = a.clone();
        b.fingerprint.cpu_model = "Other CPU".into();
        b.fingerprint.env.push(("CRH_BENCH_MS".into(), "9".into()));
        let diffs = a.fingerprint.diff(&b.fingerprint);
        assert_eq!(diffs.len(), 2, "{diffs:?}");
        let cmp = compare(&a, &b);
        assert!(cmp.render().contains("fingerprint mismatch"), "{:?}", diffs);
        assert!(!cmp.has_regressions());
    }

    #[test]
    fn snapshot_writes_and_reads_back() {
        let dir = std::env::temp_dir()
            .join(format!("crh_report_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let r = report("fig15", vec![cell(&[("t", "1")], 3.5)]);
        let path = r.write_to(&dir).expect("write");
        assert!(path.ends_with("BENCH_fig15.json"));
        let back = read_snapshot(&path).expect("read");
        assert_eq!(back, r);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_capture_is_populated() {
        let fp = Fingerprint::capture();
        assert!(fp.cpus >= 1);
        assert!(!fp.os.is_empty());
        assert!(fp.env.windows(2).all(|w| w[0].0 <= w[1].0), "env sorted");
    }
}
