//! Timed multithreaded benchmark driver (§4.1).
//!
//! All threads are released through a barrier, run the op mix against
//! the table for a fixed wall-clock duration (the paper measures time,
//! not iterations), and report per-thread op counts. Threads are pinned
//! in paper order (physical cores first, then SMT siblings).
//!
//! The measurement window is **per worker**: each worker opens its
//! clock the moment the barrier releases it and closes it after its
//! own final counted op. A single coordinator-side window (the
//! previous design) both starts late — workers run counted ops before
//! the coordinator's `t0` — and stops early relative to the up-to-63
//! counted tail ops each worker finishes after the stop flag flips, so
//! the reported ops/µs wobbles with scheduler noise. With per-worker
//! windows every counted op lies inside the window that divides it,
//! which is what lets `BENCH_*.json` snapshots gate on the number.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use crate::maps::ConcurrentSet;
use crate::util::affinity;
use crate::util::rng::Rng;

use super::workload::{prefill, Op, WorkloadCfg};

/// Result of one benchmark cell.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub threads: usize,
    pub total_ops: u64,
    /// Longest single worker window (the wall-clock measurement span).
    pub elapsed: Duration,
    pub per_thread: Vec<u64>,
    /// Each worker's own measured window in nanoseconds, opened at its
    /// barrier release and closed after its final counted op.
    pub per_thread_ns: Vec<u64>,
}

impl RunResult {
    /// Assemble a result from per-worker (ops, window) measurements.
    pub fn from_workers(
        per_thread: Vec<u64>,
        per_thread_ns: Vec<u64>,
    ) -> RunResult {
        assert_eq!(per_thread.len(), per_thread_ns.len());
        RunResult {
            threads: per_thread.len(),
            total_ops: per_thread.iter().sum(),
            elapsed: Duration::from_nanos(
                per_thread_ns.iter().copied().max().unwrap_or(0),
            ),
            per_thread,
            per_thread_ns,
        }
    }

    /// The paper's headline unit: operations per microsecond, summed
    /// over each worker's exact rate (`ops_i / window_i`) so no op is
    /// attributed to time it didn't run in.
    pub fn ops_per_us(&self) -> f64 {
        let windowed: f64 = self
            .per_thread
            .iter()
            .zip(&self.per_thread_ns)
            .filter(|&(_, &ns)| ns > 0)
            .map(|(&ops, &ns)| ops as f64 * 1e3 / ns as f64)
            .sum();
        if windowed > 0.0 {
            windowed
        } else {
            self.total_ops as f64 / self.elapsed.as_micros().max(1) as f64
        }
    }
}

/// Prefill `table` and run `threads` workers for the configured
/// duration. `pin` enables core pinning (disable inside tests sharing
/// the machine).
pub fn run_prefilled(
    table: &dyn ConcurrentSet,
    cfg: &WorkloadCfg,
    threads: usize,
    pin: bool,
) -> RunResult {
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let mut slots = vec![(0u64, 0u64); threads];

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (idx, slot) in slots.iter_mut().enumerate() {
            let stop = &stop;
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                if pin {
                    affinity::pin_thread(idx);
                }
                let mut rng = Rng::for_thread(cfg.seed, idx as u64);
                barrier.wait();
                // This worker's window: opens before its first op,
                // closes after its last (including the tail of the
                // final 64-op batch after `stop` flips).
                let t0 = Instant::now();
                let mut ops = 0u64;
                // ORDERING: the stop flag carries no data — workers
                // only need to observe it eventually, and the join
                // below synchronises the measured counts.
                while !stop.load(Ordering::Relaxed) {
                    // Check the stop flag every 64 ops to keep the flag
                    // read off the critical path.
                    for _ in 0..64 {
                        match cfg.draw_op(&mut rng) {
                            Op::Contains(k) => {
                                std::hint::black_box(table.contains(k));
                            }
                            Op::Add(k) => {
                                std::hint::black_box(table.add(k));
                            }
                            Op::Remove(k) => {
                                std::hint::black_box(table.remove(k));
                            }
                        }
                        ops += 1;
                    }
                }
                *slot = (ops, t0.elapsed().as_nanos() as u64);
            }));
        }
        barrier.wait();
        std::thread::sleep(Duration::from_millis(cfg.duration_ms));
        // ORDERING: eventual-visibility stop signal; see the worker
        // loop's load.
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    });

    let (per_thread, per_thread_ns) = slots.into_iter().unzip();
    RunResult::from_workers(per_thread, per_thread_ns)
}

/// Log2-bucketed per-operation latency histogram, cheap enough to
/// update on every op (one increment) — the measurement behind the
/// `fig15_resize` experiment's "tail latency during migration" claim.
#[derive(Clone)]
pub struct LatencyHist {
    /// `buckets[b]` counts ops with latency in `[2^b, 2^(b+1))` ns.
    buckets: [u64; 48],
    count: u64,
    max_ns: u64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { buckets: [0; 48], count: 0, max_ns: 0 }
    }

    #[inline]
    pub fn record(&mut self, ns: u64) {
        let b = if ns == 0 { 0 } else { 63 - ns.leading_zeros() as usize };
        self.buckets[b.min(47)] += 1;
        self.count += 1;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Latency at quantile `q` (0 < q <= 1), reported as the
    /// **geometric midpoint** of the log2 bucket containing the q-th
    /// sample — bucket `[2^b, 2^(b+1))` reports `2^b * sqrt(2)` —
    /// clamped to the observed max. (Reporting the bucket's upper
    /// bound, as this used to, overestimates by up to 2x and makes a
    /// p50 sitting near a bucket edge jump a full power of two between
    /// runs.) 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let mid = ((1u64 << b) as f64 * std::f64::consts::SQRT_2)
                    .round() as u64;
                return mid.min(self.max_ns.max(1));
            }
        }
        self.max_ns
    }
}

/// Configuration for a latency-recording growth cell
/// ([`run_latency`]): unlike [`WorkloadCfg`], the key space is decoupled
/// from the table size and the mix is add-biased, so the run drives the
/// table across its grow threshold mid-measurement.
#[derive(Clone, Copy, Debug)]
pub struct LatencyCfg {
    pub duration_ms: u64,
    /// Keys are uniform over `[1, key_space]` (pick > capacity so adds
    /// keep landing fresh keys and the load factor climbs).
    pub key_space: u64,
    /// Percent of ops that are `add` / `remove` (rest are `contains`).
    pub add_pct: u32,
    pub remove_pct: u32,
    pub seed: u64,
    pub pin: bool,
}

/// Timed run that records **every operation's latency** into a per
/// thread [`LatencyHist`] (merged on return). Same barrier/stop-flag
/// shape as [`run_prefilled`], with the same per-worker measurement
/// windows; the per-op `Instant` pair costs ~50 ns, identical across
/// engines, so relative tails stay comparable.
pub fn run_latency(
    table: &dyn ConcurrentSet,
    cfg: &LatencyCfg,
    threads: usize,
) -> (RunResult, LatencyHist) {
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let mut slots = vec![(0u64, 0u64); threads];
    let mut hists: Vec<LatencyHist> =
        (0..threads).map(|_| LatencyHist::new()).collect();

    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (idx, (slot, hist)) in
            slots.iter_mut().zip(hists.iter_mut()).enumerate()
        {
            let stop = &stop;
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                if cfg.pin {
                    affinity::pin_thread(idx);
                }
                let mut rng = Rng::for_thread(cfg.seed, idx as u64);
                barrier.wait();
                let w0 = Instant::now();
                let mut ops = 0u64;
                // ORDERING: eventual-visibility stop flag, as in
                // run_timed; the join synchronises the results.
                while !stop.load(Ordering::Relaxed) {
                    let key = 1 + rng.below(cfg.key_space);
                    let roll = rng.below(100) as u32;
                    let t0 = Instant::now();
                    if roll < cfg.add_pct {
                        std::hint::black_box(table.add(key));
                    } else if roll < cfg.add_pct + cfg.remove_pct {
                        std::hint::black_box(table.remove(key));
                    } else {
                        std::hint::black_box(table.contains(key));
                    }
                    hist.record(t0.elapsed().as_nanos() as u64);
                    ops += 1;
                }
                *slot = (ops, w0.elapsed().as_nanos() as u64);
            }));
        }
        barrier.wait();
        std::thread::sleep(Duration::from_millis(cfg.duration_ms));
        // ORDERING: eventual-visibility stop signal; see the worker
        // loop's load.
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
    });

    let mut merged = LatencyHist::new();
    for h in &hists {
        merged.merge(h);
    }
    let (per_thread, per_thread_ns) = slots.into_iter().unzip();
    (RunResult::from_workers(per_thread, per_thread_ns), merged)
}

/// Build, prefill, and run one cell (convenience for the CLI/benches).
pub fn run(
    kind: crate::maps::TableKind,
    cfg: &WorkloadCfg,
    threads: usize,
    pin: bool,
) -> RunResult {
    let table = kind.build(cfg.size_log2);
    prefill(table.as_ref(), cfg);
    run_prefilled(table.as_ref(), cfg, threads, pin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::{KeyDist, Mix};
    use crate::maps::TableKind;
    use std::sync::atomic::AtomicU64;

    fn tiny_cfg() -> WorkloadCfg {
        WorkloadCfg {
            size_log2: 12,
            load_factor: 0.4,
            mix: Mix::LIGHT,
            duration_ms: 50,
            seed: 3,
            dist: KeyDist::Uniform,
        }
    }

    #[test]
    fn driver_counts_ops_single_thread() {
        let r = run(TableKind::KCasRobinHood, &tiny_cfg(), 1, false);
        assert_eq!(r.threads, 1);
        assert!(r.total_ops > 1000, "suspiciously slow: {}", r.total_ops);
        assert!(r.ops_per_us() > 0.0);
    }

    #[test]
    fn driver_scales_thread_count() {
        let r = run(TableKind::LockFreeLp, &tiny_cfg(), 4, false);
        assert_eq!(r.per_thread.len(), 4);
        assert!(r.per_thread.iter().all(|&c| c > 0));
    }

    #[test]
    fn driver_runs_sharded_kinds() {
        for kind in [
            TableKind::ShardedKCasRh { shards: 4 },
            TableKind::ShardedResizableRh { shards: 4 },
        ] {
            let r = run(kind, &tiny_cfg(), 2, false);
            assert!(r.total_ops > 0, "{}", kind.name());
            assert_eq!(r.per_thread.len(), 2);
        }
    }

    /// Transparent wrapper that counts every table call, so a test can
    /// check the driver's books against the table's.
    struct CountingSet {
        inner: Box<dyn ConcurrentSet>,
        calls: AtomicU64,
    }

    impl ConcurrentSet for CountingSet {
        fn contains(&self, key: u64) -> bool {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.contains(key)
        }
        fn add(&self, key: u64) -> bool {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.add(key)
        }
        fn remove(&self, key: u64) -> bool {
            self.calls.fetch_add(1, Ordering::Relaxed);
            self.inner.remove(key)
        }
        fn name(&self) -> &'static str {
            "counting"
        }
        fn capacity(&self) -> usize {
            self.inner.capacity()
        }
        fn len_quiesced(&self) -> usize {
            self.inner.len_quiesced()
        }
    }

    #[test]
    fn window_counts_every_recorded_op_exactly_once() {
        let cfg = tiny_cfg();
        let t = CountingSet {
            inner: TableKind::KCasRobinHood.build(cfg.size_log2),
            calls: AtomicU64::new(0),
        };
        prefill(&t, &cfg);
        let before = t.calls.load(Ordering::Relaxed);
        let r = run_prefilled(&t, &cfg, 3, false);
        let measured = t.calls.load(Ordering::Relaxed) - before;
        // Every table call of the measured phase is recorded exactly
        // once — no pre-window ops, no uncounted post-stop tail.
        assert_eq!(r.total_ops, measured);
        assert_eq!(r.per_thread.len(), 3);
        assert_eq!(r.per_thread_ns.len(), 3);
        for (&ops, &ns) in r.per_thread.iter().zip(&r.per_thread_ns) {
            assert!(ops > 0);
            // Each worker's window brackets the whole measured run: it
            // opens at the barrier (before the coordinator's sleep
            // starts) and closes after the worker's own final op.
            assert!(
                ns >= cfg.duration_ms * 1_000_000 * 8 / 10,
                "window {ns} ns shorter than the measured run"
            );
        }
        assert_eq!(
            r.elapsed.as_nanos() as u64,
            *r.per_thread_ns.iter().max().unwrap(),
            "elapsed is the longest worker window"
        );
        assert!(r.ops_per_us() > 0.0);
    }

    #[test]
    fn ops_per_us_sums_exact_per_worker_rates() {
        let r = RunResult::from_workers(
            vec![1_000, 3_000],
            vec![1_000_000, 2_000_000], // 1 ms and 2 ms windows
        );
        // 1000 ops / 1000 µs + 3000 ops / 2000 µs = 1.0 + 1.5.
        assert!((r.ops_per_us() - 2.5).abs() < 1e-9);
        assert_eq!(r.total_ops, 4_000);
        assert_eq!(r.elapsed, Duration::from_millis(2));
    }

    #[test]
    fn latency_hist_quantiles_are_monotonic() {
        let mut h = LatencyHist::new();
        for ns in [10u64, 100, 1000, 10_000, 100_000, 1_000_000] {
            for _ in 0..100 {
                h.record(ns);
            }
        }
        assert_eq!(h.count(), 600);
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        assert!(p99 <= h.max_ns());
        assert!(h.max_ns() == 1_000_000);
        // Pin the geometric midpoints: the 300th sample (1000 ns) sits
        // in bucket [512, 1024) => 512 * sqrt(2) = 724; the 594th
        // (1_000_000 ns) in [524288, 1048576) => 741455.
        assert_eq!(p50, 724);
        assert_eq!(p99, 741_455);
        let mut merged = LatencyHist::new();
        merged.merge(&h);
        merged.merge(&h);
        assert_eq!(merged.count(), 1200);
        assert_eq!(merged.quantile_ns(0.5), p50);
    }

    #[test]
    fn quantile_reports_bucket_midpoint_not_upper_bound() {
        let mut h = LatencyHist::new();
        for _ in 0..100 {
            h.record(1000); // bucket [512, 1024)
        }
        assert_eq!(h.quantile_ns(0.5), 724);
        assert_eq!(h.quantile_ns(0.999), 724);
        assert_ne!(h.quantile_ns(0.5), 1024, "bare upper bound is the bug");
        // The midpoint is clamped to the observed max...
        let mut low = LatencyHist::new();
        low.record(600); // mid 724 > max 600
        assert_eq!(low.quantile_ns(0.5), 600);
        // ...and an empty histogram reports 0.
        assert_eq!(LatencyHist::new().quantile_ns(0.5), 0);
    }

    #[test]
    fn latency_driver_records_every_op() {
        let table = TableKind::IncResizableRh.build(10);
        let cfg = LatencyCfg {
            duration_ms: 50,
            key_space: 4096,
            add_pct: 45,
            remove_pct: 10,
            seed: 9,
            pin: false,
        };
        let (r, hist) = run_latency(table.as_ref(), &cfg, 2);
        assert_eq!(r.per_thread.len(), 2);
        assert_eq!(r.total_ops, hist.count());
        assert!(hist.quantile_ns(0.99) >= hist.quantile_ns(0.5));
        // The latency driver uses the same per-worker windows.
        assert!(r
            .per_thread_ns
            .iter()
            .all(|&ns| ns >= cfg.duration_ms * 1_000_000 * 8 / 10));
    }

    #[test]
    fn load_factor_is_roughly_stationary() {
        // Uniform add/remove drifts any prefill toward the 50% LF
        // equilibrium (same dynamics as the paper's workload), so test
        // stationarity AT the equilibrium point.
        let mut cfg = tiny_cfg();
        cfg.load_factor = 0.5;
        let table = TableKind::KCasRobinHood.build(cfg.size_log2);
        let added = prefill(table.as_ref(), &cfg);
        run_prefilled(table.as_ref(), &cfg, 4, false);
        let n = table.len_quiesced();
        let drift = (n as f64 - added as f64).abs() / added as f64;
        assert!(drift < 0.15, "LF drifted: {added} -> {n}");
    }
}
