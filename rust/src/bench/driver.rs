//! Timed multithreaded benchmark driver (§4.1).
//!
//! All threads are released through a barrier, run the op mix against
//! the table for a fixed wall-clock duration (the paper measures time,
//! not iterations), and report per-thread op counts. Threads are pinned
//! in paper order (physical cores first, then SMT siblings).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Barrier;
use std::time::{Duration, Instant};

use crate::maps::ConcurrentSet;
use crate::util::affinity;
use crate::util::rng::Rng;

use super::workload::{prefill, Op, WorkloadCfg};

/// Result of one benchmark cell.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub threads: usize,
    pub total_ops: u64,
    pub elapsed: Duration,
    pub per_thread: Vec<u64>,
}

impl RunResult {
    /// The paper's headline unit: operations per microsecond.
    pub fn ops_per_us(&self) -> f64 {
        self.total_ops as f64 / self.elapsed.as_micros().max(1) as f64
    }
}

/// Prefill `table` and run `threads` workers for the configured
/// duration. `pin` enables core pinning (disable inside tests sharing
/// the machine).
pub fn run_prefilled(
    table: &dyn ConcurrentSet,
    cfg: &WorkloadCfg,
    threads: usize,
    pin: bool,
) -> RunResult {
    let stop = AtomicBool::new(false);
    let barrier = Barrier::new(threads + 1);
    let mut per_thread = vec![0u64; threads];

    let elapsed = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (idx, slot) in per_thread.iter_mut().enumerate() {
            let stop = &stop;
            let barrier = &barrier;
            handles.push(s.spawn(move || {
                if pin {
                    affinity::pin_thread(idx);
                }
                let mut rng = Rng::for_thread(cfg.seed, idx as u64);
                barrier.wait();
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // Check the stop flag every 64 ops to keep the flag
                    // read off the critical path.
                    for _ in 0..64 {
                        match cfg.draw_op(&mut rng) {
                            Op::Contains(k) => {
                                std::hint::black_box(table.contains(k));
                            }
                            Op::Add(k) => {
                                std::hint::black_box(table.add(k));
                            }
                            Op::Remove(k) => {
                                std::hint::black_box(table.remove(k));
                            }
                        }
                        ops += 1;
                    }
                }
                *slot = ops;
            }));
        }
        barrier.wait();
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(cfg.duration_ms));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        t0.elapsed()
    });

    RunResult {
        threads,
        total_ops: per_thread.iter().sum(),
        elapsed,
        per_thread,
    }
}

/// Build, prefill, and run one cell (convenience for the CLI/benches).
pub fn run(
    kind: crate::maps::TableKind,
    cfg: &WorkloadCfg,
    threads: usize,
    pin: bool,
) -> RunResult {
    let table = kind.build(cfg.size_log2);
    prefill(table.as_ref(), cfg);
    run_prefilled(table.as_ref(), cfg, threads, pin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::{KeyDist, Mix};
    use crate::maps::TableKind;

    fn tiny_cfg() -> WorkloadCfg {
        WorkloadCfg {
            size_log2: 12,
            load_factor: 0.4,
            mix: Mix::LIGHT,
            duration_ms: 50,
            seed: 3,
            dist: KeyDist::Uniform,
        }
    }

    #[test]
    fn driver_counts_ops_single_thread() {
        let r = run(TableKind::KCasRobinHood, &tiny_cfg(), 1, false);
        assert_eq!(r.threads, 1);
        assert!(r.total_ops > 1000, "suspiciously slow: {}", r.total_ops);
        assert!(r.ops_per_us() > 0.0);
    }

    #[test]
    fn driver_scales_thread_count() {
        let r = run(TableKind::LockFreeLp, &tiny_cfg(), 4, false);
        assert_eq!(r.per_thread.len(), 4);
        assert!(r.per_thread.iter().all(|&c| c > 0));
    }

    #[test]
    fn driver_runs_sharded_kinds() {
        for kind in [
            TableKind::ShardedKCasRh { shards: 4 },
            TableKind::ShardedResizableRh { shards: 4 },
        ] {
            let r = run(kind, &tiny_cfg(), 2, false);
            assert!(r.total_ops > 0, "{}", kind.name());
            assert_eq!(r.per_thread.len(), 2);
        }
    }

    #[test]
    fn load_factor_is_roughly_stationary() {
        // Uniform add/remove drifts any prefill toward the 50% LF
        // equilibrium (same dynamics as the paper's workload), so test
        // stationarity AT the equilibrium point.
        let mut cfg = tiny_cfg();
        cfg.load_factor = 0.5;
        let table = TableKind::KCasRobinHood.build(cfg.size_log2);
        let added = prefill(table.as_ref(), &cfg);
        run_prefilled(table.as_ref(), &cfg, 4, false);
        let n = table.len_quiesced();
        let drift = (n as f64 - added as f64).abs() / added as f64;
        assert!(drift < 0.15, "LF drifted: {added} -> {n}");
    }
}
