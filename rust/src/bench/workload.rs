//! Workload generation per the paper's §4.1.
//!
//! "Each thread calls a random method with a random argument from some
//! predefined method and key distribution. ... The key space was equal
//! to the size of the table, and was filled to the specified load
//! factors."
//!
//! Update rate `u` splits evenly between `add` and `remove` (u/2 each),
//! the remainder are `contains` — keeping the load factor stationary
//! around its prefill value.

use crate::maps::ConcurrentSet;
use crate::util::rng::Rng;

/// One benchmark operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Contains(u64),
    Add(u64),
    Remove(u64),
}

/// Method mix (probabilities in percent).
#[derive(Clone, Copy, Debug)]
pub struct Mix {
    /// Percentage of mutating operations (split add/remove evenly).
    pub update_pct: u32,
}

impl Mix {
    pub const LIGHT: Mix = Mix { update_pct: 10 };
    pub const HEAVY: Mix = Mix { update_pct: 20 };

    /// Draw one op. Keys are uniform over `[1, key_space]`.
    #[inline]
    pub fn draw(&self, rng: &mut Rng, key_space: u64) -> Op {
        let key = 1 + rng.below(key_space);
        let roll = rng.below(100) as u32;
        if roll < self.update_pct / 2 {
            Op::Add(key)
        } else if roll < self.update_pct {
            Op::Remove(key)
        } else {
            Op::Contains(key)
        }
    }
}

/// Key distribution. The paper uses uniform keys; Zipfian skew is an
/// evaluation extension (hot keys concentrate contention on a few
/// timestamp shards / lock segments, stressing the retry paths).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KeyDist {
    Uniform,
    /// Zipf(~1) approximated by inverse-CDF `rank = N^u`, decorrelated
    /// from table order by mixing the rank.
    Zipf,
}

impl KeyDist {
    #[inline]
    pub fn draw(&self, rng: &mut Rng, key_space: u64) -> u64 {
        match self {
            KeyDist::Uniform => 1 + rng.below(key_space),
            KeyDist::Zipf => {
                let u = rng.f64().max(1e-12);
                let rank = (key_space as f64).powf(u) as u64;
                // Spread ranks over the key space so hot keys don't
                // share table neighborhoods artificially.
                1 + crate::util::hash::splitmix64(rank) % key_space
            }
        }
    }
}

/// Full workload configuration for one benchmark cell.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadCfg {
    /// Table has `1 << size_log2` buckets; key space equals table size.
    pub size_log2: u32,
    /// Prefill fraction (0.2 / 0.4 / 0.6 / 0.8 in the paper).
    pub load_factor: f64,
    pub mix: Mix,
    /// Measured run length.
    pub duration_ms: u64,
    pub seed: u64,
    /// Key distribution (paper: uniform).
    pub dist: KeyDist,
}

impl WorkloadCfg {
    /// Convenience constructor for a single uniform-key cell (the shape
    /// every coordinator experiment builds).
    pub fn cell(
        size_log2: u32,
        load_factor: f64,
        update_pct: u32,
        duration_ms: u64,
        seed: u64,
    ) -> WorkloadCfg {
        WorkloadCfg {
            size_log2,
            load_factor,
            mix: Mix { update_pct },
            duration_ms,
            seed,
            dist: KeyDist::Uniform,
        }
    }

    pub fn key_space(&self) -> u64 {
        1u64 << self.size_log2
    }

    pub fn prefill_count(&self) -> usize {
        ((1usize << self.size_log2) as f64 * self.load_factor) as usize
    }

    /// Paper's 8 configurations at a given table size.
    pub fn paper_grid(size_log2: u32, duration_ms: u64) -> Vec<WorkloadCfg> {
        let mut v = Vec::new();
        for &lf in &[0.2, 0.4, 0.6, 0.8] {
            for &mix in &[Mix::LIGHT, Mix::HEAVY] {
                v.push(WorkloadCfg::cell(
                    size_log2,
                    lf,
                    mix.update_pct,
                    duration_ms,
                    0xFEED,
                ));
            }
        }
        v
    }

    /// Draw one op with this config's key distribution.
    #[inline]
    pub fn draw_op(&self, rng: &mut Rng) -> Op {
        let key = self.dist.draw(rng, self.key_space());
        let roll = rng.below(100) as u32;
        if roll < self.mix.update_pct / 2 {
            Op::Add(key)
        } else if roll < self.mix.update_pct {
            Op::Remove(key)
        } else {
            Op::Contains(key)
        }
    }

    pub fn label(&self) -> String {
        format!(
            "{}% w/ {}%",
            (self.load_factor * 100.0) as u32,
            self.mix.update_pct
        )
    }
}

/// Prefill `table` to the configured load factor with a deterministic
/// pseudo-random subset of the key space (uniformly spread, like the
/// paper's random fill).
pub fn prefill(table: &dyn ConcurrentSet, cfg: &WorkloadCfg) -> usize {
    let n = cfg.prefill_count();
    let space = cfg.key_space();
    let mut rng = Rng::new(cfg.seed ^ 0xDEAD_BEEF);
    let mut added = 0;
    while added < n {
        let key = 1 + rng.below(space);
        if table.add(key) {
            added += 1;
        }
    }
    added
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::TableKind;

    #[test]
    fn mix_ratios_roughly_match() {
        let mix = Mix::HEAVY;
        let mut rng = Rng::new(1);
        let (mut a, mut r, mut c) = (0, 0, 0);
        for _ in 0..100_000 {
            match mix.draw(&mut rng, 1000) {
                Op::Add(_) => a += 1,
                Op::Remove(_) => r += 1,
                Op::Contains(_) => c += 1,
            }
        }
        assert!((9_000..11_000).contains(&a), "adds {a}");
        assert!((9_000..11_000).contains(&r), "removes {r}");
        assert!((78_000..82_000).contains(&c), "contains {c}");
    }

    #[test]
    fn draw_keys_in_range() {
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let op = Mix::LIGHT.draw(&mut rng, 64);
            let k = match op {
                Op::Add(k) | Op::Remove(k) | Op::Contains(k) => k,
            };
            assert!((1..=64).contains(&k));
        }
    }

    #[test]
    fn prefill_reaches_load_factor() {
        let cfg = WorkloadCfg {
            size_log2: 10,
            load_factor: 0.6,
            mix: Mix::LIGHT,
            duration_ms: 0,
            seed: 7,
            dist: KeyDist::Uniform,
        };
        let t = TableKind::KCasRobinHood.build(cfg.size_log2);
        let added = prefill(t.as_ref(), &cfg);
        assert_eq!(added, (1024.0 * 0.6) as usize);
        assert_eq!(t.len_quiesced(), added);
    }

    #[test]
    fn paper_grid_has_8_cells() {
        let g = WorkloadCfg::paper_grid(10, 100);
        assert_eq!(g.len(), 8);
        assert_eq!(g[0].label(), "20% w/ 10%");
        assert_eq!(g[7].label(), "80% w/ 20%");
    }

    #[test]
    fn cell_constructor_matches_fields() {
        let c = WorkloadCfg::cell(12, 0.6, 10, 250, 7);
        assert_eq!(c.size_log2, 12);
        assert_eq!(c.mix.update_pct, 10);
        assert_eq!(c.duration_ms, 250);
        assert_eq!(c.seed, 7);
        assert_eq!(c.dist, KeyDist::Uniform);
        assert_eq!(c.prefill_count(), (4096.0 * 0.6) as usize);
    }

    #[test]
    fn prefill_works_through_the_sharded_facade() {
        let cfg = WorkloadCfg::cell(10, 0.6, 10, 0, 7);
        let t = TableKind::ShardedKCasRh { shards: 4 }.build(cfg.size_log2);
        let added = prefill(t.as_ref(), &cfg);
        assert_eq!(added, (1024.0 * 0.6) as usize);
        assert_eq!(t.len_quiesced(), added);
    }
}

#[cfg(test)]
mod dist_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zipf_is_skewed_uniform_is_not() {
        let mut rng = Rng::new(5);
        let n = 1u64 << 16;
        let mut count = |d: KeyDist| {
            let mut freq = std::collections::HashMap::new();
            for _ in 0..50_000 {
                *freq.entry(d.draw(&mut rng, n)).or_insert(0u64) += 1;
            }
            let mut c: Vec<u64> = freq.into_values().collect();
            c.sort_unstable_by(|a, b| b.cmp(a));
            c[0]
        };
        let hot_zipf = count(KeyDist::Zipf);
        let hot_uni = count(KeyDist::Uniform);
        assert!(
            hot_zipf > 20 * hot_uni.max(1),
            "zipf hottest {hot_zipf} vs uniform {hot_uni}"
        );
    }

    #[test]
    fn zipf_keys_in_range() {
        let mut rng = Rng::new(6);
        for _ in 0..10_000 {
            let k = KeyDist::Zipf.draw(&mut rng, 1024);
            assert!((1..=1024).contains(&k));
        }
    }
}
