//! Benchmark harness — the paper's §4.1 methodology.
//!
//! * [`workload`] — key-space/prefill/op-mix generation (load factors
//!   20/40/60/80%, update rates 10% "light" / 20% "heavy").
//! * [`driver`] — barrier-synchronised, pinned, timed multithreaded
//!   runs counting per-thread operations, reported as ops/µs.
//! * [`report`] — the perf-trajectory layer: typed per-cell results,
//!   machine-fingerprinted `BENCH_<fig>.json` snapshots
//!   (`CRH_BENCH_JSON=1` / `--json`), and the >15%-regression compare
//!   mode behind `crh bench-compare`.

pub mod driver;
pub mod report;
pub mod workload;

pub use driver::{run, RunResult};
pub use report::{BenchReport, CellResult};
pub use workload::{Mix, WorkloadCfg};
