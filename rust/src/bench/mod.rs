//! Benchmark harness — the paper's §4.1 methodology.
//!
//! * [`workload`] — key-space/prefill/op-mix generation (load factors
//!   20/40/60/80%, update rates 10% "light" / 20% "heavy").
//! * [`driver`] — barrier-synchronised, pinned, timed multithreaded
//!   runs counting per-thread operations, reported as ops/µs.

pub mod driver;
pub mod workload;

pub use driver::{run, RunResult};
pub use workload::{Mix, WorkloadCfg};
