//! Minimal JSON value model, writer, and parser (std only).
//!
//! The perf-trajectory snapshots (`bench::report`) need structured,
//! machine-readable output, and the crate's zero-dependency policy
//! rules out serde — so this is the small, strict subset of JSON the
//! snapshots use: full string escaping (including `\uXXXX` with
//! surrogate pairs), finite f64 numbers, arrays, and
//! insertion-ordered objects (so written snapshots diff cleanly
//! run-over-run). The parser is defensive enough to read foreign
//! `BENCH_*.json` files: it reports byte offsets on errors and caps
//! nesting depth instead of overflowing the stack.

use std::fmt;

/// Maximum nesting depth the parser accepts (the snapshots use 4).
const MAX_DEPTH: usize = 64;

/// A JSON value. Object keys keep insertion order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse failure: the byte offset where parsing stopped plus a message.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl Json {
    /// Shorthand for building an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Render as pretty-printed JSON (2-space indent, `\n` separators).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    /// Render as a single line with no whitespace — for line-oriented
    /// consumers (the `STATS` wire reply must be exactly one line).
    pub fn render_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) if items.is_empty() => out.push_str("[]"),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent + 1);
                    item.write(out, indent + 1);
                }
                newline(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if pairs.is_empty() => out.push_str("{}"),
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                newline(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { text, bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after the document"));
        }
        Ok(v)
    }
}

fn newline(out: &mut String, indent: usize) {
    out.push('\n');
    for _ in 0..indent {
        out.push_str("  ");
    }
}

/// JSON has no NaN/Infinity; map them to `null` rather than emitting an
/// unparseable token. Integral values print without a fraction so
/// counts stay greppable.
fn write_num(out: &mut String, v: f64) {
    use std::fmt::Write as _;
    if !v.is_finite() {
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        // `{}` on f64 is the shortest representation that round-trips.
        let _ = write!(out, "{v}");
    }
}

fn write_str(out: &mut String, s: &str) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(Json::Num(v)),
            _ => Err(self.err(&format!("invalid number {text:?}"))),
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{08}'),
                        b'f' => out.push('\u{0c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: a second \uXXXX must
                                // follow with the low half.
                                if self.peek() != Some(b'\\') {
                                    return Err(
                                        self.err("lone high surrogate")
                                    );
                                }
                                self.pos += 1;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(
                                        self.err("invalid low surrogate")
                                    );
                                }
                                0x10000
                                    + ((hi - 0xD800) << 10)
                                    + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code).ok_or_else(|| {
                                    self.err("invalid unicode escape")
                                })?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(c) if c < 0x20 => {
                    return Err(self.err("raw control character in string"));
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (input is &str and `pos`
                    // only ever lands on char boundaries).
                    let c = self.text[self.pos..].chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Json) -> Json {
        Json::parse(&v.render()).expect("round-trip parse")
    }

    #[test]
    fn renders_and_parses_scalars() {
        for v in [
            Json::Null,
            Json::Bool(true),
            Json::Bool(false),
            Json::Num(0.0),
            Json::Num(-17.0),
            Json::Num(3.25),
            Json::Num(1.0e-9),
            Json::Num(123456789012345.0),
            Json::Str(String::new()),
            Json::Str("plain".into()),
        ] {
            assert_eq!(roundtrip(&v), v, "{v:?}");
        }
    }

    #[test]
    fn escapes_round_trip() {
        let tricky = "quote\" back\\slash nl\n tab\t cr\r nul\u{1} \
                      unicode \u{00e9}\u{1F600} bell\u{07}";
        let v = Json::Str(tricky.to_string());
        assert_eq!(roundtrip(&v), v);
        // The rendered form must stay ASCII-safe for the control chars.
        let text = v.render();
        assert!(text.contains("\\n"), "{text}");
        assert!(text.contains("\\u0001"), "{text}");
    }

    #[test]
    fn parses_unicode_escapes_and_surrogates() {
        assert_eq!(
            Json::parse(r#""é 😀 \/""#).unwrap(),
            Json::Str("\u{00e9} \u{1F600} /".into())
        );
        assert!(Json::parse(r#""\ud83d oops""#).is_err());
        assert!(Json::parse(r#""\ud83d ""#).is_err());
    }

    #[test]
    fn nested_structures_round_trip() {
        let v = Json::obj(vec![
            ("fig", Json::Str("fig15".into())),
            (
                "cells",
                Json::Arr(vec![
                    Json::obj(vec![
                        ("id", Json::Str("a=1/b=2".into())),
                        ("ops", Json::Num(12.75)),
                        ("empty_arr", Json::Arr(vec![])),
                        ("empty_obj", Json::Obj(vec![])),
                    ]),
                    Json::Null,
                ]),
            ),
            ("ok", Json::Bool(true)),
        ]);
        assert_eq!(roundtrip(&v), v);
    }

    #[test]
    fn compact_render_is_one_line_and_round_trips() {
        let v = Json::obj(vec![
            ("s", Json::Str("a\nb".into())),
            ("n", Json::Num(1.5)),
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Null])),
            ("o", Json::obj(vec![("k", Json::Bool(true))])),
            ("e", Json::Obj(vec![])),
        ]);
        let line = v.render_compact();
        assert!(!line.contains('\n'), "{line}");
        assert!(!line.contains(": "), "{line}");
        assert_eq!(
            line,
            r#"{"s":"a\nb","n":1.5,"a":[1,null],"o":{"k":true},"e":{}}"#
        );
        assert_eq!(Json::parse(&line).unwrap(), v);
    }

    #[test]
    fn preserves_object_order() {
        let text = r#"{"z": 1, "a": 2, "m": 3}"#;
        let v = Json::parse(text).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn floats_round_trip_exactly() {
        for x in [0.1, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -2.5e-7] {
            let v = roundtrip(&Json::Num(x));
            assert_eq!(v.as_f64(), Some(x), "{x}");
        }
    }

    #[test]
    fn non_finite_numbers_render_as_null() {
        assert_eq!(Json::Num(f64::NAN).render().trim(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render().trim(), "null");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "[1 2]", "{\"a\" 1}", "{\"a\": }", "tru",
            "nul", "\"unterminated", "1.2.3", "[1]]", "{} {}", "nan",
            "'single'", "[\u{01}]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
        assert!(e.to_string().contains("byte 4"));
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "a": [1], "f": 1.5}"#)
            .unwrap();
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(3));
        assert_eq!(v.get("f").and_then(Json::as_u64), None);
        assert_eq!(v.get("f").and_then(Json::as_f64), Some(1.5));
        assert_eq!(v.get("s").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("a").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
        assert_eq!(v.get("missing"), None);
    }
}
