//! Shared substrate: hashing, RNG, thread pinning, property testing,
//! the Linux readiness syscalls behind the epoll front-end
//! ([`sys`], `target_os = "linux"` only), a dependency-free JSON
//! writer/parser ([`json`], the substrate of the `BENCH_*.json`
//! perf-trajectory snapshots), the always-on telemetry plane
//! ([`metrics`]: sharded counters + log-histograms behind the `STATS`
//! wire verb and per-cell snapshot metrics), plus the offline-build
//! shims (cache-line padding, error plumbing) that keep the crate free
//! of external dependencies.

pub mod affinity;
pub mod error;
pub mod hash;
pub mod json;
pub mod linearize;
pub mod metrics;
pub mod pad;
pub mod prop;
pub mod rng;
#[cfg(target_os = "linux")]
pub mod sys;
