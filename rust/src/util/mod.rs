//! Shared substrate: hashing, RNG, thread pinning, property testing,
//! the Linux readiness syscalls behind the epoll front-end
//! ([`sys`], `target_os = "linux"` only), plus the offline-build shims
//! (cache-line padding, error plumbing) that keep the crate free of
//! external dependencies.

pub mod affinity;
pub mod error;
pub mod hash;
pub mod linearize;
pub mod pad;
pub mod prop;
pub mod rng;
#[cfg(target_os = "linux")]
pub mod sys;
