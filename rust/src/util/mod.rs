//! Shared substrate: hashing, RNG, thread pinning, property testing.

pub mod affinity;
pub mod hash;
pub mod linearize;
pub mod prop;
pub mod rng;
