//! SplitMix64 hash — bit-identical to the L1 Pallas kernel
//! (`python/compile/kernels/hashmix.py`).
//!
//! Every table in this crate hashes keys through [`splitmix64`]; the
//! benchmark harness pre-hashes key streams through the AOT-compiled
//! HLO artifact, and `rust/tests/runtime_integration.rs` asserts the two
//! paths agree bit-for-bit on the golden vectors emitted by `aot.py`.

/// Golden-gamma increment (Steele et al.).
pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;
/// First finalizer multiplier.
pub const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
/// Second finalizer multiplier.
pub const MIX2: u64 = 0x94D0_49BB_1331_11EB;

/// SplitMix64: gamma add + 3 xor-shift-multiply rounds. Bijective on u64.
#[inline(always)]
pub fn splitmix64(key: u64) -> u64 {
    let mut z = key.wrapping_add(GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(MIX1);
    z = (z ^ (z >> 27)).wrapping_mul(MIX2);
    z ^ (z >> 31)
}

/// Home bucket for a key in a power-of-two table: `hash & (size-1)`.
#[inline(always)]
pub fn home_bucket(key: u64, mask: u64) -> usize {
    (splitmix64(key) & mask) as usize
}

/// Distance-From-home-Bucket of an entry observed at index `i`
/// (paper's `calc_dist`), accounting for wraparound.
#[inline(always)]
pub fn dfb(home: usize, i: usize, mask: u64) -> u64 {
    (i.wrapping_sub(home) as u64) & mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector_matches_published_splitmix64() {
        // First output of Vigna's reference splitmix64 with seed 0.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn golden_vectors_match_python_reference() {
        // A few pairs lifted from `aot.golden_vectors` semantics:
        // splitmix64 of the int64 two's-complement bit pattern.
        assert_eq!(splitmix64(1), {
            let mut z = 1u64.wrapping_add(GAMMA);
            z = (z ^ (z >> 30)).wrapping_mul(MIX1);
            z = (z ^ (z >> 27)).wrapping_mul(MIX2);
            z ^ (z >> 31)
        });
        // -1 as u64.
        let _ = splitmix64(u64::MAX);
    }

    #[test]
    fn bijective_on_sample() {
        let mut seen = std::collections::HashSet::new();
        for k in 0..1u64 << 16 {
            assert!(seen.insert(splitmix64(k)));
        }
    }

    #[test]
    fn dfb_wraparound() {
        let mask = 15;
        assert_eq!(dfb(14, 1, mask), 3); // 14 -> 15 -> 0 -> 1
        assert_eq!(dfb(3, 3, mask), 0);
        assert_eq!(dfb(0, 15, mask), 15);
    }

    #[test]
    fn home_bucket_in_range() {
        let mask = (1u64 << 10) - 1;
        for k in 0..10_000u64 {
            assert!(home_bucket(k, mask) < 1 << 10);
        }
    }

    #[test]
    fn avalanche_quality() {
        // Flipping one input bit flips ~32 output bits on average.
        let mut total = 0u32;
        let n = 512u64;
        for k in 0..n {
            let a = splitmix64(k.wrapping_mul(0x9E37_79B9));
            let b = splitmix64(k.wrapping_mul(0x9E37_79B9) ^ (1 << 17));
            total += (a ^ b).count_ones();
        }
        let avg = total as f64 / n as f64;
        assert!(avg > 24.0 && avg < 40.0, "avalanche {avg}");
    }
}
