//! Minimal Linux kernel-API surface for the event-driven front-ends:
//! raw `epoll_create1` / `epoll_ctl` / `epoll_wait` / `eventfd`
//! bindings plus RAII fd wrappers (`service::reactor`), and raw
//! `io_uring_setup` / `io_uring_enter` with mmap'd submission and
//! completion rings (`service::uring`).
//!
//! Follows the `util::affinity` precedent: the `libc` crate is not
//! available in this offline build, but Rust's std already links the C
//! library on Linux, so declaring the symbols is all that is needed.
//! Errors are surfaced through `std::io::Error::last_os_error()`, which
//! reads the thread's errno the same way std's own syscall wrappers do.
//! The io_uring entry points have no libc wrappers at all on older
//! distributions, so those two go through `syscall(2)` with the
//! asm-generic numbers (425/426 — identical on x86-64 and aarch64,
//! both of which postdate the unified syscall table).
//!
//! Only what the front-ends need is bound — level-triggered readiness
//! on sockets, an eventfd wake token for cross-thread handoff and
//! graceful shutdown, the [`Uring`] submission/completion ring pair,
//! and pre-bind `SO_REUSEPORT` listener construction
//! ([`bind_reuseport`]) so each server worker can accept its own
//! connections with no hand-off hop. This module is
//! `target_os = "linux"` only; the event-driven backends fall back to
//! portable siblings elsewhere.

use std::io;
use std::os::fd::RawFd;

use crate::util::metrics::metrics;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its write half (half-close detection without a read).
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

/// `EPOLL_CLOEXEC` / `EFD_CLOEXEC` (== `O_CLOEXEC`).
const CLOEXEC: i32 = 0o2000000;
/// `EFD_NONBLOCK` (== `O_NONBLOCK`).
const EFD_NONBLOCK: i32 = 0o4000;

/// Mirror of the kernel's `struct epoll_event`. On x86-64 the kernel
/// ABI packs it (no padding between `events` and `data`); other
/// architectures use natural alignment.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token identifying the registered fd.
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> Self {
        EpollEvent { events: 0, data: 0 }
    }
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(
        epfd: i32,
        events: *mut EpollEvent,
        maxevents: i32,
        timeout_ms: i32,
    ) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
    fn setsockopt(
        fd: i32,
        level: i32,
        optname: i32,
        optval: *const u8,
        optlen: u32,
    ) -> i32;
}

const SOL_SOCKET: i32 = 1;
const SO_RCVBUF: i32 = 8;
const SO_SNDBUF: i32 = 7;

fn set_buf_opt(fd: RawFd, opt: i32, bytes: i32) -> io::Result<()> {
    let val = bytes.to_ne_bytes();
    // SAFETY: `val` is live for the whole call and `optlen` matches its
    // size; the kernel copies the option value and keeps no pointer.
    cvt(unsafe {
        setsockopt(fd, SOL_SOCKET, opt, val.as_ptr(), val.len() as u32)
    })
    .map(|_| ())
}

/// Shrink (or grow) a socket's kernel receive buffer — the
/// backpressure tests use a tiny one to force the peer's replies to
/// back up into its user-space buffer deterministically.
pub fn set_recv_buffer(fd: RawFd, bytes: i32) -> io::Result<()> {
    set_buf_opt(fd, SO_RCVBUF, bytes)
}

/// Shrink (or grow) a socket's kernel send buffer.
pub fn set_send_buffer(fd: RawFd, bytes: i32) -> io::Result<()> {
    set_buf_opt(fd, SO_SNDBUF, bytes)
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An epoll instance (closed on drop).
pub struct EpollFd(RawFd);

impl EpollFd {
    pub fn new() -> io::Result<EpollFd> {
        // SAFETY: no pointers cross the boundary; `cvt` validates the
        // returned fd.
        cvt(unsafe { epoll_create1(CLOEXEC) }).map(EpollFd)
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        metrics().syscalls_epoll.incr();
        // SAFETY: `ev` is a live, correctly-laid-out epoll_event for
        // the duration of the call; the kernel copies it out.
        cvt(unsafe { epoll_ctl(self.0, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with interest `events`, reporting `token`.
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change the interest set of a registered fd.
    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Deregister `fd` (closing the fd also deregisters it implicitly;
    /// this exists for fds that outlive their registration).
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, filling `events` from the front; returns how
    /// many entries are valid. `timeout_ms < 0` blocks indefinitely;
    /// `0` polls. Retries `EINTR` internally.
    pub fn wait(
        &self,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> io::Result<usize> {
        loop {
            metrics().syscalls_epoll.incr();
            // SAFETY: `events` is writable for `events.len()` entries
            // and `maxevents` is clamped to that length, so the kernel
            // stays in bounds.
            let n = unsafe {
                epoll_wait(
                    self.0,
                    events.as_mut_ptr(),
                    events.len().min(i32::MAX as usize) as i32,
                    timeout_ms,
                )
            };
            match cvt(n) {
                Ok(n) => return Ok(n as usize),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for EpollFd {
    fn drop(&mut self) {
        // SAFETY: self.0 is an fd this wrapper owns exclusively; it is
        // closed exactly once, here.
        unsafe { close(self.0) };
    }
}

/// A nonblocking eventfd wake token (closed on drop): `signal` from any
/// thread, register `fd()` in an epoll set, `drain` on wake-up.
pub struct EventFd(RawFd);

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        // SAFETY: no pointers cross the boundary; `cvt` validates the
        // returned fd.
        cvt(unsafe { eventfd(0, CLOEXEC | EFD_NONBLOCK) }).map(EventFd)
    }

    pub fn fd(&self) -> RawFd {
        self.0
    }

    /// Make the fd readable (wake any epoll waiter). A full counter
    /// (`EAGAIN`) already means "signalled", so that error is ignored.
    pub fn signal(&self) {
        let one = 1u64.to_ne_bytes();
        // SAFETY: `one` is live and valid for the 8 bytes written.
        unsafe { write(self.0, one.as_ptr(), one.len()) };
    }

    /// Consume all pending signals so level-triggered polling quiesces.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        // SAFETY: `buf` is writable for its full length and the read is
        // bounded by `buf.len()`.
        while unsafe { read(self.0, buf.as_mut_ptr(), buf.len()) } > 0 {}
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        // SAFETY: self.0 is an fd this wrapper owns exclusively; it is
        // closed exactly once, here.
        unsafe { close(self.0) };
    }
}

// SAFETY: EpollFd is just an owned RawFd; epoll operations are
// serialised by the kernel and the wrapper adds no interior state.
unsafe impl Send for EpollFd {}
// SAFETY: every method takes &self and maps to a single thread-safe
// syscall on the kernel side.
unsafe impl Sync for EpollFd {}
// SAFETY: EventFd is just an owned RawFd; eventfd reads and writes
// are atomic kernel operations.
unsafe impl Send for EventFd {}
// SAFETY: `signal`/`drain` are &self and kernel-atomic; concurrent
// callers at worst coalesce wake-ups, which is the intended
// semantics of an eventfd counter.
unsafe impl Sync for EventFd {}

// ------------------------------------------------- SO_REUSEPORT bind

const AF_INET: i32 = 2;
const SOCK_STREAM: i32 = 1;
/// `SOCK_CLOEXEC` (== `O_CLOEXEC`).
const SOCK_CLOEXEC: i32 = 0o2000000;
const SO_REUSEADDR: i32 = 2;
const SO_REUSEPORT: i32 = 15;
const LISTEN_BACKLOG: i32 = 1024;

/// Mirror of the kernel's `struct sockaddr_in` (IPv4 only — the
/// front-ends bind v4 addresses; a v6 bind request falls back to the
/// single-listener path at the call site).
#[repr(C)]
struct SockAddrIn {
    family: u16,
    /// Big-endian.
    port: u16,
    /// Big-endian.
    addr: u32,
    zero: [u8; 8],
}

extern "C" {
    fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
    fn bind(fd: i32, addr: *const SockAddrIn, len: u32) -> i32;
    fn listen(fd: i32, backlog: i32) -> i32;
}

fn set_int_opt(fd: RawFd, opt: i32, val: i32) -> io::Result<()> {
    let bytes = val.to_ne_bytes();
    // SAFETY: `bytes` is live for the whole call and `optlen` matches
    // its size; the kernel copies the option value out.
    cvt(unsafe {
        setsockopt(fd, SOL_SOCKET, opt, bytes.as_ptr(), bytes.len() as u32)
    })
    .map(|_| ())
}

/// Bind a TCP listener with `SO_REUSEPORT` set **before** `bind` — the
/// ordering the kernel requires for reuseport groups, which std's
/// `TcpListener::bind` cannot express. Every worker of an event-driven
/// front-end binds its own listener to the same address this way, so
/// the kernel load-balances incoming connections across workers and
/// the accept-thread hand-off hop disappears.
///
/// IPv4 only; a v6 address returns `Unsupported` and the caller falls
/// back to sharing one listener.
pub fn bind_reuseport(addr: std::net::SocketAddr) -> io::Result<std::net::TcpListener> {
    use std::os::fd::FromRawFd;
    let std::net::SocketAddr::V4(v4) = addr else {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "SO_REUSEPORT listener groups are IPv4-only here",
        ));
    };
    // SAFETY: no pointers cross the boundary; `cvt` validates the fd.
    let fd = cvt(unsafe { socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0) })?;
    // SAFETY: `fd` is a freshly-created socket nothing else owns;
    // wrapping it before any fallible call below also guarantees it
    // cannot leak on the error paths.
    let listener = unsafe { std::net::TcpListener::from_raw_fd(fd) };
    set_int_opt(fd, SO_REUSEADDR, 1)?;
    set_int_opt(fd, SO_REUSEPORT, 1)?;
    let sa = SockAddrIn {
        family: AF_INET as u16,
        port: v4.port().to_be(),
        addr: u32::from_be_bytes(v4.ip().octets()).to_be(),
        zero: [0; 8],
    };
    // SAFETY: `sa` is a live, correctly-sized sockaddr_in the kernel
    // copies during the call.
    cvt(unsafe { bind(fd, &sa, std::mem::size_of::<SockAddrIn>() as u32) })?;
    // SAFETY: no pointers cross the boundary.
    cvt(unsafe { listen(fd, LISTEN_BACKLOG) })?;
    Ok(listener)
}

/// Bind `n` listeners of one `SO_REUSEPORT` group to the same address:
/// the first to `addr` (possibly port 0 for an ephemeral pick), the
/// siblings to the port the kernel assigned it. Returns the effective
/// address with the bound listeners, one per worker.
pub fn bind_reuseport_group(
    addr: std::net::SocketAddr,
    n: usize,
) -> io::Result<(std::net::SocketAddr, Vec<std::net::TcpListener>)> {
    let first = bind_reuseport(addr)?;
    let actual = first.local_addr()?;
    let mut listeners = vec![first];
    for _ in 1..n {
        listeners.push(bind_reuseport(actual)?);
    }
    Ok((actual, listeners))
}

// ------------------------------------------------------------ io_uring
//
// Raw submission/completion rings (kernel >= 5.1; the service layer
// requires the 5.6+ `IORING_OP_READ`/`WRITE` opcodes and probes for
// them at ring construction — see `Uring::probe_rw`). The layout
// structs below mirror `<linux/io_uring.h>` exactly; the ring head and
// tail words live in kernel-shared memory and are accessed through
// `AtomicU32` with the acquire/release pairing the io_uring ABI
// specifies (kernel writes SQ head + CQ tail, userspace writes SQ tail
// + CQ head).

/// asm-generic syscall numbers (x86-64 and aarch64 agree).
const SYS_IO_URING_SETUP: i64 = 425;
const SYS_IO_URING_ENTER: i64 = 426;

/// `io_uring_setup` flag: honour `cq_entries` in the params.
const IORING_SETUP_CQSIZE: u32 = 1 << 3;
/// SQ and CQ rings come back in one mmap region.
const IORING_FEAT_SINGLE_MMAP: u32 = 1 << 0;
/// `io_uring_enter` flag: block until `min_complete` CQEs.
const IORING_ENTER_GETEVENTS: u32 = 1;

const IORING_OFF_SQ_RING: i64 = 0;
const IORING_OFF_CQ_RING: i64 = 0x0800_0000;
const IORING_OFF_SQES: i64 = 0x1000_0000;

/// The SQE opcodes the front-end uses (numeric values are kernel ABI).
pub const IORING_OP_NOP: u8 = 0;
/// Kernel 5.5+.
pub const IORING_OP_ACCEPT: u8 = 13;
/// Kernel 5.5+.
pub const IORING_OP_ASYNC_CANCEL: u8 = 14;
/// Kernel 5.6+ — the floor `Uring::probe_rw` enforces.
pub const IORING_OP_READ: u8 = 22;
/// Kernel 5.6+.
pub const IORING_OP_WRITE: u8 = 23;

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct SqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    flags: u32,
    dropped: u32,
    array: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct CqringOffsets {
    head: u32,
    tail: u32,
    ring_mask: u32,
    ring_entries: u32,
    overflow: u32,
    cqes: u32,
    flags: u32,
    resv1: u32,
    user_addr: u64,
}

#[repr(C)]
#[derive(Clone, Copy, Default)]
struct IoUringParams {
    sq_entries: u32,
    cq_entries: u32,
    flags: u32,
    sq_thread_cpu: u32,
    sq_thread_idle: u32,
    features: u32,
    wq_fd: u32,
    resv: [u32; 3],
    sq_off: SqringOffsets,
    cq_off: CqringOffsets,
}

/// One submission-queue entry (64 bytes, kernel ABI). Constructed via
/// the op-specific helpers; the trailing words cover the ABI's unions
/// (`buf_index`/`personality`/address padding) and stay zero.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct Sqe {
    opcode: u8,
    flags: u8,
    ioprio: u16,
    fd: i32,
    off: u64,
    addr: u64,
    len: u32,
    op_flags: u32,
    user_data: u64,
    extra: [u64; 3],
}

const _: () = assert!(std::mem::size_of::<Sqe>() == 64);

impl Sqe {
    const fn zeroed() -> Sqe {
        Sqe {
            opcode: 0,
            flags: 0,
            ioprio: 0,
            fd: 0,
            off: 0,
            addr: 0,
            len: 0,
            op_flags: 0,
            user_data: 0,
            extra: [0; 3],
        }
    }

    pub fn nop(user_data: u64) -> Sqe {
        Sqe { opcode: IORING_OP_NOP, user_data, ..Sqe::zeroed() }
    }

    /// `read(fd, buf, len)` at the file's current position (offset -1
    /// means "use the fd position"; sockets ignore it either way).
    ///
    /// Safety contract (enforced by the caller): `buf` must stay valid
    /// and un-moved until this SQE's completion is reaped — the kernel
    /// writes into it asynchronously.
    pub fn read(fd: RawFd, buf: *mut u8, len: u32, user_data: u64) -> Sqe {
        Sqe {
            opcode: IORING_OP_READ,
            fd,
            off: u64::MAX,
            addr: buf as u64,
            len,
            user_data,
            ..Sqe::zeroed()
        }
    }

    /// `write(fd, buf, len)`. Same buffer-stability contract as
    /// [`Sqe::read`]: the kernel reads from `buf` asynchronously.
    pub fn write(fd: RawFd, buf: *const u8, len: u32, user_data: u64) -> Sqe {
        Sqe {
            opcode: IORING_OP_WRITE,
            fd,
            off: u64::MAX,
            addr: buf as u64,
            len,
            user_data,
            ..Sqe::zeroed()
        }
    }

    /// `accept4(fd, NULL, NULL, SOCK_CLOEXEC)`; the completion's `res`
    /// is the connected socket's fd.
    pub fn accept(fd: RawFd, user_data: u64) -> Sqe {
        Sqe {
            opcode: IORING_OP_ACCEPT,
            fd,
            op_flags: SOCK_CLOEXEC as u32,
            user_data,
            ..Sqe::zeroed()
        }
    }

    /// Cancel the in-flight SQE whose `user_data` is `target` (its CQE
    /// arrives with `-ECANCELED`; this SQE's own CQE reports whether a
    /// match was found). Used at shutdown to retire armed accepts.
    pub fn cancel(target: u64, user_data: u64) -> Sqe {
        Sqe {
            opcode: IORING_OP_ASYNC_CANCEL,
            addr: target,
            user_data,
            ..Sqe::zeroed()
        }
    }
}

/// One completion-queue entry (16 bytes, kernel ABI). `res` is the
/// op's return value — byte count or connected fd on success, negated
/// errno on failure.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct Cqe {
    pub user_data: u64,
    pub res: i32,
    pub flags: u32,
}

const _: () = assert!(std::mem::size_of::<Cqe>() == 16);

const PROT_READ: i32 = 1;
const PROT_WRITE: i32 = 2;
const MAP_SHARED: i32 = 0x01;
/// Pre-fault the ring pages: they are hot from the first submission.
const MAP_POPULATE: i32 = 0x8000;

extern "C" {
    fn syscall(num: i64, ...) -> i64;
    fn mmap(
        addr: *mut u8,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut u8;
    fn munmap(addr: *mut u8, len: usize) -> i32;
}

/// One mmap'd ring region (unmapped on drop).
struct RingMmap {
    ptr: *mut u8,
    len: usize,
}

impl RingMmap {
    fn map(fd: RawFd, len: usize, offset: i64) -> io::Result<RingMmap> {
        // SAFETY: requesting a fresh kernel-chosen mapping (addr is
        // null) over the ring fd, so no existing memory is touched;
        // the result is validated against MAP_FAILED below.
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ | PROT_WRITE,
                MAP_SHARED | MAP_POPULATE,
                fd,
                offset,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(RingMmap { ptr, len })
    }

    /// Typed pointer at byte offset `off`.
    fn at<T>(&self, off: u32) -> *mut T {
        // SAFETY: callers pass kernel-reported ring offsets, which lie
        // within the `len` bytes this mapping covers.
        unsafe { self.ptr.add(off as usize) as *mut T }
    }
}

impl Drop for RingMmap {
    fn drop(&mut self) {
        // SAFETY: (ptr, len) is exactly the region mmap returned; it is
        // unmapped exactly once, here.
        unsafe { munmap(self.ptr, self.len) };
    }
}

/// An io_uring instance: the ring fd plus mmap'd SQ/CQ rings and SQE
/// array, torn down in reverse on drop. Single-producer by design —
/// each server worker owns one ring outright, so no synchronisation
/// exists on the userspace side beyond the kernel-mandated
/// acquire/release on the shared head/tail words.
pub struct Uring {
    fd: RawFd,
    sq_ring: RingMmap,
    /// `None` when `IORING_FEAT_SINGLE_MMAP` aliased it to `sq_ring`.
    cq_ring: Option<RingMmap>,
    sqe_mem: RingMmap,
    // Cached SQ geometry.
    sq_head: *const std::sync::atomic::AtomicU32,
    sq_tail: *const std::sync::atomic::AtomicU32,
    sq_mask: u32,
    sq_entries: u32,
    sq_array: *mut u32,
    sqes: *mut Sqe,
    /// Local mirror of the SQ tail (sole producer).
    tail: u32,
    /// Pushed but not yet handed to the kernel.
    to_submit: u32,
    // Cached CQ geometry.
    cq_head: *const std::sync::atomic::AtomicU32,
    cq_tail: *const std::sync::atomic::AtomicU32,
    cq_mask: u32,
    cqes: *const Cqe,
}

// SAFETY: the ring is owned and driven by exactly one worker thread;
// sending that ownership across the spawn boundary is sound because
// the raw pointers target the mmap regions the struct itself keeps
// alive. Uring is deliberately !Sync — nothing hands out &Uring across
// threads.
unsafe impl Send for Uring {}

impl Uring {
    /// Set up a ring with `sq_entries` submission slots and (at least)
    /// `cq_entries` completion slots. Returns the raw-OS error from
    /// `io_uring_setup` untouched, so callers can distinguish
    /// kernel-too-old (`ENOSYS`) from seccomp (`EPERM`) from resource
    /// pressure.
    pub fn new(sq_entries: u32, cq_entries: u32) -> io::Result<Uring> {
        use std::sync::atomic::AtomicU32;
        let mut p = IoUringParams {
            flags: IORING_SETUP_CQSIZE,
            cq_entries,
            ..IoUringParams::default()
        };
        metrics().syscalls_uring.incr();
        // SAFETY: `p` is a live IoUringParams the kernel reads and
        // fills in during the call; nothing is retained after return.
        let fd = unsafe {
            syscall(
                SYS_IO_URING_SETUP,
                sq_entries as usize,
                &mut p as *mut IoUringParams as usize,
            )
        };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        let fd = fd as RawFd;
        // Wrap the fd immediately so mmap failures below still close it.
        struct FdGuard(RawFd);
        impl Drop for FdGuard {
            fn drop(&mut self) {
                // SAFETY: the guard owns the ring fd until forgotten.
                unsafe { close(self.0) };
            }
        }
        let guard = FdGuard(fd);

        let sq_len =
            p.sq_off.array as usize + p.sq_entries as usize * std::mem::size_of::<u32>();
        let cq_len =
            p.cq_off.cqes as usize + p.cq_entries as usize * std::mem::size_of::<Cqe>();
        let single = p.features & IORING_FEAT_SINGLE_MMAP != 0;
        let sq_ring = RingMmap::map(
            fd,
            if single { sq_len.max(cq_len) } else { sq_len },
            IORING_OFF_SQ_RING,
        )?;
        let cq_ring = if single {
            None
        } else {
            Some(RingMmap::map(fd, cq_len, IORING_OFF_CQ_RING)?)
        };
        let sqe_mem = RingMmap::map(
            fd,
            p.sq_entries as usize * std::mem::size_of::<Sqe>(),
            IORING_OFF_SQES,
        )?;

        let cq_base: &RingMmap = cq_ring.as_ref().unwrap_or(&sq_ring);
        let ring = Uring {
            fd,
            sq_head: sq_ring.at::<AtomicU32>(p.sq_off.head),
            sq_tail: sq_ring.at::<AtomicU32>(p.sq_off.tail),
            // SAFETY: kernel-reported offset within the SQ mapping,
            // written by io_uring_setup before it returned.
            sq_mask: unsafe { *sq_ring.at::<u32>(p.sq_off.ring_mask) },
            sq_entries: p.sq_entries,
            sq_array: sq_ring.at::<u32>(p.sq_off.array),
            sqes: sqe_mem.at::<Sqe>(0),
            tail: 0,
            to_submit: 0,
            cq_head: cq_base.at::<AtomicU32>(p.cq_off.head),
            cq_tail: cq_base.at::<AtomicU32>(p.cq_off.tail),
            // SAFETY: kernel-reported offset within the CQ mapping,
            // written by io_uring_setup before it returned.
            cq_mask: unsafe { *cq_base.at::<u32>(p.cq_off.ring_mask) },
            cqes: cq_base.at::<Cqe>(p.cq_off.cqes),
            sq_ring,
            cq_ring,
            sqe_mem,
        };
        std::mem::forget(guard); // Uring::drop owns the fd now
        Ok(ring)
    }

    /// Free submission slots right now.
    pub fn sq_space(&self) -> u32 {
        use std::sync::atomic::Ordering;
        // SAFETY: sq_head points at an aligned u32 inside the live
        // sq_ring mapping this struct keeps alive.
        let head = unsafe { &*self.sq_head }.load(Ordering::Acquire);
        self.sq_entries - self.tail.wrapping_sub(head)
    }

    /// Queue one SQE, flushing with a submit-only `io_uring_enter`
    /// when the ring is full (in-flight ops are not bounded by ring
    /// size — slots free as soon as the kernel consumes them).
    pub fn push(&mut self, sqe: Sqe) -> io::Result<()> {
        use std::sync::atomic::Ordering;
        while self.sq_space() == 0 {
            self.enter(0)?;
        }
        let idx = self.tail & self.sq_mask;
        // SAFETY: `idx` is masked into the ring, so both writes land
        // inside the sqe_mem / sq_ring mappings; the slot is free (the
        // sq_space loop above waited for the kernel to consume it) and
        // the kernel won't read it until the Release tail store below.
        unsafe {
            *self.sqes.add(idx as usize) = sqe;
            *self.sq_array.add(idx as usize) = idx;
        }
        self.tail = self.tail.wrapping_add(1);
        // SAFETY: sq_tail points at an aligned u32 inside the live
        // sq_ring mapping.
        unsafe { &*self.sq_tail }.store(self.tail, Ordering::Release);
        self.to_submit += 1;
        Ok(())
    }

    /// One `io_uring_enter`: submit everything queued since the last
    /// enter and, when `wait > 0`, block until that many completions
    /// are available. This is the *only* syscall on the uring hot path
    /// — the batch sizes it carries are what `fig17_frontend`'s
    /// syscalls-per-op series measures.
    pub fn enter(&mut self, wait: u32) -> io::Result<u32> {
        let m = metrics();
        loop {
            let n = self.to_submit;
            m.syscalls_uring.incr();
            if n > 0 {
                m.uring_sqe_batch.record(n as u64);
            }
            let flags = if wait > 0 { IORING_ENTER_GETEVENTS } else { 0 };
            // SAFETY: integer-only syscall (the sigset argument is
            // null); the kernel touches only its own ring mappings.
            let r = unsafe {
                syscall(
                    SYS_IO_URING_ENTER,
                    self.fd as usize,
                    n as usize,
                    wait as usize,
                    flags as usize,
                    0usize,
                    0usize,
                )
            };
            if r < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(e);
            }
            self.to_submit -= (r as u32).min(self.to_submit);
            return Ok(r as u32);
        }
    }

    /// Drain every available completion into `out`; returns how many
    /// arrived. Never blocks — pair with [`Uring::enter`]`(wait)`.
    pub fn reap(&mut self, out: &mut Vec<Cqe>) -> usize {
        use std::sync::atomic::Ordering;
        // SAFETY: cq_tail points at an aligned u32 inside the live CQ
        // ring mapping this struct keeps alive.
        let tail = unsafe { &*self.cq_tail }.load(Ordering::Acquire);
        // ORDERING: Relaxed is enough for cq_head — this thread is the
        // ring's only consumer, so the load just re-reads its own last
        // store; the Acquire on cq_tail above is what synchronises with
        // the kernel's CQE publication.
        // SAFETY: same CQ ring mapping as above.
        let mut head = unsafe { &*self.cq_head }.load(Ordering::Relaxed);
        let n = tail.wrapping_sub(head) as usize;
        out.reserve(n);
        while head != tail {
            let idx = head & self.cq_mask;
            // SAFETY: `idx` is masked into the CQ ring and entries up
            // to `tail` were published by the kernel before the
            // Acquire load observed them.
            out.push(unsafe { *self.cqes.add(idx as usize) });
            head = head.wrapping_add(1);
        }
        // SAFETY: cq_head points at an aligned u32 inside the live CQ
        // ring mapping.
        unsafe { &*self.cq_head }.store(head, Ordering::Release);
        if n > 0 {
            metrics().uring_cqe_batch.record(n as u64);
        }
        n
    }

    /// Verify the kernel supports the 5.6+ `IORING_OP_READ` this
    /// module's service consumer is written against: signal an
    /// eventfd, read it back through the ring, expect 8 bytes. An old
    /// kernel (5.1–5.5) sets up the ring fine but fails the opcode
    /// with `EINVAL` — that surfaces here instead of on the first real
    /// connection.
    pub fn probe_rw(&mut self) -> io::Result<()> {
        let ev = EventFd::new()?;
        ev.signal();
        let mut buf = [0u8; 8];
        self.push(Sqe::read(ev.fd(), buf.as_mut_ptr(), 8, 0x5eed))?;
        self.enter(1)?;
        let mut cqes = Vec::with_capacity(1);
        self.reap(&mut cqes);
        match cqes.first() {
            Some(c) if c.user_data == 0x5eed && c.res == 8 => Ok(()),
            Some(c) => Err(io::Error::from_raw_os_error(
                c.res.checked_neg().filter(|&e| e > 0).unwrap_or(22), // EINVAL
            )),
            None => Err(io::Error::new(
                io::ErrorKind::Other,
                "io_uring probe produced no completion",
            )),
        }
    }
}

impl Drop for Uring {
    fn drop(&mut self) {
        // SAFETY: self.fd is the ring fd this struct owns, closed once.
        // The mmap regions unmap via their own drops; closing the ring
        // fd releases the kernel context (which cancels or waits out
        // anything still in flight — the service layer drains to zero
        // in-flight before dropping, so its buffers never dangle).
        unsafe { close(self.fd) };
    }
}

/// Best-effort "does this kernel speak the io_uring dialect we need?"
/// probe, cached after the first call (rings are cheap but not free,
/// and every server spawn asks). Failure reasons collapse to `false`:
/// ENOSYS (pre-5.1), EINVAL from `probe_rw` (pre-5.6), EPERM
/// (seccomp/container policy).
pub fn uring_supported() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static CACHE: AtomicU8 = AtomicU8::new(0);
    // ORDERING: the flag is a standalone memo (0 unknown / 1 no /
    // 2 yes) guarding no other memory; a racing thread at worst
    // re-runs the probe and stores the same answer.
    match CACHE.load(Ordering::Relaxed) {
        2 => return true,
        1 => return false,
        _ => {}
    }
    let ok = Uring::new(8, 16).and_then(|mut r| r.probe_rw()).is_ok();
    // ORDERING: see the load above — an idempotent memo with no
    // ordering dependency on other memory.
    CACHE.store(if ok { 2 } else { 1 }, Ordering::Relaxed);
    ok
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::os::fd::AsRawFd;

    #[test]
    #[cfg_attr(miri, ignore = "real epoll/eventfd fds; no kernel under Miri")]
    fn eventfd_wakes_epoll_and_drains() {
        let ep = EpollFd::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.fd(), EPOLLIN, 42).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];

        // Nothing signalled: a zero-timeout wait reports no readiness.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        ev.signal();
        ev.signal(); // coalesces into one readable counter
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (got_ev, got_tok) = (events[0].events, events[0].data);
        assert_ne!(got_ev & EPOLLIN, 0);
        assert_eq!(got_tok, 42);

        ev.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "drain clears");
    }

    #[test]
    #[cfg_attr(miri, ignore = "real epoll fds and TCP; no kernel under Miri")]
    fn epoll_reports_listener_readiness() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let ep = EpollFd::new().unwrap();
        ep.add(listener.as_raw_fd(), EPOLLIN, 7).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        let _client = std::net::TcpStream::connect(addr).unwrap();
        let n = ep.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!({ events[0].data }, 7);
        assert!(listener.accept().is_ok());

        // Interest modification: drop read interest, no more reports.
        ep.modify(listener.as_raw_fd(), 0, 7).unwrap();
        let _client2 = std::net::TcpStream::connect(addr).unwrap();
        assert_eq!(ep.wait(&mut events, 50).unwrap(), 0);
        ep.del(listener.as_raw_fd()).unwrap();
    }
}
