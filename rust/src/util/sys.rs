//! Minimal Linux readiness-API surface for the epoll front-end
//! (`service::reactor`): raw `epoll_create1` / `epoll_ctl` /
//! `epoll_wait` / `eventfd` bindings plus RAII fd wrappers.
//!
//! Follows the `util::affinity` precedent: the `libc` crate is not
//! available in this offline build, but Rust's std already links the C
//! library on Linux, so declaring the symbols is all that is needed.
//! Errors are surfaced through `std::io::Error::last_os_error()`, which
//! reads the thread's errno the same way std's own syscall wrappers do.
//!
//! Only what the reactor needs is bound — level-triggered readiness on
//! sockets plus an eventfd wake token for cross-thread handoff and
//! graceful shutdown. This module is `target_os = "linux"` only; the
//! reactor falls back to the thread-per-connection server elsewhere.

use std::io;
use std::os::fd::RawFd;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its write half (half-close detection without a read).
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

/// `EPOLL_CLOEXEC` / `EFD_CLOEXEC` (== `O_CLOEXEC`).
const CLOEXEC: i32 = 0o2000000;
/// `EFD_NONBLOCK` (== `O_NONBLOCK`).
const EFD_NONBLOCK: i32 = 0o4000;

/// Mirror of the kernel's `struct epoll_event`. On x86-64 the kernel
/// ABI packs it (no padding between `events` and `data`); other
/// architectures use natural alignment.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token identifying the registered fd.
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> Self {
        EpollEvent { events: 0, data: 0 }
    }
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(
        epfd: i32,
        events: *mut EpollEvent,
        maxevents: i32,
        timeout_ms: i32,
    ) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn close(fd: i32) -> i32;
    fn setsockopt(
        fd: i32,
        level: i32,
        optname: i32,
        optval: *const u8,
        optlen: u32,
    ) -> i32;
}

const SOL_SOCKET: i32 = 1;
const SO_RCVBUF: i32 = 8;
const SO_SNDBUF: i32 = 7;

fn set_buf_opt(fd: RawFd, opt: i32, bytes: i32) -> io::Result<()> {
    let val = bytes.to_ne_bytes();
    cvt(unsafe {
        setsockopt(fd, SOL_SOCKET, opt, val.as_ptr(), val.len() as u32)
    })
    .map(|_| ())
}

/// Shrink (or grow) a socket's kernel receive buffer — the
/// backpressure tests use a tiny one to force the peer's replies to
/// back up into its user-space buffer deterministically.
pub fn set_recv_buffer(fd: RawFd, bytes: i32) -> io::Result<()> {
    set_buf_opt(fd, SO_RCVBUF, bytes)
}

/// Shrink (or grow) a socket's kernel send buffer.
pub fn set_send_buffer(fd: RawFd, bytes: i32) -> io::Result<()> {
    set_buf_opt(fd, SO_SNDBUF, bytes)
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// An epoll instance (closed on drop).
pub struct EpollFd(RawFd);

impl EpollFd {
    pub fn new() -> io::Result<EpollFd> {
        cvt(unsafe { epoll_create1(CLOEXEC) }).map(EpollFd)
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        cvt(unsafe { epoll_ctl(self.0, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with interest `events`, reporting `token`.
    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, token)
    }

    /// Change the interest set of a registered fd.
    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, token)
    }

    /// Deregister `fd` (closing the fd also deregisters it implicitly;
    /// this exists for fds that outlive their registration).
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, filling `events` from the front; returns how
    /// many entries are valid. `timeout_ms < 0` blocks indefinitely;
    /// `0` polls. Retries `EINTR` internally.
    pub fn wait(
        &self,
        events: &mut [EpollEvent],
        timeout_ms: i32,
    ) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(
                    self.0,
                    events.as_mut_ptr(),
                    events.len().min(i32::MAX as usize) as i32,
                    timeout_ms,
                )
            };
            match cvt(n) {
                Ok(n) => return Ok(n as usize),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }
}

impl Drop for EpollFd {
    fn drop(&mut self) {
        unsafe { close(self.0) };
    }
}

/// A nonblocking eventfd wake token (closed on drop): `signal` from any
/// thread, register `fd()` in an epoll set, `drain` on wake-up.
pub struct EventFd(RawFd);

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        cvt(unsafe { eventfd(0, CLOEXEC | EFD_NONBLOCK) }).map(EventFd)
    }

    pub fn fd(&self) -> RawFd {
        self.0
    }

    /// Make the fd readable (wake any epoll waiter). A full counter
    /// (`EAGAIN`) already means "signalled", so that error is ignored.
    pub fn signal(&self) {
        let one = 1u64.to_ne_bytes();
        unsafe { write(self.0, one.as_ptr(), one.len()) };
    }

    /// Consume all pending signals so level-triggered polling quiesces.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        while unsafe { read(self.0, buf.as_mut_ptr(), buf.len()) } > 0 {}
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        unsafe { close(self.0) };
    }
}

// `RawFd` operations are thread-safe at the kernel boundary; the
// wrappers add no interior state.
unsafe impl Send for EpollFd {}
unsafe impl Sync for EpollFd {}
unsafe impl Send for EventFd {}
unsafe impl Sync for EventFd {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::os::fd::AsRawFd;

    #[test]
    fn eventfd_wakes_epoll_and_drains() {
        let ep = EpollFd::new().unwrap();
        let ev = EventFd::new().unwrap();
        ep.add(ev.fd(), EPOLLIN, 42).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];

        // Nothing signalled: a zero-timeout wait reports no readiness.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        ev.signal();
        ev.signal(); // coalesces into one readable counter
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (got_ev, got_tok) = (events[0].events, events[0].data);
        assert_ne!(got_ev & EPOLLIN, 0);
        assert_eq!(got_tok, 42);

        ev.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0, "drain clears");
    }

    #[test]
    fn epoll_reports_listener_readiness() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let ep = EpollFd::new().unwrap();
        ep.add(listener.as_raw_fd(), EPOLLIN, 7).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);

        let _client = std::net::TcpStream::connect(addr).unwrap();
        let n = ep.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!({ events[0].data }, 7);
        assert!(listener.accept().is_ok());

        // Interest modification: drop read interest, no more reports.
        ep.modify(listener.as_raw_fd(), 0, 7).unwrap();
        let _client2 = std::net::TcpStream::connect(addr).unwrap();
        assert_eq!(ep.wait(&mut events, 50).unwrap(), 0);
        ep.del(listener.as_raw_fd()).unwrap();
    }
}
