//! Deterministic per-thread RNG for workload generation and tests.
//!
//! xoshiro256** seeded via SplitMix64 (the canonical seeding procedure),
//! so independent streams are reproducible from `(seed, thread_id)`.

use super::hash::splitmix64;

/// xoshiro256** PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(super::hash::GAMMA);
            splitmix64(sm.wrapping_sub(super::hash::GAMMA))
        };
        let s = [next(), next(), next(), next()];
        // All-zero state is invalid; splitmix64 of distinct inputs makes
        // this unreachable, but guard anyway.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Self { s }
    }

    /// Independent stream `stream` of base seed `seed`.
    pub fn for_thread(seed: u64, stream: u64) -> Self {
        Self::new(splitmix64(seed ^ splitmix64(stream.wrapping_add(1))))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift rejection-free
    /// approximation is fine for benchmark workloads).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::for_thread(42, 0);
        let mut b = Rng::for_thread(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
