//! Thread pinning (paper §4.1: each thread pinned to a specific core,
//! filling physical cores before hyperthreads, then the next socket).
//!
//! The container exposes no reliable topology, so the pin order is the
//! kernel's logical CPU order; on machines with `/sys` topology we sort
//! logical CPUs so that distinct physical cores come first (paper order).

/// Number of CPUs available to this process.
pub fn available_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Read the sibling list for a logical cpu, if exposed.
fn first_sibling(cpu: usize) -> usize {
    let path =
        format!("/sys/devices/system/cpu/cpu{cpu}/topology/thread_siblings_list");
    match std::fs::read_to_string(path) {
        Ok(s) => s
            .trim()
            .split([',', '-'])
            .next()
            .and_then(|x| x.parse().ok())
            .unwrap_or(cpu),
        Err(_) => cpu,
    }
}

/// Pin order: physical cores first (one logical CPU per core), then the
/// remaining hyperthread siblings — the paper's §4.1 strategy.
pub fn pin_order() -> Vec<usize> {
    let n = available_cpus();
    let cpus: Vec<usize> = (0..n).collect();
    let mut primaries = Vec::new();
    let mut siblings = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for &c in &cpus {
        if seen.insert(first_sibling(c)) {
            primaries.push(c);
        } else {
            siblings.push(c);
        }
    }
    primaries.extend(siblings);
    primaries
}

/// Pin the calling thread to logical CPU `cpu`. Best-effort: returns
/// false (and leaves affinity unchanged) if the syscall is unavailable.
pub fn pin_to(cpu: usize) -> bool {
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(cpu % libc::CPU_SETSIZE as usize, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set)
            == 0
    }
}

/// Pin thread `idx` according to [`pin_order`].
pub fn pin_thread(idx: usize) -> bool {
    let order = pin_order();
    if order.is_empty() {
        return false;
    }
    pin_to(order[idx % order.len()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_order_covers_all_cpus_once() {
        let order = pin_order();
        assert_eq!(order.len(), available_cpus());
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..available_cpus()).collect::<Vec<_>>());
    }

    #[test]
    fn pin_to_current_cpu_succeeds() {
        // CPU 0 always exists in the mask universe.
        assert!(pin_to(0));
        // Restore: allow all cpus again.
        unsafe {
            let mut set: libc::cpu_set_t = std::mem::zeroed();
            for c in 0..available_cpus() {
                libc::CPU_SET(c, &mut set);
            }
            libc::sched_setaffinity(
                0,
                std::mem::size_of::<libc::cpu_set_t>(),
                &set,
            );
        }
    }
}
