//! Thread pinning (paper §4.1: each thread pinned to a specific core,
//! filling physical cores before hyperthreads, then the next socket).
//!
//! The container exposes no reliable topology, so the pin order is the
//! kernel's logical CPU order; on machines with `/sys` topology we sort
//! logical CPUs so that distinct physical cores come first (paper order).
//!
//! The `sched_setaffinity` binding is declared in-tree (`sys` below):
//! the `libc` crate is not available in this offline build, and Rust's
//! std already links the C library on Linux, so the raw declaration is
//! all that is needed.

/// Number of CPUs available to this process.
pub fn available_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Read the sibling list for a logical cpu, if exposed.
fn first_sibling(cpu: usize) -> usize {
    let path =
        format!("/sys/devices/system/cpu/cpu{cpu}/topology/thread_siblings_list");
    match std::fs::read_to_string(path) {
        Ok(s) => s
            .trim()
            .split([',', '-'])
            .next()
            .and_then(|x| x.parse().ok())
            .unwrap_or(cpu),
        Err(_) => cpu,
    }
}

/// Pin order: physical cores first (one logical CPU per core), then the
/// remaining hyperthread siblings — the paper's §4.1 strategy.
pub fn pin_order() -> Vec<usize> {
    let n = available_cpus();
    let cpus: Vec<usize> = (0..n).collect();
    let mut primaries = Vec::new();
    let mut siblings = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for &c in &cpus {
        if seen.insert(first_sibling(c)) {
            primaries.push(c);
        } else {
            siblings.push(c);
        }
    }
    primaries.extend(siblings);
    primaries
}

/// Minimal Linux affinity syscall surface (libc-crate-free).
#[cfg(target_os = "linux")]
mod sys {
    /// Bits in a kernel cpu mask (glibc's `CPU_SETSIZE`).
    pub const CPU_SETSIZE: usize = 1024;

    /// Mirror of glibc's `cpu_set_t`: a 1024-bit mask.
    #[repr(C)]
    pub struct CpuSet {
        pub bits: [u64; CPU_SETSIZE / 64],
    }

    impl CpuSet {
        pub fn zeroed() -> Self {
            CpuSet { bits: [0; CPU_SETSIZE / 64] }
        }

        /// Equivalent of `CPU_SET(cpu % CPU_SETSIZE, &mut set)`.
        pub fn set(&mut self, cpu: usize) {
            let cpu = cpu % CPU_SETSIZE;
            self.bits[cpu / 64] |= 1u64 << (cpu % 64);
        }
    }

    extern "C" {
        /// `int sched_setaffinity(pid_t, size_t, const cpu_set_t *)`.
        pub fn sched_setaffinity(
            pid: i32,
            cpusetsize: usize,
            mask: *const CpuSet,
        ) -> i32;
    }
}

/// Pin the calling thread to logical CPU `cpu`. Best-effort: returns
/// false (and leaves affinity unchanged) if the syscall is unavailable.
#[cfg(target_os = "linux")]
pub fn pin_to(cpu: usize) -> bool {
    let mut set = sys::CpuSet::zeroed();
    set.set(cpu);
    // SAFETY: `set` is a live, correctly-sized cpu_set_t; pid 0 means
    // the calling thread, and the kernel copies the mask out.
    unsafe {
        sys::sched_setaffinity(0, std::mem::size_of::<sys::CpuSet>(), &set)
            == 0
    }
}

/// Non-Linux fallback: pinning is a no-op.
#[cfg(not(target_os = "linux"))]
pub fn pin_to(_cpu: usize) -> bool {
    false
}

/// Pin thread `idx` according to [`pin_order`].
pub fn pin_thread(idx: usize) -> bool {
    let order = pin_order();
    if order.is_empty() {
        return false;
    }
    pin_to(order[idx % order.len()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_order_covers_all_cpus_once() {
        let order = pin_order();
        assert_eq!(order.len(), available_cpus());
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(sorted, (0..available_cpus()).collect::<Vec<_>>());
    }

    #[cfg(target_os = "linux")]
    #[test]
    #[cfg_attr(miri, ignore = "foreign sched_setaffinity call; not shimmed")]
    fn pin_to_current_cpu_succeeds() {
        // CPU 0 always exists in the mask universe.
        assert!(pin_to(0));
        // Restore: allow all cpus again.
        let mut set = super::sys::CpuSet::zeroed();
        for c in 0..available_cpus() {
            set.set(c);
        }
        // SAFETY: `set` is a live, correctly-sized cpu_set_t; pid 0 is
        // the calling thread.
        unsafe {
            super::sys::sched_setaffinity(
                0,
                std::mem::size_of::<super::sys::CpuSet>(),
                &set,
            );
        }
    }
}
