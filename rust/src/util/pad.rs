//! Cache-line padding (in-tree replacement for
//! `crossbeam_utils::CachePadded` — external crates are not available
//! in this offline build).
//!
//! Aligns the wrapped value to 128 bytes: two 64-byte lines, covering
//! the adjacent-line ("spatial") prefetcher on modern x86, which is the
//! same constant crossbeam uses there. Sharded timestamp words, lock
//! shards, and the K-CAS descriptor registry all rely on this to avoid
//! false sharing between adjacent hot words.

/// Pads and aligns `T` to 128 bytes.
#[derive(Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` in padding.
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    /// Unwrap.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    #[inline(always)]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline(always)]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for CachePadded<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.value.fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_to_128() {
        assert_eq!(std::mem::align_of::<CachePadded<u64>>(), 128);
        assert_eq!(std::mem::size_of::<CachePadded<u64>>(), 128);
        let xs: Vec<CachePadded<u64>> =
            (0..4u64).map(CachePadded::new).collect();
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(**x, i as u64);
            assert_eq!(x as *const _ as usize % 128, 0);
        }
    }

    #[test]
    fn deref_round_trip() {
        let mut p = CachePadded::new(41u32);
        *p += 1;
        assert_eq!(*p, 42);
        assert_eq!(p.into_inner(), 42);
    }
}
