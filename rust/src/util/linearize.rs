//! A small linearizability checker for set histories (Wing & Gong
//! style exhaustive search with memoization).
//!
//! The §3.4 proof obligations of the paper are linearizability of
//! `Contains`/`Add`/`Remove`; this module lets tests *check* that
//! claim mechanically on recorded concurrent histories: an operation's
//! interval is [invocation, response], and the checker searches for a
//! total order that (a) respects real-time order between
//! non-overlapping operations and (b) replays correctly against
//! sequential set semantics.
//!
//! Complexity is exponential in the worst case, so tests use short
//! windows (a few hundred events over a handful of keys) — more than
//! enough to catch timestamp-validation bugs like the paper's Fig. 5
//! race, which manifests within a handful of overlapping ops.

use std::collections::HashSet;

/// Operation kind + argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Contains(u64),
    Add(u64),
    Remove(u64),
}

/// One completed operation in a history.
#[derive(Clone, Debug)]
pub struct Event {
    pub kind: OpKind,
    pub result: bool,
    /// Invocation timestamp (ns, from a shared monotonic clock).
    pub invoke: u64,
    /// Response timestamp.
    pub response: u64,
}

/// Replay `kind` against a sequential set; returns the expected result.
fn apply(state: &mut HashSet<u64>, kind: OpKind) -> bool {
    match kind {
        OpKind::Contains(k) => state.contains(&k),
        OpKind::Add(k) => state.insert(k),
        OpKind::Remove(k) => state.remove(&k),
    }
}

fn undo(state: &mut HashSet<u64>, kind: OpKind, result: bool) {
    match kind {
        OpKind::Contains(_) => {}
        OpKind::Add(k) => {
            if result {
                state.remove(&k);
            }
        }
        OpKind::Remove(k) => {
            if result {
                state.insert(k);
            }
        }
    }
}

/// Is `history` linearizable with respect to set semantics, starting
/// from `initial` membership?
///
/// DFS over "next linearized op" choices: at each step any *minimal*
/// pending op (one whose invocation precedes every pending response)
/// may linearize next if its recorded result matches the sequential
/// replay. Memoizes (linearized-set, state-hash) pairs.
pub fn is_linearizable(initial: &[u64], history: &[Event]) -> bool {
    let n = history.len();
    assert!(n <= 64, "checker limited to 64-op windows");
    let mut state: HashSet<u64> = initial.iter().copied().collect();
    let mut done: u64 = 0; // bitmask of linearized ops
    let mut seen: HashSet<u64> = HashSet::new(); // memo on `done`
    // For real-time order: op i must linearize before op j if
    // response_i < invoke_j. Precompute "blockers": op j can be chosen
    // only when every op i with response_i < invoke_j is done.
    let mut must_precede = vec![0u64; n];
    for j in 0..n {
        for i in 0..n {
            if i != j && history[i].response < history[j].invoke {
                must_precede[j] |= 1 << i;
            }
        }
    }

    fn dfs(
        history: &[Event],
        must_precede: &[u64],
        state: &mut HashSet<u64>,
        done: &mut u64,
        seen: &mut HashSet<u64>,
    ) -> bool {
        let n = history.len();
        if done.count_ones() as usize == n {
            return true;
        }
        if !seen.insert(*done) {
            return false; // already explored this frontier
        }
        for j in 0..n {
            let bit = 1u64 << j;
            if *done & bit != 0 || (must_precede[j] & !*done) != 0 {
                continue;
            }
            let ev = &history[j];
            let got = apply(state, ev.kind);
            if got == ev.result {
                *done |= bit;
                if dfs(history, must_precede, state, done, seen) {
                    return true;
                }
                *done &= !bit;
            }
            undo(state, ev.kind, got);
        }
        false
    }

    dfs(history, &must_precede, &mut state, &mut done, &mut seen)
}

/// Record a concurrent history of random ops over a small key range
/// against any [`crate::maps::ConcurrentSet`], then check it.
pub fn record_history(
    table: &dyn crate::maps::ConcurrentSet,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<Event> {
    use std::sync::Mutex;
    use std::time::Instant;
    let clock = Instant::now();
    let events: Mutex<Vec<Event>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for tid in 0..threads {
            let events = &events;
            let clock = &clock;
            s.spawn(move || {
                let mut rng =
                    crate::util::rng::Rng::for_thread(seed, tid as u64);
                let mut local = Vec::with_capacity(ops_per_thread);
                for _ in 0..ops_per_thread {
                    let k = 1 + rng.below(keys);
                    let kind = match rng.below(3) {
                        0 => OpKind::Add(k),
                        1 => OpKind::Remove(k),
                        _ => OpKind::Contains(k),
                    };
                    let invoke = clock.elapsed().as_nanos() as u64;
                    let result = match kind {
                        OpKind::Contains(k) => table.contains(k),
                        OpKind::Add(k) => table.add(k),
                        OpKind::Remove(k) => table.remove(k),
                    };
                    let response = clock.elapsed().as_nanos() as u64;
                    local.push(Event { kind, result, invoke, response });
                }
                events.lock().unwrap().extend(local);
            });
        }
    });
    let mut h = events.into_inner().unwrap();
    h.sort_by_key(|e| e.invoke);
    h
}

// ---- key→value histories (the conditional-RMW surface) ----

/// Map operation kind + arguments, covering the conditional-first
/// [`crate::maps::ConcurrentMap`] surface (`compare_exchange` corners,
/// `get_or_insert`, `fetch_add`) alongside the unconditional trio.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapOpKind {
    Get(u64),
    Insert(u64, u64),
    Remove(u64),
    CmpEx(u64, Option<u64>, Option<u64>),
    GetOrInsert(u64, u64),
    FetchAdd(u64, u64),
}

/// Result of a map op: value-shaped (`get`/`insert`/`remove`/
/// `get_or_insert`/`fetch_add` all report an `Option<u64>`) or
/// CAS-shaped (`compare_exchange`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapRes {
    Val(Option<u64>),
    Cas(Result<(), Option<u64>>),
}

/// One completed map operation in a history.
#[derive(Clone, Debug)]
pub struct MapEvent {
    pub kind: MapOpKind,
    pub result: MapRes,
    pub invoke: u64,
    pub response: u64,
}

/// Replay `kind` against sequential map semantics.
fn map_apply(state: &mut std::collections::HashMap<u64, u64>, kind: MapOpKind) -> MapRes {
    match kind {
        MapOpKind::Get(k) => MapRes::Val(state.get(&k).copied()),
        MapOpKind::Insert(k, v) => MapRes::Val(state.insert(k, v)),
        MapOpKind::Remove(k) => MapRes::Val(state.remove(&k)),
        MapOpKind::CmpEx(k, e, n) => {
            let cur = state.get(&k).copied();
            if cur == e {
                match n {
                    Some(v) => {
                        state.insert(k, v);
                    }
                    None => {
                        state.remove(&k);
                    }
                }
                MapRes::Cas(Ok(()))
            } else {
                MapRes::Cas(Err(cur))
            }
        }
        MapOpKind::GetOrInsert(k, v) => {
            let cur = state.get(&k).copied();
            if cur.is_none() {
                state.insert(k, v);
            }
            MapRes::Val(cur)
        }
        MapOpKind::FetchAdd(k, d) => {
            let cur = state.get(&k).copied();
            state.insert(
                k,
                cur.unwrap_or(0).wrapping_add(d) & crate::kcas::MAX_VALUE,
            );
            MapRes::Val(cur)
        }
    }
}

/// Reverse a [`map_apply`]; the prior state is reconstructible from
/// `(kind, result)` for every op.
fn map_undo(
    state: &mut std::collections::HashMap<u64, u64>,
    kind: MapOpKind,
    result: MapRes,
) {
    let restore = |state: &mut std::collections::HashMap<u64, u64>,
                   k: u64,
                   prev: Option<u64>| {
        match prev {
            Some(v) => {
                state.insert(k, v);
            }
            None => {
                state.remove(&k);
            }
        }
    };
    match (kind, result) {
        (MapOpKind::Get(_), _) => {}
        (MapOpKind::Insert(k, _), MapRes::Val(prev))
        | (MapOpKind::Remove(k), MapRes::Val(prev)) => restore(state, k, prev),
        (MapOpKind::CmpEx(k, e, _), MapRes::Cas(Ok(()))) => {
            restore(state, k, e)
        }
        (MapOpKind::CmpEx(..), MapRes::Cas(Err(_))) => {}
        (MapOpKind::GetOrInsert(k, _), MapRes::Val(prev)) => {
            if prev.is_none() {
                state.remove(&k);
            }
        }
        (MapOpKind::FetchAdd(k, _), MapRes::Val(prev)) => {
            restore(state, k, prev)
        }
        _ => unreachable!("result shape mismatches op kind"),
    }
}

/// Is `history` linearizable with respect to sequential *map*
/// semantics, starting from the `initial` (key, value) pairs? Same
/// Wing & Gong search as [`is_linearizable`], over the richer state.
pub fn is_map_linearizable(initial: &[(u64, u64)], history: &[MapEvent]) -> bool {
    let n = history.len();
    assert!(n <= 64, "checker limited to 64-op windows");
    let mut state: std::collections::HashMap<u64, u64> =
        initial.iter().copied().collect();
    let mut done: u64 = 0;
    // Unlike the set checker, map states reached via different orders
    // of the same op subset can differ (last write wins), so the memo
    // is keyed on (done-mask, order-independent state hash).
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    let mut must_precede = vec![0u64; n];
    for j in 0..n {
        for i in 0..n {
            if i != j && history[i].response < history[j].invoke {
                must_precede[j] |= 1 << i;
            }
        }
    }

    fn state_hash(state: &std::collections::HashMap<u64, u64>) -> u64 {
        state.iter().fold(0u64, |acc, (&k, &v)| {
            acc ^ crate::util::hash::splitmix64(k ^ crate::util::hash::splitmix64(v))
        })
    }

    fn dfs(
        history: &[MapEvent],
        must_precede: &[u64],
        state: &mut std::collections::HashMap<u64, u64>,
        done: &mut u64,
        seen: &mut HashSet<(u64, u64)>,
    ) -> bool {
        let n = history.len();
        if done.count_ones() as usize == n {
            return true;
        }
        if !seen.insert((*done, state_hash(state))) {
            return false;
        }
        for j in 0..n {
            let bit = 1u64 << j;
            if *done & bit != 0 || (must_precede[j] & !*done) != 0 {
                continue;
            }
            let ev = &history[j];
            let got = map_apply(state, ev.kind);
            if got == ev.result {
                *done |= bit;
                if dfs(history, must_precede, state, done, seen) {
                    return true;
                }
                *done &= !bit;
            }
            map_undo(state, ev.kind, got);
        }
        false
    }

    dfs(history, &must_precede, &mut state, &mut done, &mut seen)
}

/// Record a concurrent history of random map ops (conditional ops
/// included) over a small key range against any
/// [`crate::maps::ConcurrentMap`], for [`is_map_linearizable`].
pub fn record_map_history(
    map: &dyn crate::maps::ConcurrentMap,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<MapEvent> {
    use std::sync::Mutex;
    use std::time::Instant;
    let clock = Instant::now();
    let events: Mutex<Vec<MapEvent>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for tid in 0..threads {
            let events = &events;
            let clock = &clock;
            s.spawn(move || {
                let mut rng =
                    crate::util::rng::Rng::for_thread(seed, tid as u64);
                let mut local = Vec::with_capacity(ops_per_thread);
                // Tiny value/expectation domains so conditional hits,
                // misses, and witness mismatches all occur.
                let opt = |rng: &mut crate::util::rng::Rng| {
                    if rng.below(3) == 0 {
                        None
                    } else {
                        Some(rng.below(4))
                    }
                };
                for _ in 0..ops_per_thread {
                    let k = 1 + rng.below(keys);
                    let kind = match rng.below(8) {
                        0 => MapOpKind::Get(k),
                        1 => MapOpKind::Insert(k, rng.below(4)),
                        2 => MapOpKind::Remove(k),
                        3 | 4 => MapOpKind::CmpEx(k, opt(&mut rng), opt(&mut rng)),
                        5 => MapOpKind::GetOrInsert(k, rng.below(4)),
                        _ => MapOpKind::FetchAdd(k, 1 + rng.below(2)),
                    };
                    let invoke = clock.elapsed().as_nanos() as u64;
                    let result = match kind {
                        MapOpKind::Get(k) => MapRes::Val(map.get(k)),
                        MapOpKind::Insert(k, v) => MapRes::Val(map.insert(k, v)),
                        MapOpKind::Remove(k) => MapRes::Val(map.remove(k)),
                        MapOpKind::CmpEx(k, e, n) => {
                            MapRes::Cas(map.compare_exchange(k, e, n))
                        }
                        MapOpKind::GetOrInsert(k, v) => {
                            MapRes::Val(map.get_or_insert(k, v))
                        }
                        MapOpKind::FetchAdd(k, d) => {
                            MapRes::Val(map.fetch_add(k, d))
                        }
                    };
                    let response = clock.elapsed().as_nanos() as u64;
                    local.push(MapEvent { kind, result, invoke, response });
                }
                events.lock().unwrap().extend(local);
            });
        }
    });
    let mut h = events.into_inner().unwrap();
    h.sort_by_key(|e| e.invoke);
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: OpKind, result: bool, invoke: u64, response: u64) -> Event {
        Event { kind, result, invoke, response }
    }

    #[test]
    fn sequential_history_accepts() {
        let h = vec![
            ev(OpKind::Add(1), true, 0, 1),
            ev(OpKind::Contains(1), true, 2, 3),
            ev(OpKind::Remove(1), true, 4, 5),
            ev(OpKind::Contains(1), false, 6, 7),
        ];
        assert!(is_linearizable(&[], &h));
    }

    #[test]
    fn wrong_result_rejected() {
        let h = vec![
            ev(OpKind::Add(1), true, 0, 1),
            ev(OpKind::Contains(1), false, 2, 3), // impossible
        ];
        assert!(!is_linearizable(&[], &h));
    }

    #[test]
    fn overlap_allows_reordering() {
        // contains(1)=true overlaps add(1)=true: legal (add first).
        let h = vec![
            ev(OpKind::Add(1), true, 0, 10),
            ev(OpKind::Contains(1), true, 5, 6),
        ];
        assert!(is_linearizable(&[], &h));
        // But if they do NOT overlap and contains came first: illegal.
        let h2 = vec![
            ev(OpKind::Contains(1), true, 0, 1),
            ev(OpKind::Add(1), true, 2, 3),
        ];
        assert!(!is_linearizable(&[], &h2));
    }

    #[test]
    fn fig5_style_violation_rejected() {
        // Key 7 is in the set the whole time (nobody removes it), yet a
        // reader reports it absent: the Fig. 5 bug signature.
        let h = vec![
            ev(OpKind::Remove(3), true, 0, 10), // unrelated remove
            ev(OpKind::Contains(7), false, 2, 4), // 7 never absent!
        ];
        assert!(!is_linearizable(&[3, 7], &h));
    }

    #[test]
    fn duplicate_add_semantics() {
        let h = vec![
            ev(OpKind::Add(5), true, 0, 10),
            ev(OpKind::Add(5), true, 2, 12), // both true only if a remove splits them — none here
        ];
        assert!(!is_linearizable(&[], &h));
        let h2 = vec![
            ev(OpKind::Add(5), true, 0, 10),
            ev(OpKind::Remove(5), true, 2, 12),
            ev(OpKind::Add(5), true, 4, 14), // now legal
        ];
        assert!(is_linearizable(&[], &h2));
    }

    #[test]
    fn initial_state_respected() {
        let h = vec![ev(OpKind::Contains(9), true, 0, 1)];
        assert!(is_linearizable(&[9], &h));
        assert!(!is_linearizable(&[], &h));
    }

    fn mev(kind: MapOpKind, result: MapRes, invoke: u64, response: u64) -> MapEvent {
        MapEvent { kind, result, invoke, response }
    }

    #[test]
    fn map_sequential_rmw_history_accepts() {
        let h = vec![
            mev(MapOpKind::CmpEx(1, None, Some(5)), MapRes::Cas(Ok(())), 0, 1),
            mev(MapOpKind::FetchAdd(1, 2), MapRes::Val(Some(5)), 2, 3),
            mev(MapOpKind::GetOrInsert(1, 9), MapRes::Val(Some(7)), 4, 5),
            mev(
                MapOpKind::CmpEx(1, Some(7), None),
                MapRes::Cas(Ok(())),
                6,
                7,
            ),
            mev(MapOpKind::Get(1), MapRes::Val(None), 8, 9),
            mev(MapOpKind::FetchAdd(1, 3), MapRes::Val(None), 10, 11),
            mev(MapOpKind::Get(1), MapRes::Val(Some(3)), 12, 13),
        ];
        assert!(is_map_linearizable(&[], &h));
    }

    #[test]
    fn map_lost_increment_rejected() {
        // Two fetch_adds both report the same previous value without
        // overlapping — a lost update no valid linearization allows.
        let h = vec![
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 0, 1),
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 2, 3),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h));
        // Overlapping they'd still be invalid (each sees the other's
        // commit or not — but both claiming prev=5 loses one).
        let h2 = vec![
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 0, 10),
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 1, 9),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h2));
    }

    #[test]
    fn map_double_cmpex_win_rejected() {
        // Two compare_exchange(5->6) both succeed with no one restoring
        // 5 in between: impossible.
        let h = vec![
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                0,
                10,
            ),
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                1,
                9,
            ),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h));
    }

    #[test]
    fn map_cmpex_witness_respects_overlap() {
        // The failed CAS's witness (6) is only explicable if it
        // linearizes after the overlapping winner.
        let h = vec![
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                0,
                10,
            ),
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(7)),
                MapRes::Cas(Err(Some(6))),
                2,
                8,
            ),
        ];
        assert!(is_map_linearizable(&[(1, 5)], &h));
        // Without overlap in the wrong order it's rejected.
        let h2 = vec![
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(7)),
                MapRes::Cas(Err(Some(6))),
                0,
                1,
            ),
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                2,
                3,
            ),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h2));
    }
}
