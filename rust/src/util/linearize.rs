//! A small linearizability checker for set histories (Wing & Gong
//! style exhaustive search with memoization).
//!
//! The §3.4 proof obligations of the paper are linearizability of
//! `Contains`/`Add`/`Remove`; this module lets tests *check* that
//! claim mechanically on recorded concurrent histories: an operation's
//! interval is [invocation, response], and the checker searches for a
//! total order that (a) respects real-time order between
//! non-overlapping operations and (b) replays correctly against
//! sequential set semantics.
//!
//! Complexity is exponential in the worst case, so tests use short
//! windows (a few hundred events over a handful of keys) — more than
//! enough to catch timestamp-validation bugs like the paper's Fig. 5
//! race, which manifests within a handful of overlapping ops.

use std::collections::HashSet;

/// Operation kind + argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Contains(u64),
    Add(u64),
    Remove(u64),
}

/// One completed operation in a history.
#[derive(Clone, Debug)]
pub struct Event {
    pub kind: OpKind,
    pub result: bool,
    /// Invocation timestamp (ns, from a shared monotonic clock).
    pub invoke: u64,
    /// Response timestamp.
    pub response: u64,
}

/// Replay `kind` against a sequential set; returns the expected result.
fn apply(state: &mut HashSet<u64>, kind: OpKind) -> bool {
    match kind {
        OpKind::Contains(k) => state.contains(&k),
        OpKind::Add(k) => state.insert(k),
        OpKind::Remove(k) => state.remove(&k),
    }
}

fn undo(state: &mut HashSet<u64>, kind: OpKind, result: bool) {
    match kind {
        OpKind::Contains(_) => {}
        OpKind::Add(k) => {
            if result {
                state.remove(&k);
            }
        }
        OpKind::Remove(k) => {
            if result {
                state.insert(k);
            }
        }
    }
}

/// Is `history` linearizable with respect to set semantics, starting
/// from `initial` membership?
///
/// DFS over "next linearized op" choices: at each step any *minimal*
/// pending op (one whose invocation precedes every pending response)
/// may linearize next if its recorded result matches the sequential
/// replay. Memoizes (linearized-set, state-hash) pairs.
pub fn is_linearizable(initial: &[u64], history: &[Event]) -> bool {
    let n = history.len();
    assert!(n <= 64, "checker limited to 64-op windows");
    let mut state: HashSet<u64> = initial.iter().copied().collect();
    let mut done: u64 = 0; // bitmask of linearized ops
    let mut seen: HashSet<u64> = HashSet::new(); // memo on `done`
    // For real-time order: op i must linearize before op j if
    // response_i < invoke_j. Precompute "blockers": op j can be chosen
    // only when every op i with response_i < invoke_j is done.
    let mut must_precede = vec![0u64; n];
    for j in 0..n {
        for i in 0..n {
            if i != j && history[i].response < history[j].invoke {
                must_precede[j] |= 1 << i;
            }
        }
    }

    fn dfs(
        history: &[Event],
        must_precede: &[u64],
        state: &mut HashSet<u64>,
        done: &mut u64,
        seen: &mut HashSet<u64>,
    ) -> bool {
        let n = history.len();
        if done.count_ones() as usize == n {
            return true;
        }
        if !seen.insert(*done) {
            return false; // already explored this frontier
        }
        for j in 0..n {
            let bit = 1u64 << j;
            if *done & bit != 0 || (must_precede[j] & !*done) != 0 {
                continue;
            }
            let ev = &history[j];
            let got = apply(state, ev.kind);
            if got == ev.result {
                *done |= bit;
                if dfs(history, must_precede, state, done, seen) {
                    return true;
                }
                *done &= !bit;
            }
            undo(state, ev.kind, got);
        }
        false
    }

    dfs(history, &must_precede, &mut state, &mut done, &mut seen)
}

/// Record a concurrent history of random ops over a small key range
/// against any [`crate::maps::ConcurrentSet`], then check it.
pub fn record_history(
    table: &dyn crate::maps::ConcurrentSet,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<Event> {
    use std::sync::Mutex;
    use std::time::Instant;
    let clock = Instant::now();
    let events: Mutex<Vec<Event>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for tid in 0..threads {
            let events = &events;
            let clock = &clock;
            s.spawn(move || {
                let mut rng =
                    crate::util::rng::Rng::for_thread(seed, tid as u64);
                let mut local = Vec::with_capacity(ops_per_thread);
                for _ in 0..ops_per_thread {
                    let k = 1 + rng.below(keys);
                    let kind = match rng.below(3) {
                        0 => OpKind::Add(k),
                        1 => OpKind::Remove(k),
                        _ => OpKind::Contains(k),
                    };
                    let invoke = clock.elapsed().as_nanos() as u64;
                    let result = match kind {
                        OpKind::Contains(k) => table.contains(k),
                        OpKind::Add(k) => table.add(k),
                        OpKind::Remove(k) => table.remove(k),
                    };
                    let response = clock.elapsed().as_nanos() as u64;
                    local.push(Event { kind, result, invoke, response });
                }
                events.lock().unwrap().extend(local);
            });
        }
    });
    let mut h = events.into_inner().unwrap();
    h.sort_by_key(|e| e.invoke);
    h
}

// ---- key→value histories (the conditional-RMW surface) ----

/// Map operation kind + arguments, covering the conditional-first
/// [`crate::maps::ConcurrentMap`] surface (`compare_exchange` corners,
/// `get_or_insert`, `fetch_add`) alongside the unconditional trio.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapOpKind {
    Get(u64),
    Insert(u64, u64),
    Remove(u64),
    CmpEx(u64, Option<u64>, Option<u64>),
    GetOrInsert(u64, u64),
    FetchAdd(u64, u64),
}

/// Result of a map op: value-shaped (`get`/`insert`/`remove`/
/// `get_or_insert`/`fetch_add` all report an `Option<u64>`) or
/// CAS-shaped (`compare_exchange`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapRes {
    Val(Option<u64>),
    Cas(Result<(), Option<u64>>),
}

/// One completed map operation in a history.
#[derive(Clone, Debug)]
pub struct MapEvent {
    pub kind: MapOpKind,
    pub result: MapRes,
    pub invoke: u64,
    pub response: u64,
}

/// Replay `kind` against sequential map semantics.
fn map_apply(state: &mut std::collections::HashMap<u64, u64>, kind: MapOpKind) -> MapRes {
    match kind {
        MapOpKind::Get(k) => MapRes::Val(state.get(&k).copied()),
        MapOpKind::Insert(k, v) => MapRes::Val(state.insert(k, v)),
        MapOpKind::Remove(k) => MapRes::Val(state.remove(&k)),
        MapOpKind::CmpEx(k, e, n) => {
            let cur = state.get(&k).copied();
            if cur == e {
                match n {
                    Some(v) => {
                        state.insert(k, v);
                    }
                    None => {
                        state.remove(&k);
                    }
                }
                MapRes::Cas(Ok(()))
            } else {
                MapRes::Cas(Err(cur))
            }
        }
        MapOpKind::GetOrInsert(k, v) => {
            let cur = state.get(&k).copied();
            if cur.is_none() {
                state.insert(k, v);
            }
            MapRes::Val(cur)
        }
        MapOpKind::FetchAdd(k, d) => {
            let cur = state.get(&k).copied();
            state.insert(
                k,
                cur.unwrap_or(0).wrapping_add(d) & crate::kcas::MAX_VALUE,
            );
            MapRes::Val(cur)
        }
    }
}

/// Reverse a [`map_apply`]; the prior state is reconstructible from
/// `(kind, result)` for every op.
fn map_undo(
    state: &mut std::collections::HashMap<u64, u64>,
    kind: MapOpKind,
    result: MapRes,
) {
    let restore = |state: &mut std::collections::HashMap<u64, u64>,
                   k: u64,
                   prev: Option<u64>| {
        match prev {
            Some(v) => {
                state.insert(k, v);
            }
            None => {
                state.remove(&k);
            }
        }
    };
    match (kind, result) {
        (MapOpKind::Get(_), _) => {}
        (MapOpKind::Insert(k, _), MapRes::Val(prev))
        | (MapOpKind::Remove(k), MapRes::Val(prev)) => restore(state, k, prev),
        (MapOpKind::CmpEx(k, e, _), MapRes::Cas(Ok(()))) => {
            restore(state, k, e)
        }
        (MapOpKind::CmpEx(..), MapRes::Cas(Err(_))) => {}
        (MapOpKind::GetOrInsert(k, _), MapRes::Val(prev)) => {
            if prev.is_none() {
                state.remove(&k);
            }
        }
        (MapOpKind::FetchAdd(k, _), MapRes::Val(prev)) => {
            restore(state, k, prev)
        }
        _ => unreachable!("result shape mismatches op kind"),
    }
}

/// Is `history` linearizable with respect to sequential *map*
/// semantics, starting from the `initial` (key, value) pairs? Same
/// Wing & Gong search as [`is_linearizable`], over the richer state.
pub fn is_map_linearizable(initial: &[(u64, u64)], history: &[MapEvent]) -> bool {
    let n = history.len();
    assert!(n <= 64, "checker limited to 64-op windows");
    let mut state: std::collections::HashMap<u64, u64> =
        initial.iter().copied().collect();
    let mut done: u64 = 0;
    // Unlike the set checker, map states reached via different orders
    // of the same op subset can differ (last write wins), so the memo
    // is keyed on (done-mask, order-independent state hash).
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    let mut must_precede = vec![0u64; n];
    for j in 0..n {
        for i in 0..n {
            if i != j && history[i].response < history[j].invoke {
                must_precede[j] |= 1 << i;
            }
        }
    }

    fn state_hash(state: &std::collections::HashMap<u64, u64>) -> u64 {
        state.iter().fold(0u64, |acc, (&k, &v)| {
            acc ^ crate::util::hash::splitmix64(k ^ crate::util::hash::splitmix64(v))
        })
    }

    fn dfs(
        history: &[MapEvent],
        must_precede: &[u64],
        state: &mut std::collections::HashMap<u64, u64>,
        done: &mut u64,
        seen: &mut HashSet<(u64, u64)>,
    ) -> bool {
        let n = history.len();
        if done.count_ones() as usize == n {
            return true;
        }
        if !seen.insert((*done, state_hash(state))) {
            return false;
        }
        for j in 0..n {
            let bit = 1u64 << j;
            if *done & bit != 0 || (must_precede[j] & !*done) != 0 {
                continue;
            }
            let ev = &history[j];
            let got = map_apply(state, ev.kind);
            if got == ev.result {
                *done |= bit;
                if dfs(history, must_precede, state, done, seen) {
                    return true;
                }
                *done &= !bit;
            }
            map_undo(state, ev.kind, got);
        }
        false
    }

    dfs(history, &must_precede, &mut state, &mut done, &mut seen)
}

/// Record a concurrent history of random map ops (conditional ops
/// included) over a small key range against any
/// [`crate::maps::ConcurrentMap`], for [`is_map_linearizable`].
pub fn record_map_history(
    map: &dyn crate::maps::ConcurrentMap,
    threads: usize,
    ops_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<MapEvent> {
    use std::sync::Mutex;
    use std::time::Instant;
    let clock = Instant::now();
    let events: Mutex<Vec<MapEvent>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for tid in 0..threads {
            let events = &events;
            let clock = &clock;
            s.spawn(move || {
                let mut rng =
                    crate::util::rng::Rng::for_thread(seed, tid as u64);
                let mut local = Vec::with_capacity(ops_per_thread);
                // Tiny value/expectation domains so conditional hits,
                // misses, and witness mismatches all occur.
                let opt = |rng: &mut crate::util::rng::Rng| {
                    if rng.below(3) == 0 {
                        None
                    } else {
                        Some(rng.below(4))
                    }
                };
                for _ in 0..ops_per_thread {
                    let k = 1 + rng.below(keys);
                    let kind = match rng.below(8) {
                        0 => MapOpKind::Get(k),
                        1 => MapOpKind::Insert(k, rng.below(4)),
                        2 => MapOpKind::Remove(k),
                        3 | 4 => MapOpKind::CmpEx(k, opt(&mut rng), opt(&mut rng)),
                        5 => MapOpKind::GetOrInsert(k, rng.below(4)),
                        _ => MapOpKind::FetchAdd(k, 1 + rng.below(2)),
                    };
                    let invoke = clock.elapsed().as_nanos() as u64;
                    let result = match kind {
                        MapOpKind::Get(k) => MapRes::Val(map.get(k)),
                        MapOpKind::Insert(k, v) => MapRes::Val(map.insert(k, v)),
                        MapOpKind::Remove(k) => MapRes::Val(map.remove(k)),
                        MapOpKind::CmpEx(k, e, n) => {
                            MapRes::Cas(map.compare_exchange(k, e, n))
                        }
                        MapOpKind::GetOrInsert(k, v) => {
                            MapRes::Val(map.get_or_insert(k, v))
                        }
                        MapOpKind::FetchAdd(k, d) => {
                            MapRes::Val(map.fetch_add(k, d))
                        }
                    };
                    let response = clock.elapsed().as_nanos() as u64;
                    local.push(MapEvent { kind, result, invoke, response });
                }
                events.lock().unwrap().extend(local);
            });
        }
    });
    let mut h = events.into_inner().unwrap();
    h.sort_by_key(|e| e.invoke);
    h
}

// ---- transactional histories (the multi-key `apply_txn` surface) ----

impl From<crate::maps::MapOp> for MapOpKind {
    fn from(op: crate::maps::MapOp) -> Self {
        use crate::maps::MapOp as O;
        match op {
            O::Get(k) => MapOpKind::Get(k),
            O::Insert(k, v) => MapOpKind::Insert(k, v),
            O::Remove(k) => MapOpKind::Remove(k),
            O::CmpEx(k, e, n) => MapOpKind::CmpEx(k, e, n),
            O::GetOrInsert(k, v) => MapOpKind::GetOrInsert(k, v),
            O::FetchAdd(k, d) => MapOpKind::FetchAdd(k, d),
        }
    }
}

impl From<crate::maps::MapReply> for MapRes {
    fn from(r: crate::maps::MapReply) -> Self {
        use crate::maps::MapReply as R;
        match r {
            R::Value(v)
            | R::Prev(v)
            | R::Removed(v)
            | R::Existing(v)
            | R::Added(v) => MapRes::Val(v),
            R::CmpEx(c) => MapRes::Cas(c),
        }
    }
}

/// One event in a transactional map history: a lone map op, or a whole
/// multi-key transaction occupying a *single* atomic window.
#[derive(Clone, Debug)]
pub enum TxnEventKind {
    /// A plain single-key operation with its observed result.
    Op(MapOpKind, MapRes),
    /// A committed transaction: every op took effect at one
    /// linearization point, in program order, and each reply reflects
    /// the ops before it within the same transaction (overlay
    /// semantics, matching [`crate::maps::ConcurrentMap::apply_txn`]).
    Committed(Vec<(MapOpKind, MapRes)>),
    /// An aborted transaction. All-or-nothing means it changed
    /// nothing, so it may linearize anywhere as a no-op.
    Aborted,
}

/// One completed event (op or transaction) in a history.
#[derive(Clone, Debug)]
pub struct TxnEvent {
    pub kind: TxnEventKind,
    pub invoke: u64,
    pub response: u64,
}

/// Apply a whole committed transaction at one sequential point; on any
/// reply mismatch the applied prefix is rolled back and `false`
/// returned (state unchanged).
fn txn_apply(
    state: &mut std::collections::HashMap<u64, u64>,
    ops: &[(MapOpKind, MapRes)],
) -> bool {
    for i in 0..ops.len() {
        let got = map_apply(state, ops[i].0);
        if got != ops[i].1 {
            map_undo(state, ops[i].0, got);
            for j in (0..i).rev() {
                map_undo(state, ops[j].0, ops[j].1);
            }
            return false;
        }
    }
    true
}

fn txn_undo(
    state: &mut std::collections::HashMap<u64, u64>,
    ops: &[(MapOpKind, MapRes)],
) {
    for j in (0..ops.len()).rev() {
        map_undo(state, ops[j].0, ops[j].1);
    }
}

/// Is a mixed single-op / transaction history linearizable against
/// sequential map semantics? A committed transaction is one indivisible
/// step: either a linearization order explains every reply of every
/// event, or the history is rejected — a reader (or another
/// transaction) observing *half* of a transaction's writes is exactly
/// the torn state this rules out.
pub fn is_txn_linearizable(
    initial: &[(u64, u64)],
    history: &[TxnEvent],
) -> bool {
    let n = history.len();
    assert!(n <= 64, "checker limited to 64-event windows");
    let mut state: std::collections::HashMap<u64, u64> =
        initial.iter().copied().collect();
    let mut done: u64 = 0;
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    let mut must_precede = vec![0u64; n];
    for j in 0..n {
        for i in 0..n {
            if i != j && history[i].response < history[j].invoke {
                must_precede[j] |= 1 << i;
            }
        }
    }

    fn state_hash(state: &std::collections::HashMap<u64, u64>) -> u64 {
        state.iter().fold(0u64, |acc, (&k, &v)| {
            acc ^ crate::util::hash::splitmix64(
                k ^ crate::util::hash::splitmix64(v),
            )
        })
    }

    fn dfs(
        history: &[TxnEvent],
        must_precede: &[u64],
        state: &mut std::collections::HashMap<u64, u64>,
        done: &mut u64,
        seen: &mut HashSet<(u64, u64)>,
    ) -> bool {
        let n = history.len();
        if done.count_ones() as usize == n {
            return true;
        }
        if !seen.insert((*done, state_hash(state))) {
            return false;
        }
        for j in 0..n {
            let bit = 1u64 << j;
            if *done & bit != 0 || (must_precede[j] & !*done) != 0 {
                continue;
            }
            let ok = match &history[j].kind {
                TxnEventKind::Op(kind, want) => {
                    let got = map_apply(state, *kind);
                    if got == *want {
                        true
                    } else {
                        map_undo(state, *kind, got);
                        false
                    }
                }
                TxnEventKind::Committed(ops) => txn_apply(state, ops),
                TxnEventKind::Aborted => true,
            };
            if ok {
                *done |= bit;
                if dfs(history, must_precede, state, done, seen) {
                    return true;
                }
                *done &= !bit;
                match &history[j].kind {
                    TxnEventKind::Op(kind, want) => {
                        map_undo(state, *kind, *want)
                    }
                    TxnEventKind::Committed(ops) => txn_undo(state, ops),
                    TxnEventKind::Aborted => {}
                }
            }
        }
        false
    }

    dfs(history, &must_precede, &mut state, &mut done, &mut seen)
}

/// Record a concurrent history mixing lone map ops with small
/// multi-key transactions against any
/// [`crate::maps::ConcurrentMap`], for [`is_txn_linearizable`].
/// Aborted transactions (any `Err` from `apply_txn`) are recorded as
/// no-op [`TxnEventKind::Aborted`] events.
pub fn record_txn_history(
    map: &dyn crate::maps::ConcurrentMap,
    threads: usize,
    events_per_thread: usize,
    keys: u64,
    seed: u64,
) -> Vec<TxnEvent> {
    use crate::maps::MapOp;
    use std::sync::Mutex;
    use std::time::Instant;
    let clock = Instant::now();
    let events: Mutex<Vec<TxnEvent>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for tid in 0..threads {
            let events = &events;
            let clock = &clock;
            s.spawn(move || {
                let mut rng =
                    crate::util::rng::Rng::for_thread(seed, tid as u64);
                let mut local = Vec::with_capacity(events_per_thread);
                let opt = |rng: &mut crate::util::rng::Rng| {
                    if rng.below(3) == 0 {
                        None
                    } else {
                        Some(rng.below(4))
                    }
                };
                for _ in 0..events_per_thread {
                    if rng.below(2) == 0 {
                        // A lone op through the single-key surface, so
                        // the history interleaves both API layers.
                        let k = 1 + rng.below(keys);
                        let kind = match rng.below(6) {
                            0 => MapOpKind::Get(k),
                            1 => MapOpKind::Insert(k, rng.below(4)),
                            2 => MapOpKind::Remove(k),
                            3 => MapOpKind::FetchAdd(k, 1),
                            _ => MapOpKind::CmpEx(
                                k,
                                opt(&mut rng),
                                opt(&mut rng),
                            ),
                        };
                        let invoke = clock.elapsed().as_nanos() as u64;
                        let result = match kind {
                            MapOpKind::Get(k) => MapRes::Val(map.get(k)),
                            MapOpKind::Insert(k, v) => {
                                MapRes::Val(map.insert(k, v))
                            }
                            MapOpKind::Remove(k) => {
                                MapRes::Val(map.remove(k))
                            }
                            MapOpKind::CmpEx(k, e, n) => {
                                MapRes::Cas(map.compare_exchange(k, e, n))
                            }
                            MapOpKind::GetOrInsert(k, v) => {
                                MapRes::Val(map.get_or_insert(k, v))
                            }
                            MapOpKind::FetchAdd(k, d) => {
                                MapRes::Val(map.fetch_add(k, d))
                            }
                        };
                        let response = clock.elapsed().as_nanos() as u64;
                        local.push(TxnEvent {
                            kind: TxnEventKind::Op(kind, result),
                            invoke,
                            response,
                        });
                    } else {
                        // A 2–3-op transaction; structural ops
                        // (Insert/Remove) are in the mix so migration
                        // plans and abort paths are both exercised.
                        let len = 2 + rng.below(2) as usize;
                        let mut ops = Vec::with_capacity(len);
                        for _ in 0..len {
                            let k = 1 + rng.below(keys);
                            ops.push(match rng.below(6) {
                                0 => MapOp::Get(k),
                                1 => MapOp::Insert(k, rng.below(4)),
                                2 => MapOp::Remove(k),
                                3 => MapOp::FetchAdd(k, 1),
                                _ => MapOp::CmpEx(
                                    k,
                                    opt(&mut rng),
                                    opt(&mut rng),
                                ),
                            });
                        }
                        let invoke = clock.elapsed().as_nanos() as u64;
                        let res = map.apply_txn(&ops);
                        let response = clock.elapsed().as_nanos() as u64;
                        let kind = match res {
                            Ok(replies) => TxnEventKind::Committed(
                                ops.iter()
                                    .zip(replies)
                                    .map(|(&o, r)| (o.into(), r.into()))
                                    .collect(),
                            ),
                            Err(_) => TxnEventKind::Aborted,
                        };
                        local.push(TxnEvent { kind, invoke, response });
                    }
                }
                events.lock().unwrap().extend(local);
            });
        }
    });
    let mut h = events.into_inner().unwrap();
    h.sort_by_key(|e| e.invoke);
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: OpKind, result: bool, invoke: u64, response: u64) -> Event {
        Event { kind, result, invoke, response }
    }

    #[test]
    fn sequential_history_accepts() {
        let h = vec![
            ev(OpKind::Add(1), true, 0, 1),
            ev(OpKind::Contains(1), true, 2, 3),
            ev(OpKind::Remove(1), true, 4, 5),
            ev(OpKind::Contains(1), false, 6, 7),
        ];
        assert!(is_linearizable(&[], &h));
    }

    #[test]
    fn wrong_result_rejected() {
        let h = vec![
            ev(OpKind::Add(1), true, 0, 1),
            ev(OpKind::Contains(1), false, 2, 3), // impossible
        ];
        assert!(!is_linearizable(&[], &h));
    }

    #[test]
    fn overlap_allows_reordering() {
        // contains(1)=true overlaps add(1)=true: legal (add first).
        let h = vec![
            ev(OpKind::Add(1), true, 0, 10),
            ev(OpKind::Contains(1), true, 5, 6),
        ];
        assert!(is_linearizable(&[], &h));
        // But if they do NOT overlap and contains came first: illegal.
        let h2 = vec![
            ev(OpKind::Contains(1), true, 0, 1),
            ev(OpKind::Add(1), true, 2, 3),
        ];
        assert!(!is_linearizable(&[], &h2));
    }

    #[test]
    fn fig5_style_violation_rejected() {
        // Key 7 is in the set the whole time (nobody removes it), yet a
        // reader reports it absent: the Fig. 5 bug signature.
        let h = vec![
            ev(OpKind::Remove(3), true, 0, 10), // unrelated remove
            ev(OpKind::Contains(7), false, 2, 4), // 7 never absent!
        ];
        assert!(!is_linearizable(&[3, 7], &h));
    }

    #[test]
    fn duplicate_add_semantics() {
        let h = vec![
            ev(OpKind::Add(5), true, 0, 10),
            ev(OpKind::Add(5), true, 2, 12), // both true only if a remove splits them — none here
        ];
        assert!(!is_linearizable(&[], &h));
        let h2 = vec![
            ev(OpKind::Add(5), true, 0, 10),
            ev(OpKind::Remove(5), true, 2, 12),
            ev(OpKind::Add(5), true, 4, 14), // now legal
        ];
        assert!(is_linearizable(&[], &h2));
    }

    #[test]
    fn initial_state_respected() {
        let h = vec![ev(OpKind::Contains(9), true, 0, 1)];
        assert!(is_linearizable(&[9], &h));
        assert!(!is_linearizable(&[], &h));
    }

    fn mev(kind: MapOpKind, result: MapRes, invoke: u64, response: u64) -> MapEvent {
        MapEvent { kind, result, invoke, response }
    }

    #[test]
    fn map_sequential_rmw_history_accepts() {
        let h = vec![
            mev(MapOpKind::CmpEx(1, None, Some(5)), MapRes::Cas(Ok(())), 0, 1),
            mev(MapOpKind::FetchAdd(1, 2), MapRes::Val(Some(5)), 2, 3),
            mev(MapOpKind::GetOrInsert(1, 9), MapRes::Val(Some(7)), 4, 5),
            mev(
                MapOpKind::CmpEx(1, Some(7), None),
                MapRes::Cas(Ok(())),
                6,
                7,
            ),
            mev(MapOpKind::Get(1), MapRes::Val(None), 8, 9),
            mev(MapOpKind::FetchAdd(1, 3), MapRes::Val(None), 10, 11),
            mev(MapOpKind::Get(1), MapRes::Val(Some(3)), 12, 13),
        ];
        assert!(is_map_linearizable(&[], &h));
    }

    #[test]
    fn map_lost_increment_rejected() {
        // Two fetch_adds both report the same previous value without
        // overlapping — a lost update no valid linearization allows.
        let h = vec![
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 0, 1),
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 2, 3),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h));
        // Overlapping they'd still be invalid (each sees the other's
        // commit or not — but both claiming prev=5 loses one).
        let h2 = vec![
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 0, 10),
            mev(MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5)), 1, 9),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h2));
    }

    #[test]
    fn map_double_cmpex_win_rejected() {
        // Two compare_exchange(5->6) both succeed with no one restoring
        // 5 in between: impossible.
        let h = vec![
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                0,
                10,
            ),
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                1,
                9,
            ),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h));
    }

    #[test]
    fn map_cmpex_witness_respects_overlap() {
        // The failed CAS's witness (6) is only explicable if it
        // linearizes after the overlapping winner.
        let h = vec![
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                0,
                10,
            ),
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(7)),
                MapRes::Cas(Err(Some(6))),
                2,
                8,
            ),
        ];
        assert!(is_map_linearizable(&[(1, 5)], &h));
        // Without overlap in the wrong order it's rejected.
        let h2 = vec![
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(7)),
                MapRes::Cas(Err(Some(6))),
                0,
                1,
            ),
            mev(
                MapOpKind::CmpEx(1, Some(5), Some(6)),
                MapRes::Cas(Ok(())),
                2,
                3,
            ),
        ];
        assert!(!is_map_linearizable(&[(1, 5)], &h2));
    }

    fn tev(kind: TxnEventKind, invoke: u64, response: u64) -> TxnEvent {
        TxnEvent { kind, invoke, response }
    }

    #[test]
    fn txn_sequential_history_accepts() {
        // A transfer txn then reads that see both legs.
        let h = vec![
            tev(
                TxnEventKind::Committed(vec![
                    (MapOpKind::FetchAdd(1, 3), MapRes::Val(Some(10))),
                    (
                        MapOpKind::CmpEx(2, Some(10), Some(7)),
                        MapRes::Cas(Ok(())),
                    ),
                ]),
                0,
                1,
            ),
            tev(
                TxnEventKind::Op(MapOpKind::Get(1), MapRes::Val(Some(13))),
                2,
                3,
            ),
            tev(
                TxnEventKind::Op(MapOpKind::Get(2), MapRes::Val(Some(7))),
                4,
                5,
            ),
        ];
        assert!(is_txn_linearizable(&[(1, 10), (2, 10)], &h));
    }

    #[test]
    fn txn_overlay_reply_semantics() {
        // Within one txn, later ops observe earlier ops' effects.
        let h = vec![tev(
            TxnEventKind::Committed(vec![
                (MapOpKind::Insert(1, 5), MapRes::Val(None)),
                (MapOpKind::Get(1), MapRes::Val(Some(5))),
                (MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5))),
            ]),
            0,
            1,
        )];
        assert!(is_txn_linearizable(&[], &h));
        // A reply reflecting pre-txn state where an earlier op in the
        // same txn already wrote is rejected.
        let h2 = vec![tev(
            TxnEventKind::Committed(vec![
                (MapOpKind::Insert(1, 5), MapRes::Val(None)),
                (MapOpKind::Get(1), MapRes::Val(None)),
            ]),
            0,
            1,
        )];
        assert!(!is_txn_linearizable(&[], &h2));
    }

    #[test]
    fn txn_torn_read_rejected() {
        // A reader that sees leg one of a committed two-key write but
        // not leg two — with its reads ordered after each other in
        // real time — has no valid linearization.
        let write = TxnEventKind::Committed(vec![
            (MapOpKind::Insert(1, 1), MapRes::Val(None)),
            (MapOpKind::Insert(2, 1), MapRes::Val(None)),
        ]);
        let h = vec![
            tev(write.clone(), 0, 10),
            tev(
                TxnEventKind::Op(MapOpKind::Get(1), MapRes::Val(Some(1))),
                2,
                3,
            ),
            tev(TxnEventKind::Op(MapOpKind::Get(2), MapRes::Val(None)), 4, 5),
        ];
        assert!(!is_txn_linearizable(&[], &h));
        // Seeing both legs (or neither) is fine.
        let h2 = vec![
            tev(write, 0, 10),
            tev(
                TxnEventKind::Op(MapOpKind::Get(1), MapRes::Val(Some(1))),
                2,
                3,
            ),
            tev(
                TxnEventKind::Op(MapOpKind::Get(2), MapRes::Val(Some(1))),
                4,
                5,
            ),
        ];
        assert!(is_txn_linearizable(&[], &h2));
    }

    #[test]
    fn txn_aborted_is_a_noop() {
        // An abort between two reads changes nothing.
        let h = vec![
            tev(
                TxnEventKind::Op(MapOpKind::Get(1), MapRes::Val(Some(4))),
                0,
                1,
            ),
            tev(TxnEventKind::Aborted, 2, 3),
            tev(
                TxnEventKind::Op(MapOpKind::Get(1), MapRes::Val(Some(4))),
                4,
                5,
            ),
        ];
        assert!(is_txn_linearizable(&[(1, 4)], &h));
    }

    #[test]
    fn txn_double_spend_rejected() {
        // Two non-overlapping transfers both debiting from the same
        // prev balance lose an update, exactly like the single-key
        // lost-increment case but across a two-key window.
        let t = |inv: u64, rsp: u64| {
            tev(
                TxnEventKind::Committed(vec![
                    (MapOpKind::FetchAdd(1, 1), MapRes::Val(Some(5))),
                    (MapOpKind::FetchAdd(2, 1), MapRes::Val(Some(9))),
                ]),
                inv,
                rsp,
            )
        };
        assert!(!is_txn_linearizable(&[(1, 5), (2, 9)], &[t(0, 1), t(2, 3)]));
        assert!(!is_txn_linearizable(&[(1, 5), (2, 9)], &[t(0, 10), t(1, 9)]));
    }
}
