//! Minimal error plumbing (in-tree replacement for `anyhow` — external
//! crates are not available in this offline build).
//!
//! Provides the small subset the crate needs: a string-backed [`Error`]
//! that any `std::error::Error` converts into (so `?` works on io /
//! parse errors), the [`Context`]/`with_context` extension for both
//! `Result` and `Option`, and the [`crate::bail!`] macro.

use std::fmt;

/// A string-backed error. Like `anyhow::Error`, this deliberately does
/// NOT implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parses(s: &str) -> Result<u64> {
        let n: u64 = s.parse()?; // From<ParseIntError> via the blanket impl
        Ok(n)
    }

    fn bails(x: u64) -> Result<u64> {
        if x == 0 {
            bail!("zero is not allowed (got {x})");
        }
        Ok(x)
    }

    #[test]
    fn question_mark_on_std_errors() {
        assert_eq!(parses("42").unwrap(), 42);
        assert!(parses("nope").is_err());
    }

    #[test]
    fn bail_formats() {
        assert!(bails(1).is_ok());
        let e = bails(0).unwrap_err();
        assert!(e.to_string().contains("zero is not allowed (got 0)"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> =
            Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(7u32).context("fine").unwrap(), 7);
    }
}
