//! Mini property-testing driver.
//!
//! `proptest` is not available in this offline environment (DESIGN.md
//! inventory #16), so this module provides the subset we need: seeded
//! random case generation, many iterations, and *prefix-bisection
//! shrinking* for operation-sequence properties (the dominant shape of
//! our invariants: "for any op sequence, table behaviour == oracle").

use super::rng::Rng;

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Iteration-count knob for the heavyweight concurrency tests: divide
/// `n` by [`scale_div`] (default 1, so the normal `cargo test` run is
/// unchanged), never below 1. The ThreadSanitizer CI lane sets a
/// divisor so the instrumented test binaries finish in minutes while
/// still crossing every synchronization edge the full runs cross.
pub fn scaled(n: u64) -> u64 {
    scaled_by(n, scale_div())
}

/// The pure scaling rule behind [`scaled`]: `n / div`, floored at 1 so
/// no loop ever scales away entirely. Split out so it can be tested
/// without mutating process-global environment state.
pub fn scaled_by(n: u64, div: u64) -> u64 {
    (n / div.max(1)).max(1)
}

/// The `CRH_TEST_SCALE_DIV` env knob (1 when unset or malformed).
pub fn scale_div() -> u64 {
    std::env::var("CRH_TEST_SCALE_DIV")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&d| d >= 1)
        .unwrap_or(1)
}

/// Run `iters` random cases of a property over generated op sequences.
///
/// `gen` produces a case from an RNG; `test` checks it. On failure the
/// driver shrinks by prefix bisection (for `Vec` cases via the
/// [`Shrinkable`] impl) and panics with the smallest failing case's
/// seed, length, and message.
pub fn check<T, G, F>(name: &str, iters: u64, mut gen: G, mut test: F)
where
    T: Shrinkable + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    F: FnMut(&T) -> PropResult,
{
    let base_seed = 0xC0FF_EE00u64;
    for it in 0..iters {
        let seed = base_seed.wrapping_add(it);
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = test(&case) {
            // Shrink: repeatedly try smaller versions that still fail.
            let mut smallest = case;
            let mut smsg = msg;
            loop {
                let mut shrunk = None;
                for cand in smallest.shrink_candidates() {
                    if let Err(m) = test(&cand) {
                        shrunk = Some((cand, m));
                        break;
                    }
                }
                match shrunk {
                    Some((c, m)) => {
                        smallest = c;
                        smsg = m;
                    }
                    None => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, iter={it}):\n  \
                 {smsg}\n  minimal case: {smallest:?}"
            );
        }
    }
}

/// Types that can propose smaller failing candidates.
pub trait Shrinkable: Sized + Clone {
    fn shrink_candidates(&self) -> Vec<Self>;
}

impl<T: Clone + std::fmt::Debug> Shrinkable for Vec<T> {
    fn shrink_candidates(&self) -> Vec<Self> {
        let n = self.len();
        if n == 0 {
            return vec![];
        }
        let mut out = Vec::new();
        // Halves, then drop-one-chunk, then drop-last.
        out.push(self[..n / 2].to_vec());
        out.push(self[n / 2..].to_vec());
        if n >= 4 {
            let q = n / 4;
            for i in 0..4 {
                let mut v = self.clone();
                v.drain(i * q..((i + 1) * q).min(n));
                out.push(v);
            }
        }
        out.push(self[..n - 1].to_vec());
        out.retain(|v| v.len() < n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        check(
            "always true",
            50,
            |r| vec![r.next_u64() % 10],
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert!(count >= 50);
    }

    #[test]
    #[should_panic(expected = "property 'has no 7'")]
    fn failing_property_panics_with_name() {
        check(
            "has no 7",
            100,
            |r| (0..20).map(|_| r.next_u64() % 10).collect::<Vec<_>>(),
            |v| {
                if v.contains(&7) {
                    Err("found a 7".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Capture the panic message and verify the minimal case is tiny.
        let res = std::panic::catch_unwind(|| {
            check(
                "no value above 100",
                100,
                |r| (0..64).map(|_| r.next_u64() % 200).collect::<Vec<_>>(),
                |v| {
                    if v.iter().any(|&x| x > 100) {
                        Err("big".into())
                    } else {
                        Ok(())
                    }
                },
            );
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // Minimal failing vec should have shrunk well below 64 elements.
        let after = msg.split("minimal case: ").nth(1).unwrap();
        let commas = after.matches(',').count();
        assert!(commas < 16, "did not shrink: {msg}");
    }

    #[test]
    fn shrink_candidates_are_strictly_smaller() {
        let v: Vec<u32> = (0..10).collect();
        for c in v.shrink_candidates() {
            assert!(c.len() < v.len());
        }
        assert!(Vec::<u32>::new().shrink_candidates().is_empty());
    }
}
