//! Always-on, dependency-free telemetry: sharded relaxed-atomic
//! counters and power-of-two log-histograms behind a global registry
//! of static names.
//!
//! The paper's core claims — low expected probe length, bounded K-CAS
//! retry cost, non-blocking migration — are runtime *distributions*,
//! and this module is how the tree observes them outside a benchmark
//! post-mortem: every layer (kcas, maps, resize engine, service
//! front-ends) increments the named metrics below on its hot paths,
//! and the aggregate is
//!
//! * served live over the wire (`STATS` verb, both front-ends, one
//!   compact JSON line rendered via [`crate::util::json`]),
//! * dumped by the `crh stats` CLI client, and
//! * snapshot-diffed around every benchmark cell so `BENCH_<fig>.json`
//!   carries a per-cell `metrics` section (probe-length p50/p99,
//!   K-CAS retry rate, stripes drained, ...) that `crh bench-compare`
//!   can use to *attribute* a throughput shift.
//!
//! ## Cost model
//!
//! A [`Counter`] is `SHARDS` cache-line-padded `AtomicU64`s; threads
//! pick a fixed shard on first use, so the hot path is one relaxed
//! `fetch_add` on a line the thread effectively owns. A [`Hist`] is 48
//! plain atomic buckets using **exactly** the `LatencyHist` bucket
//! scheme (`b = 63 - v.leading_zeros()`, clamped to 47; quantiles
//! report the geometric bucket midpoint `2^b * sqrt(2)` clamped to the
//! observed max) so histogram numbers are comparable across the bench
//! driver and this module.
//!
//! Recording is gated on [`enabled`]: `CRH_METRICS=0` (or `false` /
//! `off`) turns every `add`/`record` into a single relaxed load + a
//! predictable branch — near-zero cost, verified by the size
//! assertions below and the behavior tests in `tests/metrics_stats.rs`.
//! The default is **on**: telemetry you have to remember to enable is
//! telemetry you won't have when you need it.
//!
//! Environment can't vary `cfg` at compile time in a dependency-free
//! crate, so "compiled out" here means the flag is read once, cached
//! in a static, and every record site early-outs on it; the counters
//! themselves live in static storage either way (they add nothing to
//! any table or connection struct — see the `const` size assertions).

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

use crate::util::json::Json;
use crate::util::pad::CachePadded;

/// Counter shards (power of two). 16 lines bounds same-line sharing to
/// 1/16th of threads even on large boxes while keeping a full
/// [`Metrics`] table a few tens of KiB of static storage.
pub const SHARDS: usize = 16;

/// Histogram buckets — identical to `bench::driver::LatencyHist`
/// (`buckets[b]` counts values in `[2^b, 2^(b+1))`).
pub const BUCKETS: usize = 48;

// ---------------------------------------------------------------- gate

/// Tri-state cached `CRH_METRICS` gate: 0 = unread, 1 = off, 2 = on.
static GATE: AtomicU8 = AtomicU8::new(0);

#[cold]
fn gate_init() -> bool {
    let on = match std::env::var("CRH_METRICS") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            !matches!(v.as_str(), "0" | "false" | "off" | "no")
        }
        Err(_) => true,
    };
    GATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
    on
}

/// Is recording enabled? One relaxed load on the hot path.
#[inline(always)]
pub fn enabled() -> bool {
    match GATE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => gate_init(),
    }
}

/// Force the gate (tests and diagnostics; normal code never calls
/// this). Counters keep their values — disabling merely freezes them,
/// which is what makes byte-identical `STATS` replies testable.
pub fn set_enabled(on: bool) {
    GATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

// ------------------------------------------------------------- counter

/// Monotonic sharded counter: one cache line per shard, relaxed adds,
/// summed on read. Writers never contend with readers.
pub struct Counter {
    shards: [CachePadded<AtomicU64>; SHARDS],
}

// One line per shard, no hidden fields: the whole point of the
// padding. Guards against a refactor quietly packing shards together.
const _: () = assert!(
    std::mem::size_of::<Counter>()
        == SHARDS * std::mem::size_of::<CachePadded<AtomicU64>>()
);

/// Round-robin shard assignment; a thread keeps its first shard for
/// life so its counter line stays in its own cache.
static NEXT_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static MY_SHARD: usize =
        NEXT_SHARD.fetch_add(1, Ordering::Relaxed) & (SHARDS - 1);
}

#[inline]
fn my_shard() -> usize {
    MY_SHARD.with(|s| *s)
}

impl Counter {
    pub const fn new() -> Self {
        // The const is a deliberate array-init template: each use site
        // copies a fresh zeroed atomic (exactly what [ZERO; SHARDS]
        // needs), never shares one — the lint's sharing hazard can't
        // occur.
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: CachePadded<AtomicU64> =
            CachePadded::new(AtomicU64::new(0));
        Counter { shards: [ZERO; SHARDS] }
    }

    /// Add `n` (no-op when the gate is off).
    #[inline]
    pub fn add(&self, n: u64) {
        if !enabled() || n == 0 {
            return;
        }
        self.shards[my_shard()].fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1 (no-op when the gate is off).
    #[inline]
    pub fn incr(&self) {
        if !enabled() {
            return;
        }
        self.shards[my_shard()].fetch_add(1, Ordering::Relaxed);
    }

    /// Current total (sum over shards; monotonic under concurrency).
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

// ----------------------------------------------------------- histogram

/// Power-of-two log-histogram with the `LatencyHist` bucket scheme,
/// made concurrent: plain (unpadded — adjacent values land in adjacent
/// buckets anyway) atomic buckets plus a relaxed running max.
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
    max: AtomicU64,
}

// Buckets + max and nothing else — `record` must stay two relaxed RMWs.
const _: () = assert!(std::mem::size_of::<Hist>() == (BUCKETS + 1) * 8);

impl Hist {
    pub const fn new() -> Self {
        // Array-init template const, as in Counter::new — every use
        // copies a fresh zeroed atomic, so no sharing can occur.
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Hist { buckets: [ZERO; BUCKETS], max: AtomicU64::new(0) }
    }

    /// Record one value (no-op when the gate is off). Bucket `b` holds
    /// `[2^b, 2^(b+1))`; 0 lands in bucket 0 with 1.
    #[inline]
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        let b = if v == 0 { 0 } else { 63 - v.leading_zeros() as usize };
        self.buckets[b.min(BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Copy out a point-in-time view (buckets read relaxed, one pass).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot { buckets, max: self.max.load(Ordering::Relaxed) }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

/// Plain-data view of a [`Hist`]: diffable, quantile-queryable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub max: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Quantile `q` (0 < q <= 1) as the geometric bucket midpoint
    /// `2^b * sqrt(2)` clamped to the observed max — the exact
    /// `LatencyHist::quantile_ns` rule, so numbers line up across the
    /// bench driver and the metrics plane. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let target = ((q * count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let mid = ((1u64 << b) as f64 * std::f64::consts::SQRT_2)
                    .round() as u64;
                return mid.min(self.max.max(1));
            }
        }
        self.max
    }

    /// Bucket-wise `self - earlier` (saturating: a counter reset can't
    /// produce phantom negative buckets). The max carries over from
    /// `self` — a running max cannot be un-seen by differencing.
    pub fn diff(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (i, dst) in buckets.iter_mut().enumerate() {
            *dst = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        HistSnapshot { buckets, max: self.max }
    }

    /// Merge two snapshots (used to pool the per-op-class probe
    /// histograms into one headline probe-length distribution).
    pub fn merged(&self, other: &HistSnapshot) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (i, dst) in buckets.iter_mut().enumerate() {
            *dst = self.buckets[i] + other.buckets[i];
        }
        HistSnapshot { buckets, max: self.max.max(other.max) }
    }
}

// ------------------------------------------------------------ registry

/// Every metric the tree exports, one static instance, grouped by
/// layer. Field order here *is* the wire order (see [`REGISTRY`]).
pub struct Metrics {
    // kcas
    /// K-CAS executions started (owner side, `kcas::kcas`).
    pub kcas_attempts: Counter,
    /// K-CAS executions that failed and will be retried by the caller.
    pub kcas_retries: Counter,
    /// Helping entries (`kcas::help_kcas`): another thread's descriptor
    /// encountered mid-probe and completed on its behalf.
    pub kcas_helps: Counter,
    /// Per-thread descriptor slot acquisitions (registry `alloc_tid`).
    pub kcas_descriptors: Counter,

    // maps
    /// Buckets examined per membership probe (contains / probe_mig).
    pub probe_len_read: Hist,
    /// Buckets examined per write-path probe (add / remove attempts).
    pub probe_len_write: Hist,
    /// Entries displaced ("stolen from the rich") by committed adds.
    pub rh_displacements: Counter,
    /// Probe steps spent walking over `FROZEN_TOMB` marks — the read
    /// cost of tombstone drift during a migration.
    pub tombstone_drift: Counter,
    /// Ops that hit a frozen bucket and re-routed through the resize
    /// engine's slow path.
    pub freeze_encounters: Counter,

    // resize engine
    /// 64-bucket migration stripes drained by helping ops.
    pub resize_stripes_drained: Counter,
    /// Keys transferred into a successor generation (one K-CAS each).
    pub resize_keys_migrated: Counter,
    /// Generations promoted (migrations completed).
    pub resize_generations: Counter,
    /// Wall time, in ns, from generation install to promotion (summed
    /// over migrations; divide by `resize_generations` for a mean).
    pub resize_wall_ns: Counter,

    // service
    /// Ops per decoded `B <n>` batch frame (both front-ends decode
    /// through the shared `service::frame` codec).
    pub batch_size: Hist,
    /// Frames decoded (ops, batches, errors, quits — every frame).
    pub frames_decoded: Counter,
    /// Reactor connections paused at the high-water mark.
    pub backpressure_pauses: Counter,
    /// Paused connections resumed after draining below low water.
    pub backpressure_resumes: Counter,
    /// Batches whose apply panicked and was contained (either backend).
    pub server_panics: Counter,
    /// Wire bytes, per direction and backend.
    pub bytes_in_thread: Counter,
    pub bytes_out_thread: Counter,
    pub bytes_in_epoll: Counter,
    pub bytes_out_epoll: Counter,
    pub bytes_in_uring: Counter,
    pub bytes_out_uring: Counter,
    /// Wire-path syscalls, per backend: every `read`/`write` on the
    /// thread server, every `epoll_*`/`read`/`write`/`accept` on the
    /// reactor, every `io_uring_setup`/`enter` on the uring backend.
    /// Divide by ops applied for the syscalls-per-op series fig17
    /// tracks — the number this whole backend exists to shrink.
    pub syscalls_thread: Counter,
    pub syscalls_epoll: Counter,
    pub syscalls_uring: Counter,
    /// SQEs submitted per `io_uring_enter` (batching in the submit
    /// direction) and CQEs drained per reap (completion direction).
    pub uring_sqe_batch: Hist,
    pub uring_cqe_batch: Hist,

    // transactions
    /// Commit attempts (every pass through a txn commit loop, all
    /// protocols: K-CAS-native, OCC baseline, 2PL baseline).
    pub txn_attempts: Counter,
    /// Attempts that observed interference and restarted.
    pub txn_retries: Counter,
    /// Transactions abandoned with `TxnError::TxnConflict` after the
    /// bounded structural-conflict retry budget.
    pub txn_conflicts: Counter,
    /// Transactions committed.
    pub txn_commits: Counter,
    /// Committed transactions whose key set spanned more than one
    /// shard of a `Sharded<T>` facade.
    pub txn_cross_shard: Counter,
    /// K-CAS entries (or locked words) per committed transaction — the
    /// "one K-CAS per commit" span the tentpole is named for.
    pub txn_span: Hist,
    /// Ops per transaction as submitted by the caller.
    pub txn_ops: Hist,
}

impl Metrics {
    const fn new() -> Self {
        Metrics {
            kcas_attempts: Counter::new(),
            kcas_retries: Counter::new(),
            kcas_helps: Counter::new(),
            kcas_descriptors: Counter::new(),
            probe_len_read: Hist::new(),
            probe_len_write: Hist::new(),
            rh_displacements: Counter::new(),
            tombstone_drift: Counter::new(),
            freeze_encounters: Counter::new(),
            resize_stripes_drained: Counter::new(),
            resize_keys_migrated: Counter::new(),
            resize_generations: Counter::new(),
            resize_wall_ns: Counter::new(),
            batch_size: Hist::new(),
            frames_decoded: Counter::new(),
            backpressure_pauses: Counter::new(),
            backpressure_resumes: Counter::new(),
            server_panics: Counter::new(),
            bytes_in_thread: Counter::new(),
            bytes_out_thread: Counter::new(),
            bytes_in_epoll: Counter::new(),
            bytes_out_epoll: Counter::new(),
            bytes_in_uring: Counter::new(),
            bytes_out_uring: Counter::new(),
            syscalls_thread: Counter::new(),
            syscalls_epoll: Counter::new(),
            syscalls_uring: Counter::new(),
            uring_sqe_batch: Hist::new(),
            uring_cqe_batch: Hist::new(),
            txn_attempts: Counter::new(),
            txn_retries: Counter::new(),
            txn_conflicts: Counter::new(),
            txn_commits: Counter::new(),
            txn_cross_shard: Counter::new(),
            txn_span: Hist::new(),
            txn_ops: Hist::new(),
        }
    }
}

static METRICS: Metrics = Metrics::new();

/// The global metrics table. Record sites call
/// `metrics().kcas_attempts.incr()` and similar.
#[inline]
pub fn metrics() -> &'static Metrics {
    &METRICS
}

/// A registry row: static name + which metric it names.
pub enum Metric {
    Counter(&'static Counter),
    Hist(&'static Hist),
}

/// Name -> metric, in stable export order (the order of every
/// `STATS` reply and snapshot `metrics` section).
pub static REGISTRY: &[(&str, Metric)] = &[
    ("kcas_attempts", Metric::Counter(&METRICS.kcas_attempts)),
    ("kcas_retries", Metric::Counter(&METRICS.kcas_retries)),
    ("kcas_helps", Metric::Counter(&METRICS.kcas_helps)),
    ("kcas_descriptors", Metric::Counter(&METRICS.kcas_descriptors)),
    ("probe_len_read", Metric::Hist(&METRICS.probe_len_read)),
    ("probe_len_write", Metric::Hist(&METRICS.probe_len_write)),
    ("rh_displacements", Metric::Counter(&METRICS.rh_displacements)),
    ("tombstone_drift", Metric::Counter(&METRICS.tombstone_drift)),
    ("freeze_encounters", Metric::Counter(&METRICS.freeze_encounters)),
    (
        "resize_stripes_drained",
        Metric::Counter(&METRICS.resize_stripes_drained),
    ),
    (
        "resize_keys_migrated",
        Metric::Counter(&METRICS.resize_keys_migrated),
    ),
    ("resize_generations", Metric::Counter(&METRICS.resize_generations)),
    ("resize_wall_ns", Metric::Counter(&METRICS.resize_wall_ns)),
    ("batch_size", Metric::Hist(&METRICS.batch_size)),
    ("frames_decoded", Metric::Counter(&METRICS.frames_decoded)),
    (
        "backpressure_pauses",
        Metric::Counter(&METRICS.backpressure_pauses),
    ),
    (
        "backpressure_resumes",
        Metric::Counter(&METRICS.backpressure_resumes),
    ),
    ("server_panics", Metric::Counter(&METRICS.server_panics)),
    ("bytes_in_thread", Metric::Counter(&METRICS.bytes_in_thread)),
    ("bytes_out_thread", Metric::Counter(&METRICS.bytes_out_thread)),
    ("bytes_in_epoll", Metric::Counter(&METRICS.bytes_in_epoll)),
    ("bytes_out_epoll", Metric::Counter(&METRICS.bytes_out_epoll)),
    ("bytes_in_uring", Metric::Counter(&METRICS.bytes_in_uring)),
    ("bytes_out_uring", Metric::Counter(&METRICS.bytes_out_uring)),
    ("syscalls_thread", Metric::Counter(&METRICS.syscalls_thread)),
    ("syscalls_epoll", Metric::Counter(&METRICS.syscalls_epoll)),
    ("syscalls_uring", Metric::Counter(&METRICS.syscalls_uring)),
    ("uring_sqe_batch", Metric::Hist(&METRICS.uring_sqe_batch)),
    ("uring_cqe_batch", Metric::Hist(&METRICS.uring_cqe_batch)),
    ("txn_attempts", Metric::Counter(&METRICS.txn_attempts)),
    ("txn_retries", Metric::Counter(&METRICS.txn_retries)),
    ("txn_conflicts", Metric::Counter(&METRICS.txn_conflicts)),
    ("txn_commits", Metric::Counter(&METRICS.txn_commits)),
    ("txn_cross_shard", Metric::Counter(&METRICS.txn_cross_shard)),
    ("txn_span", Metric::Hist(&METRICS.txn_span)),
    ("txn_ops", Metric::Hist(&METRICS.txn_ops)),
];

// ------------------------------------------------------------ snapshot

/// Point-in-time copy of every registered metric, in registry order.
/// `diff` two of these around a region to attribute its cost.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub hists: Vec<(&'static str, HistSnapshot)>,
}

/// Capture the current value of every registered metric.
pub fn snapshot() -> Snapshot {
    let mut counters = Vec::new();
    let mut hists = Vec::new();
    for (name, m) in REGISTRY {
        match m {
            Metric::Counter(c) => counters.push((*name, c.get())),
            Metric::Hist(h) => hists.push((*name, h.snapshot())),
        }
    }
    Snapshot { counters, hists }
}

impl Snapshot {
    /// `self - earlier`, name-wise (saturating). Both snapshots come
    /// from the same static registry, so the name lists always align.
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|&(name, v)| {
                let base = earlier
                    .counters
                    .iter()
                    .find(|&&(n, _)| n == name)
                    .map_or(0, |&(_, b)| b);
                (name, v.saturating_sub(base))
            })
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(name, h)| {
                let diffed = match earlier.hists.iter().find(|(n, _)| n == name)
                {
                    Some((_, base)) => h.diff(base),
                    None => h.clone(),
                };
                (*name, diffed)
            })
            .collect();
        Snapshot { counters, hists }
    }

    /// Counter value by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|&&(n, _)| n == name)
            .map_or(0, |&(_, v)| v)
    }

    /// Histogram snapshot by name.
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// JSON rendering shared by the `STATS` wire verb, `crh stats`,
    /// and diagnostics: counters as a flat object, histograms as
    /// `{count, p50, p99, max}` summaries (full buckets stay
    /// in-process — quantiles are what a wire consumer can act on).
    pub fn to_json(&self) -> Json {
        let counters = Json::obj(
            self.counters
                .iter()
                .map(|&(n, v)| (n, Json::Num(v as f64)))
                .collect(),
        );
        let hists = Json::obj(
            self.hists
                .iter()
                .map(|(n, h)| {
                    (
                        *n,
                        Json::obj(vec![
                            ("count", Json::Num(h.count() as f64)),
                            ("p50", Json::Num(h.quantile(0.5) as f64)),
                            ("p99", Json::Num(h.quantile(0.99) as f64)),
                            ("max", Json::Num(h.max as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("enabled", Json::Bool(enabled())),
            ("counters", counters),
            ("histograms", hists),
        ])
    }
}

/// The `STATS` wire reply: the full registry as **one compact JSON
/// line** (the wire protocol is line-oriented). Identical code path on
/// both front-ends, hence identical schema — the fig17-style
/// equivalence assertion depends on it.
pub fn stats_line() -> String {
    snapshot().to_json().render_compact()
}

// -------------------------------------------------- bench integration

/// Headline per-cell metrics for `BENCH_<fig>.json`: reduce a
/// [`Snapshot::diff`] spanning one benchmark cell to the scalar series
/// `bench-compare` tracks across runs. Empty when the gate is off (an
/// all-zero section would read as "measured, and zero", which is the
/// opposite of the truth).
pub fn cell_metrics(d: &Snapshot) -> Vec<(String, f64)> {
    if !enabled() {
        return Vec::new();
    }
    let mut out: Vec<(String, f64)> = Vec::new();
    let probes = match (d.hist("probe_len_read"), d.hist("probe_len_write")) {
        (Some(r), Some(w)) => r.merged(w),
        (Some(r), None) => r.clone(),
        (None, Some(w)) => w.clone(),
        (None, None) => HistSnapshot { buckets: [0; BUCKETS], max: 0 },
    };
    if probes.count() > 0 {
        out.push(("probe_p50".into(), probes.quantile(0.5) as f64));
        out.push(("probe_p99".into(), probes.quantile(0.99) as f64));
    }
    let attempts = d.counter("kcas_attempts");
    if attempts > 0 {
        let rate = d.counter("kcas_retries") as f64 / attempts as f64;
        out.push(("kcas_retry_rate".into(), rate));
    }
    out.push((
        "stripes_drained".into(),
        d.counter("resize_stripes_drained") as f64,
    ));
    out.push((
        "keys_migrated".into(),
        d.counter("resize_keys_migrated") as f64,
    ));
    out.push((
        "freeze_encounters".into(),
        d.counter("freeze_encounters") as f64,
    ));
    let wall_ns = d.counter("resize_wall_ns");
    if wall_ns > 0 {
        out.push(("migration_ms".into(), wall_ns as f64 / 1.0e6));
    }
    for name in ["syscalls_thread", "syscalls_epoll", "syscalls_uring"] {
        let n = d.counter(name);
        if n > 0 {
            out.push((name.into(), n as f64));
        }
    }
    for name in ["uring_sqe_batch", "uring_cqe_batch"] {
        if let Some(h) = d.hist(name) {
            if h.count() > 0 {
                out.push((format!("{name}_p50"), h.quantile(0.5) as f64));
            }
        }
    }
    let commits = d.counter("txn_commits");
    if commits > 0 {
        out.push(("txn_commits".into(), commits as f64));
        let attempts = d.counter("txn_attempts");
        if attempts > 0 {
            out.push((
                "txn_retry_rate".into(),
                d.counter("txn_retries") as f64 / attempts as f64,
            ));
        }
        out.push((
            "txn_cross_shard_frac".into(),
            d.counter("txn_cross_shard") as f64 / commits as f64,
        ));
        if let Some(h) = d.hist("txn_span") {
            if h.count() > 0 {
                out.push(("txn_span_p50".into(), h.quantile(0.5) as f64));
            }
        }
    }
    out
}

/// Capture-diff convenience: metrics delta across `f()`, reduced to
/// the headline series. Returns `(f's result, cell metrics)`.
pub fn measured<R>(f: impl FnOnce() -> R) -> (R, Vec<(String, f64)>) {
    let before = snapshot();
    let r = f();
    let d = snapshot().diff(&before);
    (r, cell_metrics(&d))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The gate is process-global; tests that flip it hold this lock so
    // they serialize against each other (other tests in this binary
    // never assert on global metric *values*).
    static GATE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn counter_sums_across_shards() {
        let _g = GATE_LOCK.lock().unwrap();
        set_enabled(true);
        let c = Counter::new();
        c.add(5);
        c.incr();
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn disabled_gate_freezes_counters_and_hists() {
        let _g = GATE_LOCK.lock().unwrap();
        set_enabled(false);
        let c = Counter::new();
        let h = Hist::new();
        c.add(7);
        c.incr();
        h.record(100);
        assert_eq!(c.get(), 0);
        assert_eq!(h.snapshot().count(), 0);
        set_enabled(true);
        c.incr();
        h.record(100);
        assert_eq!(c.get(), 1);
        assert_eq!(h.snapshot().count(), 1);
    }

    #[test]
    fn hist_bucket_scheme_matches_latency_hist() {
        let _g = GATE_LOCK.lock().unwrap();
        set_enabled(true);
        let h = Hist::new();
        // 0 and 1 share bucket 0; 2..4 bucket 1; 1000 sits in
        // [512, 1024) => geometric midpoint 724 (the LatencyHist test
        // vector).
        for _ in 0..300 {
            h.record(1);
        }
        for _ in 0..300 {
            h.record(1000);
        }
        let s = h.snapshot();
        assert_eq!(s.buckets[0], 300);
        assert_eq!(s.buckets[9], 300);
        assert_eq!(s.quantile(0.5), 1);
        assert_eq!(s.quantile(0.99), 724);
        assert_eq!(s.max, 1000);
    }

    #[test]
    fn snapshot_diff_is_the_delta() {
        let _g = GATE_LOCK.lock().unwrap();
        set_enabled(true);
        let before = snapshot();
        metrics().kcas_attempts.add(3);
        metrics().probe_len_read.record(4);
        let d = snapshot().diff(&before);
        assert_eq!(d.counter("kcas_attempts"), 3);
        assert_eq!(d.hist("probe_len_read").unwrap().count(), 1);
        assert_eq!(d.counter("server_panics"), 0);
    }

    #[test]
    fn registry_names_are_unique_and_snapshot_covers_them() {
        let mut names: Vec<&str> = REGISTRY.iter().map(|&(n, _)| n).collect();
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate metric name");
        let s = snapshot();
        assert_eq!(s.counters.len() + s.hists.len(), total);
    }

    #[test]
    fn stats_line_is_one_line_of_parseable_json() {
        let line = stats_line();
        assert!(!line.contains('\n'));
        let v = Json::parse(&line).expect("STATS line parses");
        assert!(v.get("counters").is_some());
        assert!(v.get("histograms").is_some());
    }
}
