//! Experiment coordinator: one entry point per paper figure/table,
//! plus ad-hoc benchmark cells and the probe-statistics analysis that
//! runs through the PJRT engine. The CLI in `main.rs` dispatches here.
//!
//! Every figure/table entry point measures into typed
//! [`CellResult`]s and returns a [`BenchReport`]; the human-readable
//! tables print *from* those cells, and the callers (bench mains, the
//! CLI) hand the same report to `bench::report::write_if_enabled` so a
//! `CRH_BENCH_JSON=1` / `--json` run leaves a `BENCH_<fig>.json`
//! perf-trajectory snapshot behind.

use std::time::Duration;

use crate::bench::report::{BenchReport, CellResult, LatencySummary, Stat};
use crate::bench::{driver, workload::{KeyDist, WorkloadCfg}, Mix};
use crate::cachesim;
use crate::maps::{MapKind, TableKind};

/// Shared experiment options (CLI-settable).
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Table size exponent. Paper: 23 (8M buckets, larger than cache).
    pub size_log2: u32,
    /// Per-cell measured duration.
    pub duration_ms: u64,
    /// Thread counts to sweep in scaling figures.
    pub threads: Vec<usize>,
    /// Pin threads to cores.
    pub pin: bool,
    /// Repetitions per cell (paper: 5). Cells record min/median/max
    /// across reps and the tables print the median, so one scheduler
    /// hiccup cannot become the recorded number.
    pub reps: u32,
}

impl Default for ExpOpts {
    fn default() -> Self {
        let max = crate::util::affinity::available_cpus();
        // Sweep 1..8 threads even on small machines: beyond the core
        // count this measures oversubscribed (time-sliced) behaviour,
        // which is the closest available proxy for the paper's
        // 144-thread sweeps on a 1-core container (see EXPERIMENTS.md).
        let mut threads = vec![1, 2, 4, 8];
        let mut t = 16;
        while t <= max {
            threads.push(t);
            t *= 2;
        }
        if threads.last() != Some(&max) && max > 8 {
            threads.push(max);
        }
        threads.dedup();
        Self {
            size_log2: 23,
            duration_ms: 2000,
            threads,
            pin: true,
            reps: 3,
        }
    }
}

/// The sweep options every snapshot records as its `spec`.
fn opts_spec(opts: &ExpOpts) -> Vec<(String, String)> {
    vec![
        ("size_log2".to_string(), opts.size_log2.to_string()),
        ("duration_ms".to_string(), opts.duration_ms.to_string()),
        (
            "threads".to_string(),
            opts.threads
                .iter()
                .map(|t| t.to_string())
                .collect::<Vec<_>>()
                .join(","),
        ),
        ("pin".to_string(), opts.pin.to_string()),
        ("reps".to_string(), opts.reps.to_string()),
    ]
}

/// Measure one set-workload cell `reps` times (distinct seeds) and
/// aggregate to min/median/max ops/µs, plus the telemetry delta the
/// cell's reps produced ([`crate::util::metrics::cell_metrics`];
/// empty when `CRH_METRICS=0`).
fn ops_stat(
    kind: TableKind,
    cfg: &WorkloadCfg,
    threads: usize,
    pin: bool,
    reps: u32,
) -> (Stat, Vec<(String, f64)>) {
    let (samples, mets) = crate::util::metrics::measured(|| {
        (0..reps.max(1))
            .map(|rep| {
                let mut c = *cfg;
                c.seed = cfg.seed.wrapping_add(rep as u64);
                driver::run(kind, &c, threads, pin).ops_per_us()
            })
            .collect::<Vec<f64>>()
    });
    (Stat::from_samples(&samples), mets)
}

/// **Figure 10**: single-core throughput of every table relative to
/// K-CAS Robin Hood across the 8 workload configurations. Snapshot
/// cells store *absolute* ops/µs stats; the printed table derives the
/// relative percentages from the cell medians.
pub fn fig10(opts: &ExpOpts) -> BenchReport {
    let mut report = BenchReport::new("fig10", opts_spec(opts));
    println!("# Figure 10 — single-core relative performance (K-CAS RH = 100%)");
    println!(
        "# table 2^{} buckets, {} ms/cell, {} rep(s)",
        opts.size_log2, opts.duration_ms, opts.reps
    );
    let grid = WorkloadCfg::paper_grid(opts.size_log2, opts.duration_ms);
    print!("{:<18}", "config");
    for cfg in &grid {
        print!(" {:>11}", cfg.label());
    }
    println!();
    let base: Vec<(Stat, Vec<(String, f64)>)> = grid
        .iter()
        .map(|cfg| {
            ops_stat(TableKind::KCasRobinHood, cfg, 1, opts.pin, opts.reps)
        })
        .collect();
    let mut kinds = vec![TableKind::KCasRobinHood];
    kinds.extend(
        TableKind::ALL_CONCURRENT
            .iter()
            .filter(|k| **k != TableKind::KCasRobinHood),
    );
    kinds.push(TableKind::SerialRobinHood);
    for kind in kinds {
        print!("{:<18}", kind.display());
        for (cfg, (b, b_mets)) in grid.iter().zip(&base) {
            let (stat, mets) = if kind == TableKind::KCasRobinHood {
                (*b, b_mets.clone())
            } else {
                ops_stat(kind, cfg, 1, opts.pin, opts.reps)
            };
            print!(" {:>10.0}%", 100.0 * stat.median / b.median);
            report.push(
                CellResult::new([
                    ("config", cfg.label()),
                    ("table", kind.name()),
                ])
                .with_ops(stat)
                .with_metrics(mets),
            );
        }
        println!();
    }
    report
}

/// One throughput table: header row of thread counts, one row per
/// table kind, one measured [`Stat`] per cell (shared by Figs. 11-13).
/// The table prints the median; the full stat lands in `report` under
/// `panel` labels + `table`/`threads`.
fn throughput_panel(
    rows: &[TableKind],
    cfg: &WorkloadCfg,
    opts: &ExpOpts,
    label: &str,
    width: usize,
    panel: &[(String, String)],
    report: &mut BenchReport,
) {
    print!("{label:<width$}");
    for &t in &opts.threads {
        print!(" {t:>9}");
    }
    println!();
    for &kind in rows {
        print!("{:<width$}", kind.display());
        for &t in &opts.threads {
            let (stat, mets) = ops_stat(kind, cfg, t, opts.pin, opts.reps);
            print!(" {:>9.2}", stat.median);
            let mut labels = panel.to_vec();
            labels.push(("table".to_string(), kind.name()));
            labels.push(("threads".to_string(), t.to_string()));
            report.push(
                CellResult::new(labels).with_ops(stat).with_metrics(mets),
            );
        }
        println!();
    }
}

/// Scaling panels shared by Figures 11 and 12.
fn scaling_panels(
    opts: &ExpOpts,
    lfs: &[f64],
    figure: &str,
    fig_id: &str,
) -> BenchReport {
    let mut report = BenchReport::new(fig_id, opts_spec(opts));
    println!(
        "# {figure} — throughput (ops/us) vs threads; table 2^{}, {} ms/cell",
        opts.size_log2, opts.duration_ms
    );
    for &lf in lfs {
        for mix in [Mix::LIGHT, Mix::HEAVY] {
            let cfg = WorkloadCfg::cell(
                opts.size_log2,
                lf,
                mix.update_pct,
                opts.duration_ms,
                0xFEED,
            );
            println!(
                "\n## panel: load factor {}%, updates {}%",
                (lf * 100.0) as u32,
                mix.update_pct
            );
            let panel = vec![
                ("lf".to_string(), ((lf * 100.0) as u32).to_string()),
                ("updates".to_string(), mix.update_pct.to_string()),
            ];
            throughput_panel(
                &TableKind::ALL_CONCURRENT,
                &cfg,
                opts,
                "threads",
                18,
                &panel,
                &mut report,
            );
        }
    }
    report
}

/// **Figure 11**: scaling at 20% and 40% load factor.
pub fn fig11(opts: &ExpOpts) -> BenchReport {
    scaling_panels(opts, &[0.2, 0.4], "Figure 11", "fig11")
}

/// **Figure 12**: scaling at 60% and 80% load factor.
pub fn fig12(opts: &ExpOpts) -> BenchReport {
    scaling_panels(opts, &[0.6, 0.8], "Figure 12", "fig12")
}

/// **Figure 13** (extension): the sharding sweep — throughput of the
/// [`crate::maps::sharded::Sharded`] facade across shard count x thread
/// count at the paper's high-load panels (60% and 80% LF, 10% updates),
/// with the unsharded K-CAS Robin Hood table as the baseline row.
/// Sharded rows keep the *total* capacity equal to the baseline, so
/// every row runs at the same load factor.
pub fn fig13_sharding(opts: &ExpOpts, shard_counts: &[u32]) -> BenchReport {
    let mut report = BenchReport::new("fig13", opts_spec(opts));
    println!(
        "# Figure 13 — sharded K-CAS RH throughput (ops/us) vs threads; \
         table 2^{} total, {} ms/cell, {} rep(s)",
        opts.size_log2, opts.duration_ms, opts.reps
    );
    println!("# shard counts: {shard_counts:?} (x1 = facade over one shard)");
    // Keep every shard at least 2^6 buckets so no sweep point can
    // saturate (or fail to construct) a shard.
    let shard_counts: Vec<u32> = shard_counts
        .iter()
        .copied()
        .filter(|&s| {
            let ok = s.is_power_of_two()
                && s.trailing_zeros() + 6 <= opts.size_log2;
            if !ok {
                println!(
                    "# skipping shard count {s}: not 2^k or too many \
                     shards for a 2^{} table",
                    opts.size_log2
                );
            }
            ok
        })
        .collect();
    let mut rows: Vec<TableKind> = vec![TableKind::KCasRobinHood];
    rows.extend(
        shard_counts
            .iter()
            .map(|&s| TableKind::ShardedKCasRh { shards: s }),
    );
    rows.extend(
        shard_counts
            .iter()
            .filter(|&&s| s > 1)
            .map(|&s| TableKind::ShardedResizableRh { shards: s }),
    );
    for &lf in &[0.6, 0.8] {
        let cfg = WorkloadCfg::cell(
            opts.size_log2,
            lf,
            Mix::LIGHT.update_pct,
            opts.duration_ms,
            0xF13,
        );
        println!(
            "\n## panel: load factor {}%, updates {}%",
            (lf * 100.0) as u32,
            Mix::LIGHT.update_pct
        );
        let panel = vec![
            ("lf".to_string(), ((lf * 100.0) as u32).to_string()),
            ("updates".to_string(), Mix::LIGHT.update_pct.to_string()),
        ];
        throughput_panel(
            &rows,
            &cfg,
            opts,
            "table \\ threads",
            26,
            &panel,
            &mut report,
        );
    }
    report
}

/// **Figure 14** (extension): the batching sweep — throughput of the
/// key→value service layer's batched pipeline
/// ([`crate::service::batch`]) across batch size x thread count, with
/// the unbatched op-by-op map calls as the baseline row. One panel per
/// update mix at the paper's 60% load factor; every cell rebuilds and
/// prefills the same [`MapKind`] so rows differ only in batching.
pub fn fig14_batching(
    opts: &ExpOpts,
    map: MapKind,
    batch_sizes: &[usize],
) -> BenchReport {
    use crate::service::batch::{prefill_map, run_batched};
    let mut report = BenchReport::new("fig14", opts_spec(opts));
    println!(
        "# Figure 14 — batched map pipeline throughput (ops/us) vs threads; \
         {} 2^{} total, {} ms/cell, {} rep(s)",
        map.display(),
        opts.size_log2,
        opts.duration_ms,
        opts.reps
    );
    let batch_sizes: Vec<usize> = batch_sizes
        .iter()
        .copied()
        .filter(|&b| {
            let ok = b >= 1;
            if !ok {
                println!("# skipping batch size 0 (that's the baseline row)");
            }
            ok
        })
        .collect();
    println!("# batch sizes: {batch_sizes:?}; baseline row = unbatched calls");
    for mix in [Mix::LIGHT, Mix::HEAVY] {
        let cfg = WorkloadCfg::cell(
            opts.size_log2,
            0.6,
            mix.update_pct,
            opts.duration_ms,
            0xF14,
        );
        println!(
            "\n## panel: load factor 60%, updates {}%",
            mix.update_pct
        );
        print!("{:<18}", "batch \\ threads");
        for &t in &opts.threads {
            print!(" {t:>9}");
        }
        println!();
        // batch == 0 is run_batched's unbatched-baseline sentinel.
        let rows: Vec<usize> =
            std::iter::once(0).chain(batch_sizes.iter().copied()).collect();
        for batch in rows {
            let label = if batch == 0 {
                "unbatched".to_string()
            } else {
                format!("batch={batch}")
            };
            print!("{label:<18}");
            for &t in &opts.threads {
                let (samples, mets) = crate::util::metrics::measured(|| {
                    (0..opts.reps.max(1))
                        .map(|rep| {
                            let mut c = cfg;
                            c.seed = cfg.seed.wrapping_add(rep as u64);
                            let m = map.build(c.size_log2);
                            prefill_map(m.as_ref(), &c);
                            run_batched(m.as_ref(), &c, t, batch, opts.pin)
                                .ops_per_us()
                        })
                        .collect::<Vec<f64>>()
                });
                let stat = Stat::from_samples(&samples);
                print!(" {:>9.2}", stat.median);
                report.push(
                    CellResult::new([
                        ("updates", mix.update_pct.to_string()),
                        (
                            "batch",
                            if batch == 0 {
                                "unbatched".to_string()
                            } else {
                                batch.to_string()
                            },
                        ),
                        ("threads", t.to_string()),
                    ])
                    .with_ops(stat)
                    .with_metrics(mets),
                );
            }
            println!();
        }
    }
    report
}

/// **Figure 15** (extension): the resize-engine comparison — per-op
/// latency **during an in-flight migration**, incremental
/// (two-generation cooperative migration,
/// [`crate::maps::resizable::IncResizableRobinHood`]) vs quiescing
/// (epoch-RwLock rebuild, [`crate::maps::resizable::QuiescingResize`]).
/// Each cell prefills to just below the grow threshold and runs an
/// add-biased mix over a key space 4x the initial capacity, so one or
/// more grows fire mid-measurement; every op's latency is recorded.
/// The quiescing engine's tail shows the stop-the-table rebuild; the
/// incremental engine's tail shows only the per-op helping stripe.
pub fn fig15_resize(opts: &ExpOpts, grow_ats: &[f64]) -> BenchReport {
    use crate::bench::driver::{run_latency, LatencyCfg, LatencyHist};
    use crate::maps::resizable::{IncResizableRobinHood, QuiescingResize};
    use crate::maps::ConcurrentSet;

    let mut report = BenchReport::new("fig15", opts_spec(opts));
    println!(
        "# Figure 15 — resize engines: op latency during migration; \
         table 2^{} initial, {} ms/cell, {} rep(s), 45% add / 10% rem",
        opts.size_log2, opts.duration_ms, opts.reps
    );
    let us = |ns: u64| format!("{:.1}", ns as f64 / 1000.0);
    for &grow_at in grow_ats {
        if !(0.2..0.95).contains(&grow_at) {
            println!("# skipping grow threshold {grow_at}: outside [0.2, 0.95)");
            continue;
        }
        println!("\n## panel: grow threshold {:.0}%", grow_at * 100.0);
        println!(
            "{:<14} {:>4} {:>10} {:>9} {:>9} {:>9} {:>11} {:>8}",
            "engine", "thr", "ops/us", "p50(us)", "p99(us)", "p999(us)",
            "max(us)", "grows"
        );
        for &threads in &opts.threads {
            for inc in [false, true] {
                let label = if inc { "incremental" } else { "quiescing" };
                let mut hist = LatencyHist::new();
                let mut samples = Vec::new();
                let mut grows = 0u32;
                let ((), cell_mets) = crate::util::metrics::measured(|| {
                    for rep in 0..opts.reps.max(1) {
                        let table: Box<dyn ConcurrentSet> = if inc {
                            Box::new(IncResizableRobinHood::with_threshold(
                                opts.size_log2,
                                grow_at,
                            ))
                        } else {
                            Box::new(QuiescingResize::with_threshold(
                                opts.size_log2,
                                grow_at,
                            ))
                        };
                        let cap0 = table.capacity();
                        let prefill = (grow_at * cap0 as f64 * 0.9) as u64;
                        for k in 1..=prefill {
                            table.add(k);
                        }
                        let cfg = LatencyCfg {
                            duration_ms: opts.duration_ms,
                            key_space: 4 * cap0 as u64,
                            add_pct: 45,
                            remove_pct: 10,
                            seed: 0xF15 + rep as u64,
                            pin: opts.pin,
                        };
                        let (r, h) =
                            run_latency(table.as_ref(), &cfg, threads);
                        hist.merge(&h);
                        samples.push(r.ops_per_us());
                        grows += (table.capacity() / cap0).trailing_zeros();
                    }
                });
                let note = if grows == 0 {
                    "  (!) no migration ran — raise --ms or lower threshold"
                } else {
                    ""
                };
                let stat = Stat::from_samples(&samples);
                let lat = LatencySummary::from_hist(&hist);
                println!(
                    "{:<14} {:>4} {:>10.2} {:>9} {:>9} {:>9} {:>11} {:>8}{}",
                    label,
                    threads,
                    stat.median,
                    us(lat.p50_ns),
                    us(lat.p99_ns),
                    us(lat.p999_ns),
                    us(lat.max_ns),
                    grows,
                    note
                );
                report.push(
                    CellResult::new([
                        ("grow_at", format!("{grow_at}")),
                        ("engine", label.to_string()),
                        ("threads", threads.to_string()),
                    ])
                    .with_ops(stat)
                    .with_latency(lat)
                    .with_extra("grows", grows as f64)
                    .with_metrics(cell_mets),
                );
            }
        }
    }
    report
}

/// **Figure 16** (extension): the conditional-RMW comparison — the
/// CAS-heavy counter workload (`service::batch::run_rmw`: 70%
/// `fetch_add`, 20% optimistic `get`+`compare_exchange`, 10% `get`)
/// across contention skew (hot-set size: fewer keys = hotter counters)
/// x thread count, native single-K-CAS conditionals on the Robin Hood
/// map vs the lock-based reference (`LockedLpMap`). Every cell also
/// *verifies* the primitives: the committed-increment count must equal
/// the final counter sum, or the cell panics — the experiment measures
/// the new API and proves its atomicity in the same run.
pub fn fig16_rmw(
    opts: &ExpOpts,
    maps: &[MapKind],
    hot_keys: &[u64],
) -> BenchReport {
    use crate::service::batch::{rmw_counter_sum, run_rmw};
    let mut report = BenchReport::new("fig16", opts_spec(opts));
    println!(
        "# Figure 16 — conditional RMW throughput under contention skew; \
         maps 2^{} buckets, {} ms/cell, {} rep(s)",
        opts.size_log2, opts.duration_ms, opts.reps
    );
    println!(
        "# mix: 70% fetch_add / 20% optimistic cmpex / 10% get; \
         hot-set sizes {hot_keys:?}"
    );
    for &keys in hot_keys {
        if keys == 0 {
            println!("# skipping hot-set size 0");
            continue;
        }
        println!("\n## panel: {keys} hot counter(s)");
        println!(
            "{:<26} {:>4} {:>10} {:>10} {:>9}",
            "map", "thr", "ops/us", "cas-fail%", "counters"
        );
        for &kind in maps {
            for &threads in &opts.threads {
                let mut samples = Vec::new();
                let mut attempts = 0u64;
                let mut fails = 0u64;
                let ((), mets) = crate::util::metrics::measured(|| {
                    for rep in 0..opts.reps.max(1) {
                        let m = kind.build(opts.size_log2);
                        let r = run_rmw(
                            m.as_ref(),
                            keys,
                            opts.duration_ms,
                            threads,
                            opts.pin,
                            0xF16 + rep as u64,
                        );
                        // The acceptance check: no committed increment
                        // may ever be lost or double-applied.
                        let sum = rmw_counter_sum(m.as_ref(), keys);
                        assert_eq!(
                            sum,
                            r.incs,
                            "{} keys={keys} thr={threads}: counters sum to \
                             {sum}, committed {} increments",
                            kind.name(),
                            r.incs
                        );
                        samples.push(r.run.ops_per_us());
                        attempts += r.cas_attempts;
                        fails += r.cas_failures;
                    }
                });
                let fail_pct = if attempts == 0 {
                    0.0
                } else {
                    100.0 * fails as f64 / attempts as f64
                };
                let stat = Stat::from_samples(&samples);
                println!(
                    "{:<26} {:>4} {:>10.2} {:>9.1}% {:>9}",
                    kind.display(),
                    threads,
                    stat.median,
                    fail_pct,
                    "OK"
                );
                report.push(
                    CellResult::new([
                        ("hot_keys", keys.to_string()),
                        ("map", kind.name()),
                        ("threads", threads.to_string()),
                    ])
                    .with_ops(stat)
                    .with_extra("cas_fail_pct", fail_pct)
                    .with_metrics(mets),
                );
            }
        }
    }
    report
}

/// Key space the fig17 clients draw from (small enough that the
/// default 2^16 map never approaches capacity).
const FIG17_KEYS: u64 = 10_000;
/// Frames each client keeps in flight (pipelining depth).
const FIG17_DEPTH: usize = 16;

fn fig17_map(size_log2: u32) -> std::sync::Arc<dyn crate::maps::ConcurrentMap> {
    std::sync::Arc::from(
        MapKind::ShardedKCasRhMap { shards: 4 }.build(size_log2),
    )
}

/// One client connection's worth of load: `frames` pipelined frames of
/// `batch` value-shaped ops (the conditional verbs ride along via
/// fetch-add), replies drained with [`FIG17_DEPTH`] frames in flight.
fn fig17_client(
    addr: std::net::SocketAddr,
    tid: u64,
    frames: usize,
    batch: usize,
) -> std::io::Result<u64> {
    use crate::maps::MapOp;
    use crate::service::server::Client;
    let mut c = Client::connect(addr)?;
    let mut r = crate::util::rng::Rng::for_thread(0xF17, tid);
    let mut ops: Vec<MapOp> = Vec::with_capacity(batch);
    let mut inflight = 0usize;
    for _ in 0..frames {
        ops.clear();
        for _ in 0..batch {
            let k = 1 + r.below(FIG17_KEYS);
            ops.push(match r.below(10) {
                0 | 1 => MapOp::Insert(k, k),
                2 => MapOp::Remove(k),
                3 => MapOp::FetchAdd(k, 1),
                _ => MapOp::Get(k),
            });
        }
        c.send_frame(&ops)?;
        inflight += 1;
        if inflight == FIG17_DEPTH {
            c.read_batch_reply(batch)?;
            inflight -= 1;
        }
    }
    while inflight > 0 {
        c.read_batch_reply(batch)?;
        inflight -= 1;
    }
    Ok((frames * batch) as u64)
}

/// Drive `conns` concurrent clients against `addr`; ops/second.
fn fig17_run(
    addr: std::net::SocketAddr,
    conns: usize,
    frames: usize,
    batch: usize,
) -> f64 {
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..conns as u64)
        .map(|tid| {
            std::thread::spawn(move || {
                fig17_client(addr, tid, frames, batch).expect("fig17 client")
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    total as f64 / t0.elapsed().as_secs_f64()
}

/// Connection-churn load: every client thread runs `rounds` short
/// lived sessions — connect, push `frames` pipelined frames, drain,
/// disconnect — so the cell exercises the accept path (`SO_REUSEPORT`
/// distribution vs accept-thread dealing) as hard as the data path.
fn fig17_churn_run(
    addr: std::net::SocketAddr,
    conns: usize,
    rounds: usize,
    frames: usize,
    batch: usize,
) -> f64 {
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..conns as u64)
        .map(|tid| {
            std::thread::spawn(move || {
                let mut total = 0u64;
                for round in 0..rounds as u64 {
                    total += fig17_client(
                        addr,
                        tid ^ (round << 32),
                        frames,
                        batch,
                    )
                    .expect("fig17 churn client");
                }
                total
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    total as f64 / t0.elapsed().as_secs_f64()
}

/// Spawn a fig17 server (fresh map) on `backend`.
fn fig17_spawn(
    backend: crate::service::Backend,
    size_log2: u32,
    workers: usize,
) -> crate::service::FrontendHandle {
    backend
        .spawn(fig17_map(size_log2), workers)
        .unwrap_or_else(|e| panic!("spawn {backend} server: {e}"))
}

/// Sum of the per-backend server-side syscall counters in a
/// measurement window's metric delta, divided by the ops the window
/// delivered — the series the ≥256-connection acceptance gate reads.
/// `None` when metrics are disabled (`CRH_METRICS=0`).
fn fig17_syscalls_per_op(
    mets: &[(String, f64)],
    total_ops: f64,
) -> Option<f64> {
    let s: f64 = mets
        .iter()
        .filter(|(k, _)| k.starts_with("syscalls_"))
        .map(|(_, v)| v)
        .sum();
    (s > 0.0 && total_ops > 0.0).then(|| s / total_ops)
}

/// Measure one (conns, workers) point on both original backends,
/// fresh map and server per measurement: (thread-per-conn ops/s,
/// epoll ops/s). The quick-mode throughput gate in
/// `benches/fig17_frontend.rs` is built on this.
pub fn fig17_pair(
    size_log2: u32,
    conns: usize,
    workers: usize,
    frames: usize,
    batch: usize,
) -> (f64, f64) {
    use crate::service::{reactor, server};
    let h = server::spawn_server(fig17_map(size_log2)).expect("spawn server");
    let threaded = fig17_run(h.addr(), conns, frames, batch);
    h.shutdown();
    let h = reactor::spawn_server_epoll(fig17_map(size_log2), workers)
        .expect("spawn reactor");
    let epoll = fig17_run(h.addr(), conns, frames, batch);
    h.shutdown();
    (threaded, epoll)
}

/// Measure one backend at one cell: (ops/s, server-side
/// syscalls-per-op). The syscall figure is `NaN` when metrics are
/// disabled. The uring-vs-epoll quick gate compares this across
/// backends — a *count*, not a timing, so it is immune to CI-runner
/// noise.
pub fn fig17_syscalls(
    backend: crate::service::Backend,
    size_log2: u32,
    conns: usize,
    workers: usize,
    frames: usize,
    batch: usize,
) -> (f64, f64) {
    let (ops_s, mets) = crate::util::metrics::measured(|| {
        let h = fig17_spawn(backend, size_log2, workers);
        let ops_s = fig17_run(h.addr(), conns, frames, batch);
        h.shutdown();
        ops_s
    });
    let total_ops = (conns * frames * batch) as f64;
    let per_op =
        fig17_syscalls_per_op(&mets, total_ops).unwrap_or(f64::NAN);
    (ops_s, per_op)
}

/// The reply transcript of the fixed fig17 op trace against `addr`,
/// delivered in deliberately tiny write chunks so the server-side
/// decoder sees frames split across arbitrary read boundaries.
fn fig17_transcript(addr: std::net::SocketAddr) -> Vec<String> {
    use crate::service::server::Client;
    let request = "\
P 10 100\nP 10 101\nG 10\nU 10 7\nA 10 5\nC 10 106 9\nD 10\n\
G 0\nG 4611686018427387902\nP 1 4611686018427387904\nX 1\nG 1 junk\n\
B 0\nB 5000\n\
B 3\nP 2 20\nG 2\nD 2\n\
B 2\nG 0\nG 2\n\
G 2\nA 3 1\nA 3 1\nC 3 2 -\nC 3 2 -\nU 22 7\nU 22 8\nQ\n";
    const REPLIES: usize = 23;
    let mut c = Client::connect(addr).expect("connect");
    for (i, chunk) in request.as_bytes().chunks(7).enumerate() {
        c.send_raw(chunk).expect("send");
        if i % 16 == 0 {
            // Let some fragments land alone instead of coalescing.
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    (0..REPLIES).map(|_| c.read_reply_line().expect("reply")).collect()
}

/// Flattened key schema of a `STATS` reply: top-level keys plus
/// dotted paths into nested objects, sorted. Counter *values* differ
/// between backends (they measure different code paths); the schema
/// must not.
fn stats_schema(line: &str) -> Vec<String> {
    let j = crate::util::json::Json::parse(line)
        .expect("STATS reply must parse as JSON");
    let obj = j.as_obj().expect("STATS reply must be a JSON object");
    let mut keys = Vec::new();
    for (k, v) in obj {
        match v.as_obj() {
            Some(inner) => {
                for (ik, _) in inner {
                    keys.push(format!("{k}.{ik}"));
                }
            }
            None => keys.push(k.clone()),
        }
    }
    keys.sort();
    keys
}

/// The satellite smoke check behind the `fig17_frontend --quick` CI
/// step: **every** backend (thread-per-connection, epoll reactor,
/// io_uring) must answer a fixed op trace — all verbs, protocol
/// errors, batch frames, split-across-read framing —
/// **byte-identically**, and all must match the protocol's documented
/// semantics. Each backend also answers a `STATS` probe whose JSON
/// schema (key paths) must be identical across backends — the wire
/// telemetry plane cannot drift either. On kernels without io_uring
/// the uring backend transparently serves through the reactor, so the
/// gate still covers its spawn/shutdown surface. Returns the
/// transcript length; panics on any divergence.
pub fn fig17_equivalence(size_log2: u32) -> usize {
    use crate::service::server::Client;
    use crate::service::Backend;
    let expected: Vec<&str> = vec![
        "-", "100", "101", "101", "101", "OK", "9",
        "ERR key out of range", "ERR key out of range",
        "ERR value out of range", "ERR bad request", "ERR bad request",
        "ERR bad batch size", "ERR bad batch size",
        "- 20 20",
        "ERR key out of range",
        "-", "-", "1", "OK", "!-", "-", "7",
    ];
    let probe_stats = |addr: std::net::SocketAddr| -> String {
        let mut c = Client::connect(addr).expect("connect for STATS");
        c.stats().expect("STATS reply")
    };
    let mut lines = 0;
    let mut first_schema: Option<(&'static str, Vec<String>)> = None;
    for backend in Backend::ALL {
        let h = fig17_spawn(backend, size_log2, 2);
        let transcript = fig17_transcript(h.addr());
        let stats = probe_stats(h.addr());
        h.shutdown();
        assert_eq!(
            transcript,
            expected,
            "{backend} backend diverged on the fixed op trace"
        );
        lines = transcript.len();
        let schema = stats_schema(&stats);
        assert!(
            schema.iter().any(|k| k == "counters.kcas_attempts"),
            "{backend} STATS schema missing counters: {schema:?}"
        );
        match &first_schema {
            None => first_schema = Some((backend.name(), schema)),
            Some((first, expected_schema)) => assert_eq!(
                &schema, expected_schema,
                "{backend} and {first} diverged on the STATS schema"
            ),
        }
    }
    lines
}

/// Rounds each churn client reconnects; its frame budget is divided
/// across them so the churn cell moves the same op count as a plain
/// cell with the same (conns, frames) figures.
const FIG17_CHURN_ROUNDS: usize = 8;

/// **Figure 17** (extension): the front-end comparison — end-to-end KV
/// throughput over TCP across a **three-backend matrix**:
/// thread-per-connection pipeline, epoll event loop, and io_uring
/// completion rings, swept across connection count x event-loop
/// worker count, plus a high-connection-count connection-*churn* cell
/// per event-loop backend (short-lived sessions hammering the accept
/// path). Every cell runs the same work-bound load (`frames`
/// pipelined frames of `batch` ops per connection) against a fresh
/// server+map, so rows differ only in how sockets are multiplexed.
/// The equivalence check runs first: all selected backends must
/// answer the fixed protocol trace identically before their
/// throughput is worth comparing.
///
/// Each cell is measured `reps` times against a fresh server+map per
/// rep; the table prints the median in kops/s and the server-side
/// syscalls-per-op (from the `syscalls_*` counters — the number the
/// io_uring backend exists to shrink), while the snapshot cell stores
/// the stat in ops/µs (kops/s ÷ 1000) and carries `syscalls_per_op`
/// as an extra so `BENCH_fig17.json` records the series.
///
/// `backends` selects the matrix rows (CLI/bench `--backend` filter);
/// uring cells are skipped with a notice when the kernel lacks
/// io_uring — measuring the fallback as if it were the ring would
/// poison baselines.
pub fn fig17_frontend(
    size_log2: u32,
    conn_counts: &[usize],
    worker_counts: &[usize],
    frames: usize,
    batch: usize,
    reps: u32,
    backends: &[crate::service::Backend],
) -> BenchReport {
    use crate::service::Backend;
    let mut report = BenchReport::new(
        "fig17",
        vec![
            ("size_log2".to_string(), size_log2.to_string()),
            ("frames".to_string(), frames.to_string()),
            ("batch".to_string(), batch.to_string()),
            ("depth".to_string(), FIG17_DEPTH.to_string()),
            ("reps".to_string(), reps.to_string()),
        ],
    );
    println!(
        "# Figure 17 — KV front-ends: thread-per-conn vs epoll vs io_uring; \
         sharded-kcas-rh-map:4 2^{size_log2}, {frames} frames/conn x \
         {batch} ops/frame, pipeline depth {FIG17_DEPTH}, {reps} rep(s)"
    );
    let lines = fig17_equivalence(size_log2);
    println!(
        "## equivalence: identical reply transcripts on the fixed op trace \
         ({lines} lines) OK"
    );
    let uring_live = crate::service::uring::uring_frontend_available();
    if backends.contains(&Backend::Uring) && !uring_live {
        println!(
            "## NOTE: kernel lacks io_uring — uring cells skipped \
             (the fallback would measure the epoll reactor twice)"
        );
    }
    println!(
        "\n{:<10} {:>7} {:>7} {:>6} {:>12} {:>14}",
        "backend", "workers", "conns", "churn", "kops/s", "syscalls/op"
    );
    // One measured cell: `reps` fresh server+map runs on `backend`,
    // reported in ops/µs with the syscalls-per-op extra derived from
    // the metric delta over the whole window.
    let mut cell = |backend: Backend, workers: usize, conns: usize, churn: bool| {
        let (samples, mets) = crate::util::metrics::measured(|| {
            (0..reps.max(1))
                .map(|_| {
                    let h = fig17_spawn(backend, size_log2, workers);
                    let ops_s = if churn {
                        fig17_churn_run(
                            h.addr(),
                            conns,
                            FIG17_CHURN_ROUNDS,
                            (frames / FIG17_CHURN_ROUNDS).max(1),
                            batch,
                        )
                    } else {
                        fig17_run(h.addr(), conns, frames, batch)
                    };
                    h.shutdown();
                    ops_s / 1e6
                })
                .collect::<Vec<f64>>()
        });
        let stat = Stat::from_samples(&samples);
        let ops_per_rep = if churn {
            (conns * FIG17_CHURN_ROUNDS * (frames / FIG17_CHURN_ROUNDS).max(1)
                * batch) as f64
        } else {
            (conns * frames * batch) as f64
        };
        let per_op = fig17_syscalls_per_op(
            &mets,
            ops_per_rep * reps.max(1) as f64,
        );
        let workers_label = if backend == Backend::Threads {
            "-".to_string()
        } else {
            workers.to_string()
        };
        println!(
            "{:<10} {:>7} {:>7} {:>6} {:>12.1} {:>14}",
            backend.name(),
            workers_label,
            conns,
            if churn { "yes" } else { "-" },
            stat.median * 1e3,
            per_op.map_or("-".to_string(), |v| format!("{v:.3}")),
        );
        let mut c = CellResult::new([
            ("backend", backend.name().to_string()),
            ("workers", workers_label),
            ("conns", conns.to_string()),
            ("churn", if churn { "yes" } else { "-" }.to_string()),
        ])
        .with_ops(stat)
        .with_metrics(mets);
        if let Some(v) = per_op {
            c = c.with_extra("syscalls_per_op", v);
        }
        report.push(c);
    };
    let selected: Vec<Backend> = backends
        .iter()
        .copied()
        .filter(|&b| b != Backend::Uring || uring_live)
        .collect();
    for &conns in conn_counts {
        for &backend in &selected {
            if backend == Backend::Threads {
                // No worker knob: the backend spawns per connection.
                cell(backend, 0, conns, false);
            } else {
                for &workers in worker_counts {
                    cell(backend, workers, conns, false);
                }
            }
        }
    }
    // The churn row: shortest-lived connections at the highest
    // connection count, one cell per event-loop backend at the widest
    // worker setting — accept-path stress the plain sweep never
    // applies.
    let churn_conns = conn_counts.iter().copied().max().unwrap_or(0);
    let churn_workers = worker_counts.iter().copied().max().unwrap_or(1);
    if churn_conns > 0 {
        for &backend in &selected {
            if backend == Backend::Threads {
                continue;
            }
            cell(backend, churn_workers, churn_conns, true);
        }
    }
    report
}

/// Accounts a fig18 cell pre-seeds (keys `1..=FIG18_ACCOUNTS`).
const FIG18_ACCOUNTS: u64 = 1024;
/// Per-account seed balance; the conserved quantity is
/// `FIG18_ACCOUNTS * FIG18_BALANCE` (mod 2^62).
const FIG18_BALANCE: u64 = 1_000_000;

/// **Figure 18** (extension): multi-key transactions — SmallBank-style
/// transfer throughput of the `apply_txn` API across commit engines:
/// the native path (**one K-CAS per commit** on the Robin Hood map;
/// 2PL on the locked baseline) vs the OCC read-validate-write
/// baseline, swept across transaction size (legs per transfer) x
/// contention skew (accounts drawn from a hot subset) x thread count,
/// at each sharded layout. Every cell seeds the same account set and
/// every *native* cell asserts conservation afterwards — the grand
/// total mod 2^62 must equal the seeded total, or the cell panics: the
/// experiment measures the new API and proves its atomicity in the
/// same run. (OCC is exempt: its documented weaker isolation is
/// exactly what the comparison demonstrates.)
pub fn fig18_txn(
    opts: &ExpOpts,
    shard_counts: &[u32],
    txn_sizes: &[usize],
    hot_accounts: &[u64],
) -> BenchReport {
    use crate::service::batch::{
        run_txn_transfers, txn_balance_sum, TxnEngine,
    };
    assert!(
        opts.size_log2 >= 12,
        "fig18 needs 2^12+ buckets for its {FIG18_ACCOUNTS} accounts"
    );
    let mut report = BenchReport::new("fig18", opts_spec(opts));
    println!(
        "# Figure 18 — multi-key transactions: SmallBank-style transfers; \
         {FIG18_ACCOUNTS} accounts, maps 2^{} buckets, {} ms/cell, {} rep(s)",
        opts.size_log2, opts.duration_ms, opts.reps
    );
    println!(
        "# engines: kcas = native one-K-CAS commit, occ = read-validate-\
         write baseline, 2pl = locked two-phase baseline"
    );
    for &txn_size in txn_sizes {
        if !(2..=16).contains(&txn_size) {
            println!("# skipping txn size {txn_size}: outside [2, 16]");
            continue;
        }
        for &shards in shard_counts {
            println!("\n## panel: {txn_size} legs/transfer, {shards} shard(s)");
            println!(
                "{:<6} {:>6} {:>4} {:>10} {:>8} {:>9}",
                "engine", "hot", "thr", "txns/us", "abort%", "conserved"
            );
            let rows: [(&str, MapKind, TxnEngine); 3] = [
                (
                    "kcas",
                    MapKind::ShardedKCasRhMap { shards },
                    TxnEngine::Native,
                ),
                (
                    "occ",
                    MapKind::ShardedKCasRhMap { shards },
                    TxnEngine::Occ,
                ),
                (
                    "2pl",
                    MapKind::ShardedLockedLpMap { shards },
                    TxnEngine::Native,
                ),
            ];
            for (label, kind, engine) in rows {
                for &hot in hot_accounts {
                    let hot = hot.clamp(txn_size as u64, FIG18_ACCOUNTS);
                    for &threads in &opts.threads {
                        let mut commits = 0u64;
                        let mut aborts = 0u64;
                        let (samples, mets) =
                            crate::util::metrics::measured(|| {
                                (0..opts.reps.max(1))
                                    .map(|rep| {
                                        let m = kind.build(opts.size_log2);
                                        for k in 1..=FIG18_ACCOUNTS {
                                            m.insert(k, FIG18_BALANCE);
                                        }
                                        let r = run_txn_transfers(
                                            m.as_ref(),
                                            engine,
                                            hot,
                                            txn_size,
                                            opts.duration_ms,
                                            threads,
                                            opts.pin,
                                            0xF18 + rep as u64,
                                        );
                                        commits += r.commits;
                                        aborts += r.aborts;
                                        if engine == TxnEngine::Native {
                                            // The acceptance check: an
                                            // atomic commit cannot
                                            // create or destroy money.
                                            let total = txn_balance_sum(
                                                m.as_ref(),
                                                FIG18_ACCOUNTS,
                                            );
                                            assert_eq!(
                                                total % (1u128 << 62),
                                                (FIG18_ACCOUNTS
                                                    * FIG18_BALANCE)
                                                    as u128,
                                                "{label} shards={shards} \
                                                 size={txn_size} hot={hot} \
                                                 thr={threads}: conservation \
                                                 violated"
                                            );
                                        }
                                        r.run.ops_per_us()
                                    })
                                    .collect::<Vec<f64>>()
                            });
                        let abort_pct = if commits + aborts == 0 {
                            0.0
                        } else {
                            100.0 * aborts as f64
                                / (commits + aborts) as f64
                        };
                        let stat = Stat::from_samples(&samples);
                        println!(
                            "{:<6} {:>6} {:>4} {:>10.3} {:>7.2}% {:>9}",
                            label,
                            hot,
                            threads,
                            stat.median,
                            abort_pct,
                            if engine == TxnEngine::Native {
                                "OK"
                            } else {
                                "-"
                            }
                        );
                        report.push(
                            CellResult::new([
                                ("size", txn_size.to_string()),
                                ("shards", shards.to_string()),
                                ("engine", label.to_string()),
                                ("hot", hot.to_string()),
                                ("threads", threads.to_string()),
                            ])
                            .with_ops(stat)
                            .with_extra("abort_pct", abort_pct)
                            .with_metrics(mets),
                        );
                    }
                }
            }
        }
    }
    report
}

/// **Table 1**: simulated cache misses relative to K-CAS Robin Hood
/// (single core), via the trace models + cache hierarchy. Snapshot
/// cells carry the relative miss percentage as an `extra` metric (the
/// simulator is deterministic, so there is nothing to repeat).
pub fn table1(size_log2: u32, ops: u64) -> BenchReport {
    let mut report = BenchReport::new(
        "table1",
        vec![
            ("size_log2".to_string(), size_log2.to_string()),
            ("ops".to_string(), ops.to_string()),
        ],
    );
    println!(
        "# Table 1 — LLC misses relative to K-CAS Robin Hood \
         (cache simulator; table 2^{size_log2}, {ops} ops/cell)"
    );
    let labels = cachesim::grid_labels(size_log2);
    print!("{:<18}", "config");
    for l in &labels {
        print!(" {:>11}", l);
    }
    println!();
    let baseline = cachesim::table1_baseline(size_log2, ops);
    let rows = [
        TableKind::Hopscotch,
        TableKind::LockFreeLp,
        TableKind::LockedLp,
        TableKind::Michael,
        TableKind::TxRobinHood,
    ];
    for kind in rows {
        let row = cachesim::table1_row(kind, size_log2, ops, &baseline);
        print!("{:<18}", kind.display());
        for (l, v) in labels.iter().zip(&row) {
            print!(" {:>10.0}%", v);
            report.push(
                CellResult::new([
                    ("config", l.clone()),
                    ("table", kind.name()),
                ])
                .with_extra("llc_miss_rel_pct", *v),
            );
        }
        println!();
    }
    report
}

/// Ablation: timestamp shard granularity for K-CAS Robin Hood.
///
/// The paper shards one timestamp per 64 buckets (16 MiB of timestamp
/// words at 2^23 — misses in cache, which is what makes its Table 1
/// show Tx-RH ahead of K-CAS RH). This crate's default bounds the shard
/// table to <= 8192 entries (cache-resident). The ablation quantifies
/// the tradeoff on real throughput and simulated misses.
pub fn ablate_ts(size_log2: u32, duration_ms: u64) {
    use crate::cachesim::{trace::RhFlavor, trace::RhTrace, Hierarchy};
    use crate::maps::kcas_rh::KCasRobinHood;
    println!(
        "# ts-sharding ablation — K-CAS RH, 2^{size_log2} buckets, \
         LF 60%, 10% updates"
    );
    println!(
        "{:<26} {:>12} {:>12} {:>14}",
        "buckets/shard (log2)", "ops/us (1T)", "ops/us (4T)", "LLC miss/op"
    );
    let default = crate::maps::kcas_rh::default_shard_log2(size_log2);
    let mut widths = vec![6u32, 8, 10, 12];
    if !widths.contains(&default) {
        widths.push(default);
    }
    widths.sort_unstable();
    widths.dedup();
    for w in widths {
        let cfg = WorkloadCfg::cell(
            size_log2,
            0.6,
            Mix::LIGHT.update_pct,
            duration_ms,
            0xAB1A,
        );
        let mut tp = [0.0f64; 2];
        for (i, threads) in [1usize, 4].into_iter().enumerate() {
            let table = KCasRobinHood::with_shards(size_log2, w);
            crate::bench::workload::prefill(&table, &cfg);
            tp[i] =
                driver::run_prefilled(&table, &cfg, threads, true).ops_per_us();
        }
        // Simulated misses under the same sharding.
        let mut t = RhTrace::with_ts_sharding(size_log2, RhFlavor::KCas, w);
        let mut h = Hierarchy::new();
        let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xDEAD_BEEF);
        let mut added = std::collections::HashSet::new();
        while added.len() < cfg.prefill_count() {
            let key = 1 + rng.below(cfg.key_space());
            if added.insert(key) {
                t.op(crate::bench::workload::Op::Add(key), &mut h);
            }
        }
        h.reset_counters();
        let ops = 500_000u64;
        let mut rng = crate::util::rng::Rng::for_thread(cfg.seed, 0);
        for _ in 0..ops {
            t.op(cfg.draw_op(&mut rng), &mut h);
        }
        let tag = if w == default { " (default)" } else if w == 6 { " (paper)" } else { "" };
        println!(
            "{:<26} {:>12.2} {:>12.2} {:>14.3}",
            format!("{w}{tag}"),
            tp[0],
            tp[1],
            h.llc_misses() as f64 / ops as f64
        );
    }
}

/// Ad-hoc single benchmark cell.
pub fn bench_cell(
    kind: TableKind,
    size_log2: u32,
    lf: f64,
    update_pct: u32,
    threads: usize,
    duration_ms: u64,
    pin: bool,
    dist: KeyDist,
) {
    let cfg = WorkloadCfg {
        dist,
        ..WorkloadCfg::cell(size_log2, lf, update_pct, duration_ms, 0xFEED)
    };
    let r = driver::run(kind, &cfg, threads, pin);
    println!(
        "{} size=2^{} lf={:.0}% updates={}% threads={} dist={:?} -> {:.3} ops/us \
         ({} ops in {:?})",
        kind.display(),
        size_log2,
        lf * 100.0,
        update_pct,
        threads,
        cfg.dist,
        r.ops_per_us(),
        r.total_ops,
        r.elapsed
    );
}

/// Probe-length analysis through the runtime engine (L2 `probe_stats`):
/// fill a K-CAS Robin Hood table, snapshot DFBs, run the analytics.
pub fn analyze(size_log2: u32, lf: f64) -> crate::util::error::Result<()> {
    let engine = crate::runtime::Engine::load_default()?;
    println!("# probe-distance analysis ({} backend)", engine.platform());
    let cfg = WorkloadCfg::cell(size_log2, lf, Mix::LIGHT.update_pct, 0, 0xFEED);
    let table = TableKind::KCasRobinHood.build(size_log2);
    crate::bench::workload::prefill(table.as_ref(), &cfg);
    let snap = table.dfb_snapshot();
    let stats = engine.probe_stats(&snap)?;
    println!(
        "load factor {:.0}%: {} entries, mean DFB {:.3}, var {:.3}, max {}",
        lf * 100.0,
        stats.count,
        stats.mean,
        stats.var,
        stats.max
    );
    print!("hist:");
    for (d, &c) in stats.hist.iter().enumerate().take(12) {
        print!(" {d}:{c}");
    }
    println!(" ...");
    // Celis' theory: mean successful probe stays O(1); sanity-check.
    if lf <= 0.8 {
        assert!(stats.mean < 8.0, "mean DFB {} looks wrong", stats.mean);
    }
    Ok(())
}

/// Verify artifacts + Rust/JAX hash agreement (golden vectors).
pub fn validate() -> crate::util::error::Result<()> {
    let dir = crate::runtime::artifacts_dir();
    let engine = crate::runtime::Engine::load(&dir)?;
    let n = engine.verify_golden(&dir)?;
    println!(
        "validate: {} golden vectors OK on {} (rust == jax == pallas)",
        n,
        engine.platform()
    );
    Ok(())
}

/// Tiny built-in smoke run used by `crh smoke` and CI.
pub fn smoke() {
    let opts = ExpOpts {
        size_log2: 14,
        duration_ms: 100,
        threads: vec![1, 2],
        pin: false,
        reps: 1,
    };
    let kinds = TableKind::ALL_CONCURRENT.into_iter().chain([
        TableKind::ShardedKCasRh { shards: 4 },
        TableKind::IncResizableRh,
    ]);
    for kind in kinds {
        let cfg = WorkloadCfg::cell(
            opts.size_log2,
            0.4,
            Mix::LIGHT.update_pct,
            opts.duration_ms,
            1,
        );
        let r = driver::run(kind, &cfg, 2, false);
        println!("smoke {:<22} {:>8.2} ops/us", kind.name(), r.ops_per_us());
        assert!(r.total_ops > 0);
    }
    let _ = Duration::from_millis(0);
    println!("smoke OK");
}
