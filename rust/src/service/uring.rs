//! io_uring completion-ring front-end — the third server backend,
//! speaking the identical wire protocol through the same
//! [`super::frame`] codec as [`super::server`] and [`super::reactor`].
//!
//! ## Why
//!
//! The epoll reactor already batches *table* work (all ops from all
//! ready sockets apply in one
//! [`crate::maps::ConcurrentMap::apply_batch_hashed`] call), but it
//! still pays one `read` and one `write` syscall per ready connection
//! per wake-up, plus `epoll_wait` and `epoll_ctl` traffic. This
//! backend extends the batching amplifier down to the kernel boundary:
//! reads, writes, and accepts are submission-queue entries on a
//! per-worker io_uring ([`crate::util::sys::Uring`]), and each
//! wake-batch costs **one** `io_uring_enter` in each direction no
//! matter how many connections participated. `fig17_frontend`'s
//! syscalls-per-op series measures exactly this.
//!
//! ## Shape
//!
//! * No accept thread: with [`spawn_server_uring`] each worker binds
//!   its own `SO_REUSEPORT` listener
//!   ([`crate::util::sys::bind_reuseport`]) and the kernel
//!   load-balances incoming connections across workers; with
//!   [`serve_uring`] (externally bound listener — `SO_REUSEPORT` must
//!   be set pre-bind, so siblings can't be added retroactively) every
//!   worker arms an accept SQE on a dup of the same listener fd.
//!   Either way the hand-off hop is gone.
//! * Each worker owns one ring and its connections outright. A
//!   wake-batch runs the reactor's three phases: drain the CQ and feed
//!   read completions through per-connection
//!   [`FrameDecoder`](super::frame::FrameDecoder)s, apply
//!   every decoded op with one `apply_batch_hashed`, then queue reply
//!   writes and re-arm reads as SQEs that the next `io_uring_enter`
//!   submits together.
//! * Backpressure mirrors the reactor's high/low-water scheme
//!   ([`super::reactor::HIGH_WATER`]/[`super::reactor::LOW_WATER`]): a
//!   connection
//!   whose unsent replies exceed the high-water mark gets no new read
//!   SQE until the backlog drains below low water, and withheld
//!   decoded frames replay on resume.
//! * Panic containment is the reactor's doomed-wake-batch rule: a
//!   batch that unwinds may have applied partially, so every
//!   connection with ops in it gets one `ERR server error` line and a
//!   close.
//! * Shutdown: [`UringHandle::shutdown`] signals each worker's
//!   eventfd (armed as a read SQE), workers cancel their accepts,
//!   shut down every socket, drain in-flight completions to zero, and
//!   are joined.
//!
//! ## Buffer-stability safety
//!
//! The kernel reads and writes our buffers *asynchronously*, so every
//! byte handed to an SQE must stay valid and un-moved until its CQE is
//! reaped. Three invariants enforce that:
//!
//! 1. each connection's read buffer is a `Box<[u8]>` — heap address
//!    stable even as the connection table reallocates;
//! 2. writes are double-buffered: `wbuf` is **frozen** (never touched)
//!    while a write SQE is in flight and new replies accumulate in
//!    `out`; the two swap only between flights;
//! 3. a connection slot is never freed while it has an SQE in flight —
//!    teardown shuts the socket down (forcing the completions) and
//!    frees the slot when the in-flight count reaches zero.
//!
//! ## Fallback
//!
//! Kernels without io_uring (pre-5.6 opcodes, `ENOSYS`, seccomp
//! `EPERM`) are detected at spawn by a runtime probe
//! ([`crate::util::sys::uring_supported`]) and the same API serves
//! through the epoll reactor instead — [`UringHandle::is_fallback`]
//! reports which path was taken, `CRH_URING=0` forces it from the
//! environment, and [`force_fallback`] forces it programmatically
//! (tests can't mutate the environment of a multithreaded binary).

#[cfg(target_os = "linux")]
pub use imp::{
    force_fallback, serve_uring, spawn_server_uring,
    uring_frontend_available, UringHandle,
};

#[cfg(not(target_os = "linux"))]
pub use fallback::{
    force_fallback, serve_uring, spawn_server_uring,
    uring_frontend_available, UringHandle,
};

#[cfg(target_os = "linux")]
mod imp {
    use std::io;
    use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
    use std::os::fd::{AsRawFd, FromRawFd};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, OnceLock};
    use std::thread::JoinHandle;

    use crate::maps::{ConcurrentMap, HashedMapOp, MapOp, MapReply};
    use crate::service::frame::{
        push_reply, txn_err_line, Frame, FrameDecoder, ERR_SERVER,
    };
    use crate::service::panic_message;
    use crate::service::reactor::{
        self, ReactorHandle, HIGH_WATER, LOW_WATER,
    };
    use crate::util::hash::splitmix64;
    use crate::util::metrics::{metrics, stats_line};
    use crate::util::sys::{
        bind_reuseport_group, uring_supported, Cqe, EventFd, Sqe, Uring,
    };

    /// Per-connection read-buffer size (one read SQE's worth).
    const READ_CHUNK: usize = 16 * 1024;
    /// Submission ring slots. The ring is a *queue to the kernel*, not
    /// an in-flight bound — `Uring::push` flushes when full.
    const SQ_ENTRIES: u32 = 256;
    /// Completion ring slots. In-flight SQEs are bounded by
    /// 2/connection (one read + one write) + accept + wake, so this
    /// accommodates ~2k connections per worker without CQ overflow.
    const CQ_ENTRIES: u32 = 4096;

    // user_data token layout: tag(8) | gen(16) | zero(8) | slot(32).
    const TAG_READ: u64 = 1 << 56;
    const TAG_WRITE: u64 = 2 << 56;
    const TAG_ACCEPT: u64 = 3 << 56;
    const TAG_WAKE: u64 = 4 << 56;
    const TAG_CANCEL: u64 = 5 << 56;
    const TAG_MASK: u64 = 0xff << 56;

    fn tok(tag: u64, gen: u16, slot: u32) -> u64 {
        tag | ((gen as u64) << 32) | slot as u64
    }

    fn tok_gen(ud: u64) -> u16 {
        (ud >> 32) as u16
    }

    fn tok_slot(ud: u64) -> u32 {
        ud as u32
    }

    // ------------------------------------------------- fallback gating

    static FORCE_FALLBACK: AtomicBool = AtomicBool::new(false);

    /// Force the epoll-fallback path for subsequent spawns (tests:
    /// mutating the environment of a multithreaded test binary is a
    /// data race, so the kernel-too-old path is exercised through this
    /// hook instead, like `metrics::set_enabled`).
    pub fn force_fallback(on: bool) {
        // ORDERING: a standalone boolean gate consulted at spawn time;
        // no other memory is published through it, and a marginally
        // stale read just means one more spawn on the previous path.
        FORCE_FALLBACK.store(on, Ordering::Relaxed);
    }

    fn env_enabled() -> bool {
        static CACHE: OnceLock<bool> = OnceLock::new();
        *CACHE.get_or_init(|| match std::env::var("CRH_URING") {
            Ok(v) => !matches!(
                v.trim().to_ascii_lowercase().as_str(),
                "0" | "false" | "off" | "no"
            ),
            Err(_) => true,
        })
    }

    /// Will a uring spawn actually use io_uring here and now? False on
    /// old kernels (runtime probe), under `CRH_URING=0`, or while
    /// [`force_fallback`] is on — the CI smoke lane prints its skip
    /// notice off this.
    pub fn uring_frontend_available() -> bool {
        env_enabled()
            // ORDERING: same standalone gate as force_fallback — no
            // happens-before edge needed for an advisory flag.
            && !FORCE_FALLBACK.load(Ordering::Relaxed)
            && uring_supported()
    }

    // ------------------------------------------------------ connection

    /// One queued reply action, in frame order (identical semantics to
    /// the reactor's).
    #[derive(Clone, Copy)]
    enum Pending {
        /// Reply line for `batch_ops[start..start + len]` of this wake.
        Ops { start: usize, len: usize },
        /// Reply line for the wake's `idx`-th queued transaction
        /// (`T <n>` frame; committed in phase 2 after the wake batch).
        Txn { idx: usize },
        /// Literal protocol-error line.
        Line(&'static str),
        /// Telemetry snapshot (`STATS`), rendered at reply-format time.
        Stats,
    }

    /// Phase-2 result of one queued transaction — identical semantics
    /// to the reactor's.
    enum TxnOutcome {
        Replies(Vec<MapReply>),
        Abort(&'static str),
        Panicked,
    }

    struct Conn {
        stream: TcpStream,
        dec: FrameDecoder,
        /// Reply actions accumulated this wake (drained in phase 3).
        pending: Vec<Pending>,
        /// Read landing zone — boxed so its heap address survives the
        /// connection table reallocating under it (invariant 1).
        rbuf: Box<[u8]>,
        /// Replies not yet handed to the kernel (ours to grow freely).
        out: Vec<u8>,
        /// Bytes a write SQE may be flying over — frozen while
        /// `write_inflight` (invariant 2); `wsent` is the completed
        /// prefix.
        wbuf: Vec<u8>,
        wsent: usize,
        read_inflight: bool,
        write_inflight: bool,
        /// In this wake's touched set already.
        touched: bool,
        /// Reading suspended: reply backlog above the high-water mark.
        paused: bool,
        /// No more input will be consumed (Q, EOF-drained, or fatal);
        /// close once the backlog flushes.
        closing: bool,
        /// Fatal: close as soon as in-flight SQEs drain.
        dead: bool,
        /// Peer finished sending (read completed with 0).
        eof: bool,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            Conn {
                stream,
                dec: FrameDecoder::new(),
                pending: Vec::new(),
                rbuf: vec![0u8; READ_CHUNK].into_boxed_slice(),
                out: Vec::new(),
                wbuf: Vec::new(),
                wsent: 0,
                read_inflight: false,
                write_inflight: false,
                touched: false,
                paused: false,
                closing: false,
                dead: false,
                eof: false,
            }
        }

        /// Unsent reply bytes (accumulating + frozen-unflown).
        fn backlog(&self) -> usize {
            self.out.len() + (self.wbuf.len() - self.wsent)
        }

        fn inflight(&self) -> bool {
            self.read_inflight || self.write_inflight
        }
    }

    /// Decode complete frames, accumulating batch ops (with their
    /// routing hash) into the wake-wide batch and recording the
    /// per-connection reply actions in frame order — the reactor's
    /// phase 1b verbatim, including the transaction-boundary stop: a
    /// `T <n>` frame ends this connection's parsing for the wake so
    /// frames decoded after it observe its commit next wake (replay).
    fn parse_frames(
        conn: &mut Conn,
        batch_ops: &mut Vec<HashedMapOp>,
        txns: &mut Vec<Vec<MapOp>>,
    ) {
        while !conn.closing && conn.backlog() <= HIGH_WATER {
            let frame = match conn.dec.next_frame() {
                Some(f) => f,
                None if conn.eof => match conn.dec.finish() {
                    Some(f) => f,
                    None => break,
                },
                None => break,
            };
            match frame {
                Frame::Batch(ops) => {
                    let start = batch_ops.len();
                    batch_ops.extend(
                        ops.iter().map(|&op| (splitmix64(op.key()), op)),
                    );
                    conn.pending.push(Pending::Ops { start, len: ops.len() });
                }
                Frame::Txn(ops) => {
                    conn.pending.push(Pending::Txn { idx: txns.len() });
                    txns.push(ops);
                    break;
                }
                Frame::Err(e) => conn.pending.push(Pending::Line(e)),
                Frame::Stats => conn.pending.push(Pending::Stats),
                Frame::Quit => conn.closing = true,
            }
        }
    }

    /// Render this connection's reply lines into `out` — the reactor's
    /// phase 3a, doomed-wake-batch semantics included: if the wake
    /// batch panicked it may have applied partially and cannot be
    /// retried, so every connection with ops in it gets one
    /// `ERR server error` line and closes (earlier `ERR` lines still
    /// go out in order).
    fn format_replies(
        conn: &mut Conn,
        replies: &[MapReply],
        txn_results: &[TxnOutcome],
        panicked: bool,
        line: &mut String,
    ) {
        for i in 0..conn.pending.len() {
            line.clear();
            match conn.pending[i] {
                Pending::Line(e) => line.push_str(e),
                Pending::Stats => line.push_str(&stats_line()),
                Pending::Ops { start, len } => {
                    if panicked {
                        conn.out.extend_from_slice(ERR_SERVER.as_bytes());
                        conn.out.push(b'\n');
                        conn.closing = true;
                        break;
                    }
                    for (j, &r) in
                        replies[start..start + len].iter().enumerate()
                    {
                        if j > 0 {
                            line.push(' ');
                        }
                        push_reply(r, line);
                    }
                }
                Pending::Txn { idx } => match &txn_results[idx] {
                    TxnOutcome::Replies(rs) => {
                        for (j, &r) in rs.iter().enumerate() {
                            if j > 0 {
                                line.push(' ');
                            }
                            push_reply(r, line);
                        }
                    }
                    TxnOutcome::Abort(e) => line.push_str(e),
                    TxnOutcome::Panicked => {
                        conn.out.extend_from_slice(ERR_SERVER.as_bytes());
                        conn.out.push(b'\n');
                        conn.closing = true;
                        break;
                    }
                },
            }
            line.push('\n');
            conn.out.extend_from_slice(line.as_bytes());
        }
        conn.pending.clear();
    }

    // ---------------------------------------------------------- worker

    struct Worker {
        ring: Uring,
        listener: TcpListener,
        wake: Arc<EventFd>,
        /// Landing zone for the wake eventfd's read SQE (boxed:
        /// invariant 1 applies to it too).
        wake_buf: Box<[u8; 8]>,
        stop: Arc<AtomicBool>,
        map: Arc<dyn ConcurrentMap>,
        conns: Vec<Option<Conn>>,
        /// Per-slot generation, bumped on free so a stale CQE can
        /// never act on a recycled slot.
        gens: Vec<u16>,
        free: Vec<u32>,
        live: usize,
        accept_inflight: bool,
        stopping: bool,
    }

    impl Worker {
        fn new(
            ring: Uring,
            listener: TcpListener,
            wake: Arc<EventFd>,
            stop: Arc<AtomicBool>,
            map: Arc<dyn ConcurrentMap>,
        ) -> Worker {
            Worker {
                ring,
                listener,
                wake,
                wake_buf: Box::new([0u8; 8]),
                stop,
                map,
                conns: Vec::new(),
                gens: Vec::new(),
                free: Vec::new(),
                live: 0,
                accept_inflight: false,
                stopping: false,
            }
        }

        fn arm_wake(&mut self) -> io::Result<()> {
            let sqe = Sqe::read(
                self.wake.fd(),
                self.wake_buf.as_mut_ptr(),
                8,
                TAG_WAKE,
            );
            self.ring.push(sqe)
        }

        fn arm_accept(&mut self) -> io::Result<()> {
            let sqe = Sqe::accept(self.listener.as_raw_fd(), TAG_ACCEPT);
            self.accept_inflight = true;
            self.ring.push(sqe)
        }

        fn arm_read(&mut self, slot: u32) -> io::Result<()> {
            let gen = self.gens[slot as usize];
            let conn = self.conns[slot as usize].as_mut().expect("armed conn");
            let sqe = Sqe::read(
                conn.stream.as_raw_fd(),
                conn.rbuf.as_mut_ptr(),
                conn.rbuf.len() as u32,
                tok(TAG_READ, gen, slot),
            );
            conn.read_inflight = true;
            self.ring.push(sqe)
        }

        fn arm_write(&mut self, slot: u32) -> io::Result<()> {
            let gen = self.gens[slot as usize];
            let conn = self.conns[slot as usize].as_mut().expect("armed conn");
            // SAFETY: wbuf is frozen until this SQE's completion, so
            // the pointer outlives the kernel's use of it, and `wsent`
            // is always <= wbuf.len().
            let ptr = unsafe { conn.wbuf.as_ptr().add(conn.wsent) };
            let len = (conn.wbuf.len() - conn.wsent) as u32;
            let sqe = Sqe::write(
                conn.stream.as_raw_fd(),
                ptr,
                len,
                tok(TAG_WRITE, gen, slot),
            );
            conn.write_inflight = true;
            self.ring.push(sqe)
        }

        fn alloc_slot(&mut self, stream: TcpStream) -> u32 {
            self.live += 1;
            match self.free.pop() {
                Some(slot) => {
                    self.conns[slot as usize] = Some(Conn::new(stream));
                    slot
                }
                None => {
                    self.conns.push(Some(Conn::new(stream)));
                    self.gens.push(0);
                    (self.conns.len() - 1) as u32
                }
            }
        }

        /// Free the slot if the connection is finished *and* no SQE
        /// still references its buffers (invariant 3). A finished
        /// connection with flights pending gets its socket shut down,
        /// which forces those completions; the last one lands back
        /// here.
        fn maybe_free(&mut self, slot: u32) {
            let idx = slot as usize;
            let Some(conn) = self.conns[idx].as_mut() else { return };
            let done = conn.dead || (conn.closing && conn.backlog() == 0);
            if !done {
                return;
            }
            if conn.inflight() {
                let _ = conn.stream.shutdown(Shutdown::Both);
                conn.dead = true;
                return;
            }
            self.conns[idx] = None;
            self.gens[idx] = self.gens[idx].wrapping_add(1);
            self.free.push(slot);
            self.live -= 1;
        }

        fn on_wake(&mut self) -> io::Result<()> {
            if !self.stop.load(Ordering::SeqCst) {
                // Spurious signal: re-arm and keep serving.
                return self.arm_wake();
            }
            self.stopping = true;
            if self.accept_inflight {
                self.ring.push(Sqe::cancel(TAG_ACCEPT, TAG_CANCEL))?;
            }
            for slot in 0..self.conns.len() as u32 {
                if let Some(conn) = self.conns[slot as usize].as_mut() {
                    conn.dead = true;
                } else {
                    continue;
                }
                self.maybe_free(slot);
            }
            Ok(())
        }

        fn on_accept(&mut self, res: i32) -> io::Result<()> {
            self.accept_inflight = false;
            if self.stopping {
                if res >= 0 {
                    // Adopted just to close it.
                    // SAFETY: a non-negative accept CQE res is a fresh
                    // connected fd owned by no one else.
                    drop(unsafe { TcpStream::from_raw_fd(res) });
                }
                return Ok(());
            }
            if res >= 0 {
                // SAFETY: a non-negative accept CQE res is a fresh
                // connected fd owned by no one else; the TcpStream
                // takes sole ownership.
                let stream = unsafe { TcpStream::from_raw_fd(res) };
                stream.set_nodelay(true).ok();
                let slot = self.alloc_slot(stream);
                self.arm_read(slot)?;
            }
            // Negative res here is a transient accept error
            // (ECONNABORTED and kin): re-arm, same resilience as a
            // blocking accept loop.
            self.arm_accept()
        }

        fn on_read(
            &mut self,
            slot: u32,
            gen: u16,
            res: i32,
            batch_ops: &mut Vec<HashedMapOp>,
            txns: &mut Vec<Vec<MapOp>>,
            touched: &mut Vec<u32>,
        ) {
            if self.gens.get(slot as usize) != Some(&gen) {
                return; // stale completion for a recycled slot
            }
            let Some(conn) = self.conns[slot as usize].as_mut() else {
                return;
            };
            conn.read_inflight = false;
            if !conn.touched {
                conn.touched = true;
                touched.push(slot);
            }
            if res > 0 {
                metrics().bytes_in_uring.add(res as u64);
                let n = res as usize;
                // rbuf sliced immutably here; the SQE that wrote it is
                // the one this completion just retired.
                let (rbuf, dec) = (&conn.rbuf[..n], &mut conn.dec);
                dec.feed(rbuf);
            } else if res == 0 {
                conn.eof = true;
            } else {
                conn.dead = true;
            }
            if !conn.dead && !conn.closing && !conn.paused {
                parse_frames(conn, batch_ops, txns);
            }
        }

        fn on_write(
            &mut self,
            slot: u32,
            gen: u16,
            res: i32,
            touched: &mut Vec<u32>,
        ) -> io::Result<()> {
            if self.gens.get(slot as usize) != Some(&gen) {
                return Ok(());
            }
            let Some(conn) = self.conns[slot as usize].as_mut() else {
                return Ok(());
            };
            conn.write_inflight = false;
            if !conn.touched {
                conn.touched = true;
                touched.push(slot);
            }
            let mut resubmit = false;
            if res > 0 {
                metrics().bytes_out_uring.add(res as u64);
                conn.wsent += res as usize;
                // Partial write: fly the remainder immediately; wbuf
                // stays frozen across the re-flight.
                resubmit = !conn.dead && conn.wsent < conn.wbuf.len();
            } else {
                conn.dead = true;
            }
            if resubmit {
                self.arm_write(slot)?;
            }
            Ok(())
        }

        /// Phase 3 for one touched connection: render replies, swap
        /// the accumulated bytes into the (idle) write buffer and arm
        /// a write SQE, manage backpressure and lifecycle, re-arm the
        /// read SQE when reading is allowed.
        fn finish_wake(
            &mut self,
            slot: u32,
            replies: &[MapReply],
            txn_results: &[TxnOutcome],
            panicked: bool,
            line: &mut String,
            replay: &mut Vec<u32>,
        ) -> io::Result<()> {
            let stopping = self.stopping;
            let Some(conn) = self.conns[slot as usize].as_mut() else {
                return Ok(());
            };
            conn.touched = false;
            if !conn.dead {
                format_replies(conn, replies, txn_results, panicked, line);
            }
            let want_write = !conn.dead
                && !conn.write_inflight
                && conn.wsent == conn.wbuf.len()
                && !conn.out.is_empty();
            if want_write {
                conn.wbuf.clear();
                conn.wsent = 0;
                std::mem::swap(&mut conn.out, &mut conn.wbuf);
            }
            // Backpressure transitions — bounded in-flight write bytes:
            // a paused connection gets no read SQE, so its backlog is
            // capped at HIGH_WATER plus one read's worth of replies.
            if !conn.paused && conn.backlog() > HIGH_WATER {
                conn.paused = true;
                metrics().backpressure_pauses.incr();
            } else if conn.paused && conn.backlog() <= LOW_WATER {
                conn.paused = false;
                metrics().backpressure_resumes.incr();
            }
            // Withheld frames — backpressure unpause, or parsing
            // stopped at a transaction boundary to preserve
            // per-connection frame order: serve them next wake.
            if !conn.paused
                && !conn.closing
                && !conn.dead
                && (conn.dec.has_complete_line()
                    || (conn.eof && conn.dec.buffered() > 0))
            {
                replay.push(slot);
            }
            if conn.eof && !conn.paused && conn.dec.buffered() == 0 {
                conn.closing = true;
            }
            let want_read = !conn.dead
                && !conn.read_inflight
                && !conn.paused
                && !conn.closing
                && !conn.eof
                && !stopping;
            if want_write {
                self.arm_write(slot)?;
            }
            if want_read {
                self.arm_read(slot)?;
            }
            self.maybe_free(slot);
            Ok(())
        }

        fn run(mut self) {
            if self.arm_wake().is_err() || self.arm_accept().is_err() {
                return;
            }
            let mut cqes: Vec<Cqe> = Vec::new();
            let mut batch_ops: Vec<HashedMapOp> = Vec::new();
            let mut txns: Vec<Vec<MapOp>> = Vec::new();
            let mut txn_results: Vec<TxnOutcome> = Vec::new();
            let mut replies: Vec<MapReply> = Vec::new();
            let mut line = String::new();
            let mut touched: Vec<u32> = Vec::new();
            let mut replay: Vec<u32> = Vec::new();
            loop {
                // A nonzero replay set means unpaused connections
                // still hold decoded-but-unanswered frames: submit
                // without blocking, serve them now.
                let wait = if replay.is_empty() { 1 } else { 0 };
                if self.ring.enter(wait).is_err() {
                    return;
                }
                cqes.clear();
                self.ring.reap(&mut cqes);
                batch_ops.clear();
                txns.clear();
                txn_results.clear();
                touched.clear();

                // Re-admit replayed connections first (frame order
                // within a connection is preserved: its decoder is the
                // queue).
                for slot in std::mem::take(&mut replay) {
                    let Some(conn) = self.conns[slot as usize].as_mut()
                    else {
                        continue;
                    };
                    if !conn.touched {
                        conn.touched = true;
                        touched.push(slot);
                    }
                    if !conn.dead && !conn.closing && !conn.paused {
                        parse_frames(conn, &mut batch_ops, &mut txns);
                    }
                }

                // Phase 1: dispatch completions — reads feed decoders
                // and accumulate the wake-wide hashed op batch.
                for i in 0..cqes.len() {
                    let c = cqes[i];
                    let (gen, slot) = (tok_gen(c.user_data), tok_slot(c.user_data));
                    let step = match c.user_data & TAG_MASK {
                        TAG_WAKE => self.on_wake(),
                        TAG_ACCEPT => self.on_accept(c.res),
                        TAG_READ => {
                            self.on_read(
                                slot, gen, c.res, &mut batch_ops,
                                &mut txns, &mut touched,
                            );
                            Ok(())
                        }
                        TAG_WRITE => {
                            self.on_write(slot, gen, c.res, &mut touched)
                        }
                        // TAG_CANCEL (and anything else): the cancel
                        // op's own completion carries no state.
                        _ => Ok(()),
                    };
                    if step.is_err() {
                        return;
                    }
                }

                // Phase 2: one table call for every op this wake
                // delivered, across all connections.
                let mut panicked = false;
                if !batch_ops.is_empty() {
                    let applied = catch_unwind(AssertUnwindSafe(|| {
                        self.map.apply_batch_hashed(&batch_ops, &mut replies)
                    }));
                    if let Err(payload) = applied {
                        panicked = true;
                        metrics().server_panics.incr();
                        eprintln!(
                            "crh-uring: contained panic in wake batch \
                             ({} ops across {} conns): {}",
                            batch_ops.len(),
                            touched.len(),
                            panic_message(payload.as_ref()),
                        );
                    }
                }

                // Phase 2b: apply queued transactions, each all-or-
                // nothing, in arrival order after the wake batch.
                for ops in &txns {
                    let applied = catch_unwind(AssertUnwindSafe(|| {
                        self.map.apply_txn(ops)
                    }));
                    txn_results.push(match applied {
                        Ok(Ok(rs)) => TxnOutcome::Replies(rs),
                        Ok(Err(e)) => TxnOutcome::Abort(txn_err_line(&e)),
                        Err(payload) => {
                            metrics().server_panics.incr();
                            eprintln!(
                                "crh-uring: contained panic in txn \
                                 ({} ops): {}",
                                ops.len(),
                                panic_message(payload.as_ref()),
                            );
                            TxnOutcome::Panicked
                        }
                    });
                }

                // Phase 3: format replies, queue write/read SQEs (the
                // next enter submits them all at once), lifecycle.
                for i in 0..touched.len() {
                    let slot = touched[i];
                    if self
                        .finish_wake(
                            slot, &replies, &txn_results, panicked,
                            &mut line, &mut replay,
                        )
                        .is_err()
                    {
                        return;
                    }
                }

                if self.stopping && self.live == 0 && !self.accept_inflight {
                    return; // ring drop closes the fd and the ring
                }
            }
        }
    }

    // ---------------------------------------------------------- handle

    enum Inner {
        Ring {
            addr: SocketAddr,
            stop: Arc<AtomicBool>,
            wakes: Vec<Arc<EventFd>>,
            threads: Vec<JoinHandle<()>>,
        },
        Fallback(ReactorHandle),
    }

    /// Handle to a running io_uring server (or its epoll fallback).
    /// Dropping it detaches the server; [`UringHandle::shutdown`]
    /// stops and joins every worker, closing all sockets.
    pub struct UringHandle {
        inner: Inner,
    }

    impl UringHandle {
        /// The address the server is listening on.
        pub fn addr(&self) -> SocketAddr {
            match &self.inner {
                Inner::Ring { addr, .. } => *addr,
                Inner::Fallback(h) => h.addr(),
            }
        }

        /// Did this spawn fall back to the epoll reactor (kernel
        /// without io_uring, `CRH_URING=0`, or [`force_fallback`])?
        pub fn is_fallback(&self) -> bool {
            matches!(self.inner, Inner::Fallback(_))
        }

        /// Stop every worker, join them all, and close every
        /// connection.
        pub fn shutdown(self) {
            match self.inner {
                Inner::Ring { stop, wakes, mut threads, .. } => {
                    stop.store(true, Ordering::SeqCst);
                    for w in &wakes {
                        w.signal();
                    }
                    for t in threads.drain(..) {
                        let _ = t.join();
                    }
                }
                Inner::Fallback(h) => h.shutdown(),
            }
        }
    }

    fn serve_on(
        listeners: Vec<TcpListener>,
        addr: SocketAddr,
        map: Arc<dyn ConcurrentMap>,
    ) -> io::Result<UringHandle> {
        let stop = Arc::new(AtomicBool::new(false));
        let mut wakes = Vec::with_capacity(listeners.len());
        let mut workers = Vec::with_capacity(listeners.len());
        for listener in listeners {
            let ring = Uring::new(SQ_ENTRIES, CQ_ENTRIES)?;
            let wake = Arc::new(EventFd::new()?);
            wakes.push(wake.clone());
            workers.push(Worker::new(
                ring,
                listener,
                wake,
                stop.clone(),
                map.clone(),
            ));
        }
        let threads = workers
            .into_iter()
            .map(|w| std::thread::spawn(move || w.run()))
            .collect();
        Ok(UringHandle { inner: Inner::Ring { addr, stop, wakes, threads } })
    }

    /// Serve `map` on `listener` with `workers` ring-driven threads
    /// (0 = [`reactor::default_workers`]). `SO_REUSEPORT` must be set
    /// pre-bind, so an externally bound listener can't gain reuseport
    /// siblings; instead every worker arms an accept SQE on a dup of
    /// the same listener fd — still no hand-off hop. Falls back to the
    /// epoll reactor when io_uring is unavailable.
    pub fn serve_uring(
        listener: TcpListener,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<UringHandle> {
        let workers =
            if workers == 0 { reactor::default_workers() } else { workers };
        if !uring_frontend_available() {
            return reactor::serve_epoll(listener, map, workers)
                .map(|h| UringHandle { inner: Inner::Fallback(h) });
        }
        let addr = listener.local_addr()?;
        let mut listeners = Vec::with_capacity(workers);
        for _ in 0..workers {
            listeners.push(listener.try_clone()?);
        }
        serve_on(listeners, addr, map)
    }

    /// Bind an ephemeral localhost port and serve `map` on the uring
    /// backend with a per-worker `SO_REUSEPORT` listener group — each
    /// worker accepts its own connections, kernel-load-balanced.
    /// Falls back to a shared listener if reuseport binding fails, and
    /// to the epoll reactor if io_uring is unavailable.
    pub fn spawn_server_uring(
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<UringHandle> {
        let workers =
            if workers == 0 { reactor::default_workers() } else { workers };
        if !uring_frontend_available() {
            return reactor::spawn_server_epoll(map, workers)
                .map(|h| UringHandle { inner: Inner::Fallback(h) });
        }
        let local = SocketAddr::from(([127, 0, 0, 1], 0));
        match bind_reuseport_group(local, workers) {
            Ok((addr, listeners)) => serve_on(listeners, addr, map),
            Err(_) => {
                serve_uring(TcpListener::bind(local)?, map, workers)
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod fallback {
    //! io_uring is Linux-only; elsewhere the "uring" API serves
    //! through the reactor module (whose own non-Linux fallback is the
    //! thread-per-connection backend). The protocol is identical
    //! either way.

    use std::io;
    use std::net::{SocketAddr, TcpListener};
    use std::sync::Arc;

    use crate::maps::ConcurrentMap;
    use crate::service::reactor::{self, ReactorHandle};

    pub struct UringHandle(ReactorHandle);

    impl UringHandle {
        pub fn addr(&self) -> SocketAddr {
            self.0.addr()
        }

        pub fn is_fallback(&self) -> bool {
            true
        }

        pub fn shutdown(self) {
            self.0.shutdown()
        }
    }

    pub fn force_fallback(_on: bool) {}

    pub fn uring_frontend_available() -> bool {
        false
    }

    pub fn serve_uring(
        listener: TcpListener,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<UringHandle> {
        reactor::serve_epoll(listener, map, workers).map(UringHandle)
    }

    pub fn spawn_server_uring(
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<UringHandle> {
        reactor::spawn_server_epoll(map, workers).map(UringHandle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::maps::{ConcurrentMap, MapKind, MapOp};
    use crate::service::server::Client;
    use std::sync::Arc;

    fn map() -> Arc<dyn ConcurrentMap> {
        Arc::from(MapKind::ShardedKCasRhMap { shards: 4 }.build(12))
    }

    // These run on whatever the host kernel provides: with io_uring
    // they exercise the ring path, without it the transparent epoll
    // fallback — the protocol contract is identical by construction,
    // and tests/frontend.rs covers the forced-fallback path
    // explicitly.

    #[test]
    #[cfg_attr(miri, ignore = "real io_uring/TCP; no kernel under Miri")]
    fn round_trip_and_shutdown_joins() {
        let h = spawn_server_uring(map(), 2).unwrap();
        let mut c = Client::connect(h.addr()).unwrap();
        assert_eq!(c.request_line("P 5 50").unwrap(), "-");
        assert_eq!(c.request_line("G 5").unwrap(), "50");
        assert_eq!(c.request_line("A 5 1").unwrap(), "50");
        assert_eq!(c.request_line("C 5 51 -").unwrap(), "OK");
        assert_eq!(c.request_line("G 0").unwrap(), "ERR key out of range");
        let replies = c
            .batch(&[MapOp::Insert(7, 70), MapOp::Get(7), MapOp::Remove(7)])
            .unwrap();
        assert_eq!(replies, vec![None, Some(70), Some(70)]);
        h.shutdown();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real io_uring/TCP; no kernel under Miri")]
    fn quit_closes_after_replies_flush() {
        let h = spawn_server_uring(map(), 1).unwrap();
        let mut c = Client::connect(h.addr()).unwrap();
        c.send_raw(b"P 9 90\nG 9\nQ\n").unwrap();
        assert_eq!(c.read_reply_line().unwrap(), "-");
        assert_eq!(c.read_reply_line().unwrap(), "90");
        assert!(c.read_reply_line().is_err(), "connection should be closed");
        h.shutdown();
    }

    #[test]
    #[cfg_attr(miri, ignore = "real io_uring/TCP; no kernel under Miri")]
    fn many_connections_share_workers() {
        let m = map();
        let h = spawn_server_uring(m.clone(), 2).unwrap();
        let addr = h.addr();
        let mut handles = Vec::new();
        for tid in 0..16u64 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let base = 1 + tid * 1000;
                for k in base..base + 50 {
                    assert_eq!(
                        c.request_line(&format!("P {k} {k}")).unwrap(),
                        "-"
                    );
                }
                let ops: Vec<MapOp> =
                    (base..base + 50).map(MapOp::Get).collect();
                let got = c.batch(&ops).unwrap();
                assert!(got
                    .iter()
                    .zip(base..base + 50)
                    .all(|(v, k)| *v == Some(k)));
            }));
        }
        for th in handles {
            th.join().unwrap();
        }
        assert_eq!(m.len_quiesced(), 16 * 50);
        h.shutdown();
    }
}
