//! **Key→value service layer** — the repro as a servable KV store.
//!
//! The paper's cost model charges every operation one K-CAS descriptor
//! acquire/release plus a thread-local scratch borrow; Maier, Sanders &
//! Dementiev ("Concurrent Hash Tables: Fast and General(?)!") observe
//! that the map interface *plus bulk operations* is where concurrent
//! tables earn their keep in real systems. This module supplies both
//! halves on top of [`crate::maps::ConcurrentMap`]:
//!
//! * [`batch`] — the batched operation API
//!   (`apply_batch(&[MapOp]) -> Vec<MapReply>`): a batch is grouped by
//!   shard inside the `Sharded` facade and each shard's run executes
//!   against **one** thread-local `OpBuilder`/scratch borrow, amortising
//!   the per-op descriptor setup. Also hosts the timed batched driver
//!   behind the `fig14_batching` experiment.
//! * [`frame`] — the wire-protocol codec (line grammar, `B <n>` batch
//!   framing, reply formatting) plus the incremental [`frame::FrameDecoder`]
//!   both front-ends decode through, so their reply streams cannot
//!   drift.
//! * [`server`] — the thread-per-connection front-end (std threads +
//!   channels): a reader stage decodes frames while the connection
//!   thread applies each with one `apply_batch` call. Two OS threads
//!   per connection; simple, and fastest at small connection counts.
//!   Returns a [`server::ServerHandle`] whose `shutdown` joins every
//!   spawned thread.
//! * [`reactor`] — the epoll event-loop front-end (raw syscall
//!   bindings in [`crate::util::sys`]): N nonblocking connections per
//!   worker thread, ops accumulated **across ready sockets** into one
//!   `apply_batch_hashed` call per wake-up, EPOLLOUT-driven write
//!   flushing with high/low-water backpressure, eventfd-signalled
//!   graceful shutdown. This is the front-end that scales connection
//!   count past the thread scheduler. Accepts either through a
//!   dealing accept thread (legacy) or per-worker `SO_REUSEPORT`
//!   listeners.
//! * [`uring`] — the io_uring completion-ring front-end: same
//!   wake-batch structure as the reactor, but reads, writes, and
//!   accepts are ring submissions, so a wake-batch costs one
//!   `io_uring_enter` in each direction regardless of how many
//!   connections participate, and per-worker `SO_REUSEPORT` listeners
//!   remove the accept hand-off hop entirely. Falls back to the
//!   reactor on kernels without io_uring, behind the same API.
//!   `fig17_frontend` measures all three backends against each other
//!   (including a syscalls-per-op series) and asserts their reply
//!   streams are identical.
//!
//! All of it speaks the full **conditional-first** op vocabulary
//! ([`crate::maps::MapOp`]: `CmpEx`/`GetOrInsert`/`FetchAdd` next to
//! the unconditional trio; wire verbs `C`/`U`/`A`), so check-then-act
//! traffic — counters, leases, optimistic updates — runs as native
//! single-K-CAS operations instead of read-check-write round trips.
//! Batched traffic carries its routing hash all the way down
//! ([`crate::maps::ConcurrentMap::apply_batch_hashed`]): one SplitMix64
//! per op, same as the single-op path.
//!
//! Maps are named by [`crate::maps::MapKind`] specs
//! (`sharded-kcas-rh-map:16` etc.); the CLI entry points are
//! `crh fig14_batching` (batching sweep), `crh fig16_rmw`
//! (conditional-RMW counter workload), `crh fig17_frontend`
//! (front-end comparison), `crh serve` (run either server until
//! killed), and `crh stats` (query a running server's telemetry).
//!
//! Every front-end answers the `STATS` wire verb with one line of
//! compact JSON rendered from [`crate::util::metrics`] — same codec
//! ([`frame::Frame::Stats`]), same renderer, so the schema cannot
//! drift between backends.

pub mod batch;
pub mod frame;
pub mod reactor;
pub mod server;
pub mod uring;

use std::io;
use std::net::SocketAddr;
use std::sync::Arc;

use crate::maps::ConcurrentMap;

/// Which server front-end to run. All three speak the identical wire
/// protocol through [`frame`]; they differ only in how sockets are
/// multiplexed onto threads and syscalls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Thread-per-connection ([`server`]).
    Threads,
    /// Epoll event loop ([`reactor`]).
    Reactor,
    /// io_uring completion rings ([`uring`]); transparently serves
    /// through the reactor when the kernel lacks io_uring.
    Uring,
}

impl Backend {
    /// All backends, in bench/matrix order.
    pub const ALL: [Backend; 3] =
        [Backend::Threads, Backend::Reactor, Backend::Uring];

    /// The flag/bench label for this backend.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Threads => "threads",
            Backend::Reactor => "reactor",
            Backend::Uring => "uring",
        }
    }

    /// Parse a `--backend` flag value (aliases: `thread`, `epoll`,
    /// `io_uring`).
    pub fn parse(s: &str) -> Option<Backend> {
        match s.trim().to_ascii_lowercase().as_str() {
            "threads" | "thread" => Some(Backend::Threads),
            "reactor" | "epoll" => Some(Backend::Reactor),
            "uring" | "io_uring" | "io-uring" => Some(Backend::Uring),
            _ => None,
        }
    }

    /// Spawn a server for `map` on an ephemeral localhost port.
    /// `workers` is ignored by the threaded backend (it spawns per
    /// connection); 0 means [`reactor::default_workers`] for the
    /// event-loop backends.
    pub fn spawn(
        self,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<FrontendHandle> {
        match self {
            Backend::Threads => {
                server::spawn_server(map).map(FrontendHandle::Threads)
            }
            Backend::Reactor => reactor::spawn_server_epoll(map, workers)
                .map(FrontendHandle::Reactor),
            Backend::Uring => uring::spawn_server_uring(map, workers)
                .map(FrontendHandle::Uring),
        }
    }

    /// Serve `map` on an already-bound listener (e.g. from `crh
    /// serve --addr`). See [`Backend::spawn`] for `workers`.
    pub fn serve(
        self,
        listener: std::net::TcpListener,
        map: Arc<dyn ConcurrentMap>,
        workers: usize,
    ) -> io::Result<FrontendHandle> {
        match self {
            Backend::Threads => {
                server::spawn_server_on(listener, map).map(FrontendHandle::Threads)
            }
            Backend::Reactor => reactor::serve_epoll(listener, map, workers)
                .map(FrontendHandle::Reactor),
            Backend::Uring => uring::serve_uring(listener, map, workers)
                .map(FrontendHandle::Uring),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A running server of any backend: one `addr`/`shutdown` surface so
/// benches, tests, and the CLI can treat the three interchangeably.
pub enum FrontendHandle {
    Threads(server::ServerHandle),
    Reactor(reactor::ReactorHandle),
    Uring(uring::UringHandle),
}

impl FrontendHandle {
    /// The address the server is listening on.
    pub fn addr(&self) -> SocketAddr {
        match self {
            FrontendHandle::Threads(h) => h.addr(),
            FrontendHandle::Reactor(h) => h.addr(),
            FrontendHandle::Uring(h) => h.addr(),
        }
    }

    /// Stop the server and join every thread it spawned.
    pub fn shutdown(self) {
        match self {
            FrontendHandle::Threads(h) => h.shutdown(),
            FrontendHandle::Reactor(h) => h.shutdown(),
            FrontendHandle::Uring(h) => h.shutdown(),
        }
    }
}

/// Best-effort text of a contained panic payload (the `&str` /
/// `String` shapes `panic!` produces); both front-ends log it with
/// the connection id and op count when a batch unwinds.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "<non-string panic payload>"
    }
}
