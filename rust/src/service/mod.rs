//! **Key→value service layer** — the repro as a servable KV store.
//!
//! The paper's cost model charges every operation one K-CAS descriptor
//! acquire/release plus a thread-local scratch borrow; Maier, Sanders &
//! Dementiev ("Concurrent Hash Tables: Fast and General(?)!") observe
//! that the map interface *plus bulk operations* is where concurrent
//! tables earn their keep in real systems. This module supplies both
//! halves on top of [`crate::maps::ConcurrentMap`]:
//!
//! * [`batch`] — the batched operation API
//!   (`apply_batch(&[MapOp]) -> Vec<MapReply>`): a batch is grouped by
//!   shard inside the `Sharded` facade and each shard's run executes
//!   against **one** thread-local `OpBuilder`/scratch borrow, amortising
//!   the per-op descriptor setup. Also hosts the timed batched driver
//!   behind the `fig14_batching` experiment.
//! * [`server`] — a dependency-free (std threads + channels) TCP
//!   request pipeline speaking a line-oriented protocol with multi-op
//!   batch frames (`B <n>`), replacing the one-op-per-line loop the
//!   `kv_service` example shipped with. Each connection decouples
//!   parsing from table work so clients can stream frames without
//!   waiting for replies.
//!
//! Both halves speak the full **conditional-first** op vocabulary
//! ([`crate::maps::MapOp`]: `CmpEx`/`GetOrInsert`/`FetchAdd` next to
//! the unconditional trio; wire verbs `C`/`U`/`A`), so check-then-act
//! traffic — counters, leases, optimistic updates — runs as native
//! single-K-CAS operations instead of read-check-write round trips.
//! Batched traffic carries its routing hash all the way down
//! ([`crate::maps::ConcurrentMap::apply_batch_hashed`]): one SplitMix64
//! per op, same as the single-op path.
//!
//! Maps are named by [`crate::maps::MapKind`] specs
//! (`sharded-kcas-rh-map:16` etc.); the CLI entry points are
//! `crh fig14_batching` (batching sweep) and `crh fig16_rmw`
//! (conditional-RMW counter workload under contention skew).

pub mod batch;
pub mod server;
