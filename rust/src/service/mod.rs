//! **Key→value service layer** — the repro as a servable KV store.
//!
//! The paper's cost model charges every operation one K-CAS descriptor
//! acquire/release plus a thread-local scratch borrow; Maier, Sanders &
//! Dementiev ("Concurrent Hash Tables: Fast and General(?)!") observe
//! that the map interface *plus bulk operations* is where concurrent
//! tables earn their keep in real systems. This module supplies both
//! halves on top of [`crate::maps::ConcurrentMap`]:
//!
//! * [`batch`] — the batched operation API
//!   (`apply_batch(&[MapOp]) -> Vec<MapReply>`): a batch is grouped by
//!   shard inside the `Sharded` facade and each shard's run executes
//!   against **one** thread-local `OpBuilder`/scratch borrow, amortising
//!   the per-op descriptor setup. Also hosts the timed batched driver
//!   behind the `fig14_batching` experiment.
//! * [`frame`] — the wire-protocol codec (line grammar, `B <n>` batch
//!   framing, reply formatting) plus the incremental [`frame::FrameDecoder`]
//!   both front-ends decode through, so their reply streams cannot
//!   drift.
//! * [`server`] — the thread-per-connection front-end (std threads +
//!   channels): a reader stage decodes frames while the connection
//!   thread applies each with one `apply_batch` call. Two OS threads
//!   per connection; simple, and fastest at small connection counts.
//!   Returns a [`server::ServerHandle`] whose `shutdown` joins every
//!   spawned thread.
//! * [`reactor`] — the epoll event-loop front-end (raw syscall
//!   bindings in [`crate::util::sys`]): N nonblocking connections per
//!   worker thread, ops accumulated **across ready sockets** into one
//!   `apply_batch_hashed` call per wake-up, EPOLLOUT-driven write
//!   flushing with high/low-water backpressure, eventfd-signalled
//!   graceful shutdown. This is the front-end that scales connection
//!   count past the thread scheduler; `fig17_frontend` measures the
//!   two against each other and asserts their reply streams are
//!   identical.
//!
//! All of it speaks the full **conditional-first** op vocabulary
//! ([`crate::maps::MapOp`]: `CmpEx`/`GetOrInsert`/`FetchAdd` next to
//! the unconditional trio; wire verbs `C`/`U`/`A`), so check-then-act
//! traffic — counters, leases, optimistic updates — runs as native
//! single-K-CAS operations instead of read-check-write round trips.
//! Batched traffic carries its routing hash all the way down
//! ([`crate::maps::ConcurrentMap::apply_batch_hashed`]): one SplitMix64
//! per op, same as the single-op path.
//!
//! Maps are named by [`crate::maps::MapKind`] specs
//! (`sharded-kcas-rh-map:16` etc.); the CLI entry points are
//! `crh fig14_batching` (batching sweep), `crh fig16_rmw`
//! (conditional-RMW counter workload), `crh fig17_frontend`
//! (front-end comparison), `crh serve` (run either server until
//! killed), and `crh stats` (query a running server's telemetry).
//!
//! Both front-ends answer the `STATS` wire verb with one line of
//! compact JSON rendered from [`crate::util::metrics`] — same codec
//! ([`frame::Frame::Stats`]), same renderer, so the schema cannot
//! drift between backends.

pub mod batch;
pub mod frame;
pub mod reactor;
pub mod server;

/// Best-effort text of a contained panic payload (the `&str` /
/// `String` shapes `panic!` produces); both front-ends log it with
/// the connection id and op count when a batch unwinds.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "<non-string panic payload>"
    }
}
