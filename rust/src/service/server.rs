//! Pipelined TCP front-end for a [`ConcurrentMap`] — dependency-free
//! (std threads + mpsc channels), replacing the one-op-per-line loop
//! the `kv_service` example originally shipped with.
//!
//! ## Protocol (line-oriented text)
//!
//! ```text
//! G <k>        get            → reply line: "<v>" or "-"
//! P <k> <v>    put (insert)   → previous "<v>" or "-"
//! D <k>        delete         → removed "<v>" or "-"
//! U <k> <v>    get-or-insert  → pre-existing "<v>", or "-" (inserted)
//! A <k> <d>    fetch-add      → previous "<v>", or "-" (was absent,
//!              now holds d; missing keys count as 0)
//! C <k> <e> <n>  compare-exchange; <e>/<n> are a value or "-"
//!              (absent) — the four corners of
//!              ConcurrentMap::compare_exchange → "OK" on commit,
//!              "!<v>" / "!-" with the witnessed value on failure
//! B <n>        batch frame: the next n lines are ops (any of the
//!              above); one reply line with n space-separated tokens
//! Q            quit (close the connection)
//! ```
//!
//! The conditional verbs (`C`/`U`/`A`) are the service-layer face of
//! the map's native K-CAS read-modify-write primitives: a client
//! counter is one `A` line, a lease acquire is `C <k> - <owner>`, a
//! lease release is `C <k> <owner> -` — no read-check-write round
//! trips, no server-side locking.
//!
//! Malformed or out-of-range requests get an `ERR <msg>` line and the
//! connection **stays up** — in particular keys outside
//! `[1, MAX_KEY]` are rejected at the protocol boundary with
//! `ERR key out of range` instead of tripping the table's `check_key`
//! assert and killing the connection thread (the old server's DoS bug),
//! and values (including `C` operands and `A` deltas) above
//! `kcas::MAX_VALUE` get `ERR value out of range`.
//! A batch frame is validated as a unit: if any member op is invalid
//! the whole frame is rejected with a single `ERR` line and nothing is
//! applied.
//!
//! ## Pipeline shape
//!
//! Each connection runs two stages connected by a bounded channel:
//! a *reader* thread parses lines into frames while the connection
//! thread applies each frame with one [`ConcurrentMap::apply_batch`]
//! call and writes the reply. Clients may therefore stream many frames
//! without waiting for replies (replies always come back in frame
//! order), overlapping network I/O with table work — batch frames
//! amortise syscalls and round trips on top of the descriptor-setup
//! amortisation `apply_batch` already provides.

use std::io::{self, BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;

use crate::kcas::MAX_VALUE;
use crate::maps::{ConcurrentMap, MapOp, MapReply, MAX_KEY};

/// Largest accepted batch frame (bounds per-connection memory).
pub const MAX_BATCH: usize = 4096;
/// Frames buffered between the reader and the apply/write stage.
const PIPELINE_DEPTH: usize = 64;

pub const ERR_KEY_RANGE: &str = "ERR key out of range";
pub const ERR_VALUE_RANGE: &str = "ERR value out of range";
pub const ERR_BAD_REQUEST: &str = "ERR bad request";
pub const ERR_BAD_BATCH: &str = "ERR bad batch size";
pub const ERR_SERVER: &str = "ERR server error";

fn parse_key(s: &str) -> Result<u64, &'static str> {
    let k: u64 = s.parse().map_err(|_| ERR_BAD_REQUEST)?;
    if !(1..=MAX_KEY).contains(&k) {
        return Err(ERR_KEY_RANGE);
    }
    Ok(k)
}

fn parse_value(s: &str) -> Result<u64, &'static str> {
    let v: u64 = s.parse().map_err(|_| ERR_BAD_REQUEST)?;
    if v > MAX_VALUE {
        return Err(ERR_VALUE_RANGE);
    }
    Ok(v)
}

/// `C` operand: a value or `-` for "absent".
fn parse_opt_value(s: &str) -> Result<Option<u64>, &'static str> {
    if s == "-" {
        return Ok(None);
    }
    parse_value(s).map(Some)
}

/// Parse one op line (`G <k>` / `P <k> <v>` / `D <k>` / `U <k> <v>` /
/// `A <k> <d>` / `C <k> <e> <n>`), enforcing the key and value ranges
/// at the protocol boundary.
pub fn parse_op(line: &str) -> Result<MapOp, &'static str> {
    let mut it = line.split_whitespace();
    let toks = [it.next(), it.next(), it.next(), it.next(), it.next()];
    match toks {
        [Some("G"), Some(k), None, None, None] => {
            Ok(MapOp::Get(parse_key(k)?))
        }
        [Some("D"), Some(k), None, None, None] => {
            Ok(MapOp::Remove(parse_key(k)?))
        }
        [Some("P"), Some(k), Some(v), None, None] => {
            Ok(MapOp::Insert(parse_key(k)?, parse_value(v)?))
        }
        [Some("U"), Some(k), Some(v), None, None] => {
            Ok(MapOp::GetOrInsert(parse_key(k)?, parse_value(v)?))
        }
        [Some("A"), Some(k), Some(d), None, None] => {
            Ok(MapOp::FetchAdd(parse_key(k)?, parse_value(d)?))
        }
        [Some("C"), Some(k), Some(e), Some(n), None] => Ok(MapOp::CmpEx(
            parse_key(k)?,
            parse_opt_value(e)?,
            parse_opt_value(n)?,
        )),
        _ => Err(ERR_BAD_REQUEST),
    }
}

/// Append one reply token: the value or `-` for value-shaped replies,
/// `OK` / `!<witness>` / `!-` for `CmpEx`.
pub fn push_reply(reply: MapReply, out: &mut String) {
    use std::fmt::Write as _;
    match reply {
        MapReply::CmpEx(Ok(())) => out.push_str("OK"),
        MapReply::CmpEx(Err(w)) => {
            out.push('!');
            match w {
                Some(v) => write!(out, "{v}").expect("write to String"),
                None => out.push('-'),
            }
        }
        _ => match reply.value() {
            Some(v) => write!(out, "{v}").expect("write to String"),
            None => out.push('-'),
        },
    }
}

/// One parsed request frame.
enum Frame {
    /// Ops to apply with a single `apply_batch` call.
    Batch(Vec<MapOp>),
    /// Protocol error to report; nothing is applied.
    Err(&'static str),
    /// Client said `Q`.
    Quit,
}

/// Reader stage: parse lines into frames until EOF/`Q`, handing them to
/// the apply/write stage through the bounded channel.
fn read_frames(stream: TcpStream, tx: mpsc::SyncSender<Frame>) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            return; // EOF or broken pipe: dropping tx drains the stage
        }
        let head = line.trim();
        if head.is_empty() {
            continue;
        }
        if head == "Q" {
            let _ = tx.send(Frame::Quit);
            return;
        }
        let frame = if let Some(rest) = head.strip_prefix("B ") {
            match rest.trim().parse::<usize>() {
                Ok(n) if (1..=MAX_BATCH).contains(&n) => {
                    let mut ops = Vec::with_capacity(n);
                    let mut err: Option<&'static str> = None;
                    for _ in 0..n {
                        line.clear();
                        if reader.read_line(&mut line).unwrap_or(0) == 0 {
                            return; // truncated frame: connection gone
                        }
                        // Keep consuming the frame even after an error
                        // so the stream stays in sync.
                        match parse_op(line.trim()) {
                            Ok(op) => ops.push(op),
                            Err(e) => err = err.or(Some(e)),
                        }
                    }
                    match err {
                        None => Frame::Batch(ops),
                        Some(e) => Frame::Err(e),
                    }
                }
                _ => Frame::Err(ERR_BAD_BATCH),
            }
        } else {
            match parse_op(head) {
                Ok(op) => Frame::Batch(vec![op]),
                Err(e) => Frame::Err(e),
            }
        };
        if tx.send(frame).is_err() {
            return; // writer stage gone
        }
    }
}

/// Apply/write stage: one `apply_batch` call and one buffered write per
/// frame, replies in frame order.
fn serve_conn(stream: TcpStream, map: Arc<dyn ConcurrentMap>) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::sync_channel::<Frame>(PIPELINE_DEPTH);
    let reader = std::thread::spawn(move || read_frames(read_half, tx));
    let mut out = BufWriter::new(stream);
    let mut replies: Vec<MapReply> = Vec::new();
    let mut line = String::new();
    for frame in rx {
        line.clear();
        let mut fatal = false;
        match frame {
            Frame::Quit => break,
            Frame::Err(e) => line.push_str(e),
            Frame::Batch(ops) => {
                // Range checks happened at parse time, but the table
                // can still panic on in-range input (e.g. the "map is
                // full" capacity assert). Contain it: report a server
                // error and drop the connection instead of dying with
                // no reply — the same connection-killing failure mode
                // the key-range validation exists to prevent. The ops
                // clear their per-thread scratch on entry, so the
                // thread-local state stays reusable after an unwind.
                let applied = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        map.apply_batch(&ops, &mut replies)
                    }),
                );
                if applied.is_ok() {
                    for (i, &r) in replies.iter().enumerate() {
                        if i > 0 {
                            line.push(' ');
                        }
                        push_reply(r, &mut line);
                    }
                } else {
                    line.push_str(ERR_SERVER);
                    fatal = true;
                }
            }
        }
        line.push('\n');
        if out.write_all(line.as_bytes()).is_err() || out.flush().is_err() {
            break;
        }
        if fatal {
            break;
        }
    }
    drop(out); // close the write half before reaping the reader
    let _ = reader.join();
}

/// Accept loop: one pipelined connection handler per client.
pub fn serve(listener: TcpListener, map: Arc<dyn ConcurrentMap>) {
    for stream in listener.incoming() {
        let Ok(stream) = stream else { break };
        let map = map.clone();
        std::thread::spawn(move || serve_conn(stream, map));
    }
}

/// Bind an ephemeral localhost port, serve `map` on a background
/// thread, and return the address (examples and tests).
pub fn spawn_ephemeral(map: Arc<dyn ConcurrentMap>) -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind 127.0.0.1:0");
    let addr = listener.local_addr().expect("local_addr");
    std::thread::spawn(move || serve(listener, map));
    addr
}

/// Append one op in wire format (plus newline).
fn push_op(op: MapOp, out: &mut String) {
    use std::fmt::Write as _;
    let opt = |v: Option<u64>| match v {
        Some(v) => v.to_string(),
        None => "-".into(),
    };
    match op {
        MapOp::Get(k) => writeln!(out, "G {k}"),
        MapOp::Insert(k, v) => writeln!(out, "P {k} {v}"),
        MapOp::Remove(k) => writeln!(out, "D {k}"),
        MapOp::GetOrInsert(k, v) => writeln!(out, "U {k} {v}"),
        MapOp::FetchAdd(k, d) => writeln!(out, "A {k} {d}"),
        MapOp::CmpEx(k, e, n) => writeln!(out, "C {k} {} {}", opt(e), opt(n)),
    }
    .expect("write to String");
}

/// Minimal blocking client for the wire protocol (examples, tests,
/// and the example's load generator).
pub struct Client {
    reader: BufReader<TcpStream>,
    out: TcpStream,
    frame: String,
    reply: String,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> io::Result<Client> {
        let out = TcpStream::connect(addr)?;
        out.set_nodelay(true)?;
        let reader = BufReader::new(out.try_clone()?);
        Ok(Client {
            reader,
            out,
            frame: String::new(),
            reply: String::new(),
        })
    }

    /// Send one raw request line, read one reply line (trimmed).
    pub fn request_line(&mut self, line: &str) -> io::Result<String> {
        self.out.write_all(line.as_bytes())?;
        self.out.write_all(b"\n")?;
        self.read_reply_line()
    }

    /// Send a batch of ops as one frame (a bare op line for a single
    /// op, a `B <n>` frame otherwise) in a single write, then read the
    /// reply line and parse its tokens. Protocol `ERR` replies surface
    /// as `io::ErrorKind::InvalidData`. Value-shaped convenience for
    /// `G`/`P`/`D`/`U`/`A` traffic; use [`Client::batch_typed`] when
    /// the batch contains `CmpEx` ops (their `OK`/`!` tokens don't fit
    /// an `Option<u64>`).
    pub fn batch(&mut self, ops: &[MapOp]) -> io::Result<Vec<Option<u64>>> {
        self.send_frame(ops)?;
        self.read_batch_reply(ops.len())
    }

    /// Send a batch and parse the reply into full [`MapReply`] values
    /// (token shape inferred from each op's variant) — the conditional
    /// verbs' round trip.
    pub fn batch_typed(&mut self, ops: &[MapOp]) -> io::Result<Vec<MapReply>> {
        self.send_frame(ops)?;
        let line = self.read_reply_line()?;
        if line.starts_with("ERR") {
            return Err(io::Error::new(io::ErrorKind::InvalidData, line));
        }
        let bad = |tok: &str| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("bad reply token {tok:?}"),
            )
        };
        let parse_val = |tok: &str| -> io::Result<Option<u64>> {
            match tok {
                "-" => Ok(None),
                v => v.parse::<u64>().map(Some).map_err(|_| bad(v)),
            }
        };
        let mut toks = line.split_whitespace();
        let mut replies = Vec::with_capacity(ops.len());
        for &op in ops {
            let tok = toks.next().ok_or_else(|| bad(""))?;
            replies.push(match op {
                MapOp::CmpEx(..) => MapReply::CmpEx(match tok {
                    "OK" => Ok(()),
                    "!-" => Err(None),
                    t if t.starts_with('!') => Err(Some(
                        t[1..].parse::<u64>().map_err(|_| bad(t))?,
                    )),
                    t => return Err(bad(t)),
                }),
                MapOp::Get(_) => MapReply::Value(parse_val(tok)?),
                MapOp::Insert(..) => MapReply::Prev(parse_val(tok)?),
                MapOp::Remove(_) => MapReply::Removed(parse_val(tok)?),
                MapOp::GetOrInsert(..) => MapReply::Existing(parse_val(tok)?),
                MapOp::FetchAdd(..) => MapReply::Added(parse_val(tok)?),
            });
        }
        if toks.next().is_some() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "trailing reply tokens",
            ));
        }
        Ok(replies)
    }

    /// Write one frame without waiting for the reply (pipelining).
    pub fn send_frame(&mut self, ops: &[MapOp]) -> io::Result<()> {
        use std::fmt::Write as _;
        assert!(!ops.is_empty() && ops.len() <= MAX_BATCH);
        self.frame.clear();
        if ops.len() > 1 {
            writeln!(self.frame, "B {}", ops.len()).expect("write to String");
        }
        for &op in ops {
            push_op(op, &mut self.frame);
        }
        self.out.write_all(self.frame.as_bytes())
    }

    /// Read and parse one batch reply of `n` ops (pairs with
    /// [`Client::send_frame`]; replies arrive in frame order).
    pub fn read_batch_reply(
        &mut self,
        n: usize,
    ) -> io::Result<Vec<Option<u64>>> {
        let line = self.read_reply_line()?;
        if line.starts_with("ERR") {
            return Err(io::Error::new(io::ErrorKind::InvalidData, line));
        }
        let mut vals = Vec::with_capacity(n);
        for tok in line.split_whitespace() {
            vals.push(match tok {
                "-" => None,
                v => Some(v.parse::<u64>().map_err(|_| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("bad reply token {v:?}"),
                    )
                })?),
            });
        }
        if vals.len() != n {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected {n} reply tokens, got {}", vals.len()),
            ));
        }
        Ok(vals)
    }

    fn read_reply_line(&mut self) -> io::Result<String> {
        self.reply.clear();
        if self.reader.read_line(&mut self.reply)? == 0 {
            return Err(io::ErrorKind::UnexpectedEof.into());
        }
        Ok(self.reply.trim_end().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_op_accepts_valid_lines() {
        assert_eq!(parse_op("G 5"), Ok(MapOp::Get(5)));
        assert_eq!(parse_op("P 5 10"), Ok(MapOp::Insert(5, 10)));
        assert_eq!(parse_op("D 5"), Ok(MapOp::Remove(5)));
        assert_eq!(parse_op("  G   5  "), Ok(MapOp::Get(5)));
        assert_eq!(parse_op(&format!("G {MAX_KEY}")), Ok(MapOp::Get(MAX_KEY)));
        assert_eq!(
            parse_op(&format!("P 1 {MAX_VALUE}")),
            Ok(MapOp::Insert(1, MAX_VALUE))
        );
    }

    #[test]
    fn parse_op_rejects_out_of_range_keys() {
        // The old server's DoS: any k >= 1 was forwarded to the table,
        // and k > MAX_KEY tripped check_key's assert mid-connection.
        assert_eq!(parse_op(&format!("G {}", MAX_KEY + 1)), Err(ERR_KEY_RANGE));
        assert_eq!(parse_op("G 0"), Err(ERR_KEY_RANGE));
        assert_eq!(parse_op(&format!("P {} 1", u64::MAX)), Err(ERR_KEY_RANGE));
        assert_eq!(parse_op("D 0"), Err(ERR_KEY_RANGE));
        assert_eq!(
            parse_op(&format!("P 1 {}", MAX_VALUE + 1)),
            Err(ERR_VALUE_RANGE)
        );
    }

    #[test]
    fn parse_op_rejects_malformed_lines() {
        for bad in [
            "", "G", "P 1", "G x", "P 1 y", "X 1", "G 1 2", "P 1 2 3", "Q 1",
        ] {
            assert_eq!(parse_op(bad), Err(ERR_BAD_REQUEST), "line {bad:?}");
        }
    }

    #[test]
    fn parse_op_accepts_conditional_verbs() {
        assert_eq!(parse_op("U 5 10"), Ok(MapOp::GetOrInsert(5, 10)));
        assert_eq!(parse_op("A 5 3"), Ok(MapOp::FetchAdd(5, 3)));
        assert_eq!(parse_op("C 5 - 10"), Ok(MapOp::CmpEx(5, None, Some(10))));
        assert_eq!(parse_op("C 5 10 -"), Ok(MapOp::CmpEx(5, Some(10), None)));
        assert_eq!(
            parse_op("C 5 10 11"),
            Ok(MapOp::CmpEx(5, Some(10), Some(11)))
        );
        assert_eq!(parse_op("C 5 - -"), Ok(MapOp::CmpEx(5, None, None)));
        // Range / shape enforcement.
        assert_eq!(
            parse_op(&format!("A 5 {}", MAX_VALUE + 1)),
            Err(ERR_VALUE_RANGE)
        );
        assert_eq!(
            parse_op(&format!("C 5 - {}", MAX_VALUE + 1)),
            Err(ERR_VALUE_RANGE)
        );
        assert_eq!(parse_op("C 0 - 1"), Err(ERR_KEY_RANGE));
        for bad in ["U 5", "A 5", "C 5 -", "C 5 - - -", "C 5 x 1", "U 5 1 2"] {
            assert_eq!(parse_op(bad), Err(ERR_BAD_REQUEST), "line {bad:?}");
        }
    }

    #[test]
    fn cmpex_reply_tokens() {
        let mut s = String::new();
        push_reply(MapReply::CmpEx(Ok(())), &mut s);
        s.push(' ');
        push_reply(MapReply::CmpEx(Err(Some(7))), &mut s);
        s.push(' ');
        push_reply(MapReply::CmpEx(Err(None)), &mut s);
        s.push(' ');
        push_reply(MapReply::Existing(None), &mut s);
        s.push(' ');
        push_reply(MapReply::Added(Some(3)), &mut s);
        assert_eq!(s, "OK !7 !- - 3");
    }

    #[test]
    fn reply_tokens_round_trip() {
        let mut s = String::new();
        push_reply(MapReply::Value(Some(42)), &mut s);
        s.push(' ');
        push_reply(MapReply::Prev(None), &mut s);
        s.push(' ');
        push_reply(MapReply::Removed(Some(7)), &mut s);
        assert_eq!(s, "42 - 7");
    }
}
