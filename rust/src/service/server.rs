//! Pipelined thread-per-connection TCP front-end for a
//! [`ConcurrentMap`] — dependency-free (std threads + mpsc channels).
//! The wire protocol lives in [`super::frame`] (one grammar shared
//! with the epoll front-end, [`super::reactor`], so the two backends
//! answer bit-identically); this module supplies the blocking
//! transport around it plus the [`Client`] used by examples, tests,
//! and the benchmark load generators.
//!
//! ## Pipeline shape
//!
//! Each connection runs two stages connected by a bounded channel:
//! a *reader* thread feeds received bytes through a [`FrameDecoder`]
//! while the connection thread applies each frame with one
//! [`ConcurrentMap::apply_batch`] call and writes the reply. Clients
//! may therefore stream many frames without waiting for replies
//! (replies always come back in frame order), overlapping network I/O
//! with table work — batch frames amortise syscalls and round trips on
//! top of the descriptor-setup amortisation `apply_batch` already
//! provides.
//!
//! ## Lifecycle
//!
//! [`spawn_server`] returns a [`ServerHandle`]; dropping it detaches
//! the server (it keeps serving until process exit, the old
//! behaviour), while [`ServerHandle::shutdown`] closes the listener
//! and every live connection and **joins** the accept loop and all
//! connection threads — so `cargo test` no longer strands a pair of
//! blocked threads per connection ever served.
//!
//! This front-end spawns two OS threads per connection; it saturates a
//! table at small connection counts but dies at C10K. The epoll
//! reactor ([`super::reactor`]) serves the same protocol with a fixed
//! worker pool; `fig17_frontend` measures the crossover.

use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::maps::{ConcurrentMap, MapError, MapOp, MapReply};
use crate::service::frame::{
    push_op, push_reply, txn_err_line, Frame, FrameDecoder, ERR_SERVER,
    ERR_TXN_CONFLICT, ERR_TXN_UNSUPPORTED, MAX_BATCH,
};
use crate::service::panic_message;
use crate::util::metrics::{metrics, stats_line};

// Re-export the codec surface under its historical home so protocol
// users keep one import path per front-end.
pub use crate::service::frame::{
    parse_op, ERR_BAD_BATCH, ERR_BAD_REQUEST, ERR_KEY_RANGE, ERR_VALUE_RANGE,
};

/// Frames buffered between the reader and the apply/write stage.
const PIPELINE_DEPTH: usize = 64;

/// Reader stage: decode received bytes into frames until EOF/`Q`,
/// handing them to the apply/write stage through the bounded channel.
fn read_frames(mut stream: TcpStream, tx: mpsc::SyncSender<Frame>) {
    let mut dec = FrameDecoder::new();
    let mut chunk = [0u8; 4096];
    loop {
        metrics().syscalls_thread.incr();
        let n = match stream.read(&mut chunk) {
            Ok(0) => {
                // EOF: a final line without a trailing newline still
                // deserves its reply (`printf 'G 5' |` clients), as it
                // did under the old read_line reader. Dropping tx then
                // drains the stage.
                if let Some(frame) = dec.finish() {
                    let _ = tx.send(frame);
                }
                return;
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return, // broken pipe / shutdown
        };
        metrics().bytes_in_thread.add(n as u64);
        dec.feed(&chunk[..n]);
        while let Some(frame) = dec.next_frame() {
            let quit = matches!(frame, Frame::Quit);
            if tx.send(frame).is_err() || quit {
                return; // writer stage gone, or client said Q
            }
        }
    }
}

/// Apply/write stage: one `apply_batch` call and one buffered write per
/// frame, replies in frame order.
fn serve_conn(stream: TcpStream, map: Arc<dyn ConcurrentMap>, conn_id: u64) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let Ok(close_half) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::sync_channel::<Frame>(PIPELINE_DEPTH);
    let reader = std::thread::spawn(move || read_frames(read_half, tx));
    let mut out = io::BufWriter::new(stream);
    let mut replies: Vec<MapReply> = Vec::new();
    let mut line = String::new();
    for frame in rx {
        line.clear();
        let mut fatal = false;
        match frame {
            Frame::Quit => break,
            Frame::Err(e) => line.push_str(e),
            Frame::Stats => line.push_str(&stats_line()),
            Frame::Batch(ops) => {
                // Range checks happened at parse time, but the table
                // can still panic on in-range input (e.g. the "map is
                // full" capacity assert). Contain it: report a server
                // error and drop the connection instead of dying with
                // no reply — the same connection-killing failure mode
                // the key-range validation exists to prevent. The ops
                // clear their per-thread scratch on entry, so the
                // thread-local state stays reusable after an unwind.
                let applied = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        map.apply_batch(&ops, &mut replies)
                    }),
                );
                match applied {
                    Ok(()) => {
                        for (i, &r) in replies.iter().enumerate() {
                            if i > 0 {
                                line.push(' ');
                            }
                            push_reply(r, &mut line);
                        }
                    }
                    Err(payload) => {
                        metrics().server_panics.incr();
                        eprintln!(
                            "crh-server: contained panic on conn {conn_id} \
                             ({} ops): {}",
                            ops.len(),
                            panic_message(payload.as_ref()),
                        );
                        line.push_str(ERR_SERVER);
                        fatal = true;
                    }
                }
            }
            Frame::Txn(ops) => {
                // Same containment as Batch; the commit itself is
                // all-or-nothing, so a typed abort is an ordinary
                // reply line, not a connection event.
                let applied = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| map.apply_txn(&ops)),
                );
                match applied {
                    Ok(Ok(replies)) => {
                        for (i, &r) in replies.iter().enumerate() {
                            if i > 0 {
                                line.push(' ');
                            }
                            push_reply(r, &mut line);
                        }
                    }
                    Ok(Err(e)) => line.push_str(txn_err_line(&e)),
                    Err(payload) => {
                        metrics().server_panics.incr();
                        eprintln!(
                            "crh-server: contained panic on conn {conn_id} \
                             (txn, {} ops): {}",
                            ops.len(),
                            panic_message(payload.as_ref()),
                        );
                        line.push_str(ERR_SERVER);
                        fatal = true;
                    }
                }
            }
        }
        line.push('\n');
        // One buffered write + flush per frame ≈ one `write` syscall.
        metrics().syscalls_thread.incr();
        if out.write_all(line.as_bytes()).is_err() || out.flush().is_err() {
            break;
        }
        metrics().bytes_out_thread.add(line.len() as u64);
        if fatal {
            break;
        }
    }
    // Shut the socket down (both halves) to unblock the reader's
    // pending read; plain drop would leave it parked until the client
    // hung up — the thread leak this handle-based lifecycle closes.
    drop(out);
    let _ = close_half.shutdown(Shutdown::Both);
    let _ = reader.join();
}

/// State shared between the accept loop and the shutdown handle.
struct Shared {
    stop: AtomicBool,
    /// Read-half clones of every live connection, so shutdown can
    /// unblock their reader threads; connection threads deregister
    /// themselves on exit.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

/// Handle to a running thread-per-connection server.
///
/// Dropping the handle detaches the server; [`ServerHandle::shutdown`]
/// stops it and joins every thread it ever spawned.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The address the server is listening on.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close every live connection, and join the
    /// accept loop plus all connection threads.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // The accept loop sits in a blocking `accept`; a throwaway
        // connection wakes it so it can observe the stop flag (it then
        // sweeps and joins the connection threads itself).
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Accept loop: one pipelined connection handler per client; on stop,
/// closes every live connection and joins all handlers (it owns the
/// listener, so returning also closes the listening socket).
fn accept_loop(
    listener: TcpListener,
    map: Arc<dyn ConcurrentMap>,
    shared: Arc<Shared>,
) {
    let mut workers: Vec<JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { break };
        // ORDERING: a fresh-id ticket — uniqueness comes from the RMW
        // itself; the id is handed to the handler thread through the
        // spawn, which synchronises.
        let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap().insert(id, clone);
        }
        let map = map.clone();
        let shared = shared.clone();
        workers.push(std::thread::spawn(move || {
            serve_conn(stream, map, id);
            shared.conns.lock().unwrap().remove(&id);
        }));
    }
    // Unblock every connection's reader, then reap the handlers.
    for s in shared.conns.lock().unwrap().values() {
        let _ = s.shutdown(Shutdown::Both);
    }
    for h in workers {
        let _ = h.join();
    }
}

/// Serve `map` on `listener` from a background accept thread.
pub fn spawn_server_on(
    listener: TcpListener,
    map: Arc<dyn ConcurrentMap>,
) -> io::Result<ServerHandle> {
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        stop: AtomicBool::new(false),
        conns: Mutex::new(HashMap::new()),
        next_id: AtomicU64::new(0),
    });
    let accept = {
        let shared = shared.clone();
        std::thread::spawn(move || accept_loop(listener, map, shared))
    };
    Ok(ServerHandle { addr, shared, accept: Some(accept) })
}

/// Bind an ephemeral localhost port and serve `map` (examples, tests,
/// benches). The returned handle's [`ServerHandle::shutdown`] joins
/// every spawned thread.
pub fn spawn_server(map: Arc<dyn ConcurrentMap>) -> io::Result<ServerHandle> {
    spawn_server_on(TcpListener::bind("127.0.0.1:0")?, map)
}

/// What a typed transaction round trip can fail with: the server's
/// typed abort (mapped back onto [`MapError`], so callers match on the
/// same vocabulary as the in-process [`ConcurrentMap::apply_txn`]), or
/// a transport/framing failure.
#[derive(Debug)]
pub enum WireError {
    /// The server answered with a typed transaction abort line
    /// (`ERR txn conflict` / `ERR txn unsupported`). Nothing was
    /// applied; a conflict is retryable.
    Txn(MapError),
    /// Transport or reply-parse failure.
    Io(io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Txn(e) => write!(f, "transaction aborted: {e}"),
            WireError::Io(e) => write!(f, "wire error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Parse one reply line's space-separated tokens into typed
/// [`MapReply`] values, the token shape inferred from each op's
/// variant — the single reply-segment parser behind both
/// [`Client::batch_typed`] and [`Client::txn`].
fn parse_typed_replies(ops: &[MapOp], line: &str) -> io::Result<Vec<MapReply>> {
    let bad = |tok: &str| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad reply token {tok:?}"),
        )
    };
    let parse_val = |tok: &str| -> io::Result<Option<u64>> {
        match tok {
            "-" => Ok(None),
            v => v.parse::<u64>().map(Some).map_err(|_| bad(v)),
        }
    };
    let mut toks = line.split_whitespace();
    let mut replies = Vec::with_capacity(ops.len());
    for &op in ops {
        let tok = toks.next().ok_or_else(|| bad(""))?;
        replies.push(match op {
            MapOp::CmpEx(..) => MapReply::CmpEx(match tok {
                "OK" => Ok(()),
                "!-" => Err(None),
                t if t.starts_with('!') => {
                    Err(Some(t[1..].parse::<u64>().map_err(|_| bad(t))?))
                }
                t => return Err(bad(t)),
            }),
            MapOp::Get(_) => MapReply::Value(parse_val(tok)?),
            MapOp::Insert(..) => MapReply::Prev(parse_val(tok)?),
            MapOp::Remove(_) => MapReply::Removed(parse_val(tok)?),
            MapOp::GetOrInsert(..) => MapReply::Existing(parse_val(tok)?),
            MapOp::FetchAdd(..) => MapReply::Added(parse_val(tok)?),
        });
    }
    if toks.next().is_some() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "trailing reply tokens",
        ));
    }
    Ok(replies)
}

/// Minimal blocking client for the wire protocol (examples, tests,
/// and the benchmark load generators).
pub struct Client {
    reader: BufReader<TcpStream>,
    out: TcpStream,
    frame: String,
    reply: String,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> io::Result<Client> {
        let out = TcpStream::connect(addr)?;
        out.set_nodelay(true)?;
        let reader = BufReader::new(out.try_clone()?);
        Ok(Client {
            reader,
            out,
            frame: String::new(),
            reply: String::new(),
        })
    }

    /// Request a telemetry snapshot (`STATS` verb): one line of
    /// compact JSON rendered from the server's metrics registry.
    pub fn stats(&mut self) -> io::Result<String> {
        self.request_line("STATS")
    }

    /// Send one raw request line, read one reply line (trimmed).
    pub fn request_line(&mut self, line: &str) -> io::Result<String> {
        self.out.write_all(line.as_bytes())?;
        self.out.write_all(b"\n")?;
        self.read_reply_line()
    }

    /// Send raw bytes without waiting for replies (adversarial-framing
    /// tests and the equivalence trace drive arbitrary fragmentation
    /// through this).
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.out.write_all(bytes)
    }

    /// Send a batch of ops as one frame (a bare op line for a single
    /// op, a `B <n>` frame otherwise) in a single write, then read the
    /// reply line and parse its tokens. Protocol `ERR` replies surface
    /// as `io::ErrorKind::InvalidData`. Value-shaped convenience for
    /// `G`/`P`/`D`/`U`/`A` traffic; use [`Client::batch_typed`] when
    /// the batch contains `CmpEx` ops (their `OK`/`!` tokens don't fit
    /// an `Option<u64>`).
    pub fn batch(&mut self, ops: &[MapOp]) -> io::Result<Vec<Option<u64>>> {
        self.send_frame(ops)?;
        self.read_batch_reply(ops.len())
    }

    /// Send a batch and parse the reply into full [`MapReply`] values
    /// (token shape inferred from each op's variant) — the conditional
    /// verbs' round trip.
    pub fn batch_typed(&mut self, ops: &[MapOp]) -> io::Result<Vec<MapReply>> {
        self.send_frame(ops)?;
        let line = self.read_reply_line()?;
        if line.starts_with("ERR") {
            return Err(io::Error::new(io::ErrorKind::InvalidData, line));
        }
        parse_typed_replies(ops, &line)
    }

    /// Commit `ops` atomically on the server (`T <n>` frame) and parse
    /// the typed replies. A typed abort line comes back as
    /// [`WireError::Txn`] carrying the same [`MapError`] the in-process
    /// [`ConcurrentMap::apply_txn`] would return — conflict is
    /// retryable, unsupported is not; nothing was applied either way.
    pub fn txn(&mut self, ops: &[MapOp]) -> Result<Vec<MapReply>, WireError> {
        use std::fmt::Write as _;
        assert!(!ops.is_empty() && ops.len() <= MAX_BATCH);
        self.frame.clear();
        writeln!(self.frame, "T {}", ops.len()).expect("write to String");
        for &op in ops {
            push_op(op, &mut self.frame);
        }
        self.out.write_all(self.frame.as_bytes())?;
        let line = self.read_reply_line()?;
        if line == ERR_TXN_CONFLICT {
            return Err(WireError::Txn(MapError::TxnConflict));
        }
        if line == ERR_TXN_UNSUPPORTED {
            return Err(WireError::Txn(MapError::Unsupported));
        }
        if line.starts_with("ERR") {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidData,
                line,
            )));
        }
        Ok(parse_typed_replies(ops, &line)?)
    }

    /// Write one frame without waiting for the reply (pipelining).
    pub fn send_frame(&mut self, ops: &[MapOp]) -> io::Result<()> {
        use std::fmt::Write as _;
        assert!(!ops.is_empty() && ops.len() <= MAX_BATCH);
        self.frame.clear();
        if ops.len() > 1 {
            writeln!(self.frame, "B {}", ops.len()).expect("write to String");
        }
        for &op in ops {
            push_op(op, &mut self.frame);
        }
        self.out.write_all(self.frame.as_bytes())
    }

    /// Read and parse one batch reply of `n` ops (pairs with
    /// [`Client::send_frame`]; replies arrive in frame order).
    pub fn read_batch_reply(
        &mut self,
        n: usize,
    ) -> io::Result<Vec<Option<u64>>> {
        let line = self.read_reply_line()?;
        if line.starts_with("ERR") {
            return Err(io::Error::new(io::ErrorKind::InvalidData, line));
        }
        let mut vals = Vec::with_capacity(n);
        for tok in line.split_whitespace() {
            vals.push(match tok {
                "-" => None,
                v => Some(v.parse::<u64>().map_err(|_| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("bad reply token {v:?}"),
                    )
                })?),
            });
        }
        if vals.len() != n {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected {n} reply tokens, got {}", vals.len()),
            ));
        }
        Ok(vals)
    }

    /// Read one reply line (trimmed). Pairs with [`Client::send_raw`]
    /// when the test knows how many reply lines its bytes will earn.
    pub fn read_reply_line(&mut self) -> io::Result<String> {
        self.reply.clear();
        if self.reader.read_line(&mut self.reply)? == 0 {
            return Err(io::ErrorKind::UnexpectedEof.into());
        }
        Ok(self.reply.trim_end().to_string())
    }
}
